#!/usr/bin/env python3
"""CI bench gates in one place (stdlib only).

Each gate that used to live as an inline-Python step in
.github/workflows/ci.yml is a named subcommand here, with its threshold
in THRESHOLDS rather than buried in a heredoc. CI invokes one gate per
step so a failure is attributed to the right step name:

    python3 tools/bench_gate.py fp16-volume  BENCH_ci.json
    python3 tools/bench_gate.py hier-vs-flat BENCH_pr.json
    python3 tools/bench_gate.py overlap      BENCH_pr.json
    python3 tools/bench_gate.py planner      BENCH_pr.json
    python3 tools/bench_gate.py compute      BENCH_pr.json
    python3 tools/bench_gate.py compute      runtime_microbench.json
    python3 tools/bench_gate.py staleness    BENCH_pr.json
    python3 tools/bench_gate.py autotune-log quickstart_auto.log
    python3 tools/bench_gate.py sweep-summary allreduce_nightly.json

Exit status: 0 == the gate holds; anything else is a regression, with
the reason on stdout/stderr (and a ::error:: annotation where the gate
guards a committed file).
"""

import json
import math
import re
import subprocess
import sys

THRESHOLDS = {
    # fp16 must at least halve the wire volume (with header slack);
    # top-k at k=0.1 must cut it below a quarter.
    "fp16_bytes_ratio": 0.60,
    "topk10_bytes_ratio": 0.25,
    # World sizes from which the asymptotic winner must actually win.
    "hier_beats_flat_from_n": 16,
    "overlap_wins_from_n": 8,
    # The planner must not pick a hierarchy below the crossover (n=2
    # has no valid grouping at all) and must pick one at scale.
    "planner_flat_below_n": 4,
    "planner_hier_from_n": 16,
    # Pool speedup floor for the compute gate's measured path: the
    # large-shape GEMM at 4 threads must beat 1 thread by this factor
    # (closed-form BENCH_pr.json numbers just need t4 > t1).
    "compute_t4_speedup_min": 1.2,
}

CANDIDATE_RE = re.compile(
    r"\[planner\] candidate (\S+) predicted ([0-9.eE+-]+)s/round")
CHOSE_RE = re.compile(
    r"\[planner\] chose (\S+) codec=(\S+) buckets=\S+ "
    r"predicted ([0-9.eE+-]+)s/round")


def load(path):
    with open(path) as f:
        return json.load(f)


def comm_block(path):
    """BENCH_ci.json is a list of bench blocks; pick the microbench."""
    doc = load(path)
    blocks = doc if isinstance(doc, list) else [doc]
    for b in blocks:
        if b.get("bench") == "comm_microbench":
            return b
    sys.exit(f"no comm_microbench block in {path}")


def gate_fp16_volume(path):
    comm = comm_block(path)
    fp16, topk = comm["ratio_fp16"], comm["ratio_topk10"]
    lim16 = THRESHOLDS["fp16_bytes_ratio"]
    limtk = THRESHOLDS["topk10_bytes_ratio"]
    print(f"fp16 bytes/round ratio:     {fp16:.4f} (must be < {lim16})")
    print(f"topk:0.1 bytes/round ratio: {topk:.4f} (must be < {limtk})")
    if fp16 >= lim16 or topk >= limtk:
        sys.exit("wire compression regressed past the gate")


def gate_hier_vs_flat(path):
    pr = load(path)
    flat = pr["collective_ns"]["flat"]
    hier = pr["collective_ns"]["hier"]
    from_n = THRESHOLDS["hier_beats_flat_from_n"]
    bad = []
    for key, t_flat in sorted(flat.items()):
        n = int(key[1:])
        t_hier = hier[key]
        marker = "<=" if t_hier <= t_flat else "REGRESSION"
        print(f"n={n:3d}: hier {t_hier:>9.0f} ns {marker} "
              f"flat {t_flat:>9.0f} ns")
        if n >= from_n and t_hier > t_flat:
            bad.append(key)
    if bad:
        sys.exit(f"hierarchical all-reduce slower than the flat ring "
                 f"at {bad} — the topology gate failed")


def gate_overlap(path):
    pr = load(path)
    bucketed = pr["overlap"]["bucketed_ns"]
    serial = pr["overlap"]["serial_ns"]
    from_n = THRESHOLDS["overlap_wins_from_n"]
    bad = []
    for key, t_serial in sorted(serial.items()):
        n = int(key[1:])
        t_bucketed = bucketed[key]
        marker = "<" if t_bucketed < t_serial else "REGRESSION"
        print(f"n={n:3d}: bucketed {t_bucketed:>9.0f} ns {marker} "
              f"serial {t_serial:>9.0f} ns")
        if n >= from_n and t_bucketed >= t_serial:
            bad.append(key)
    if bad:
        sys.exit(f"bucketed round not faster than backprop + "
                 f"standalone reduce at {bad} — the overlap gate "
                 f"failed")


def gate_planner(path):
    """Schema-4 planner block: the recorded choice must be the argmin
    of the recorded predictions, flat below the crossover, hierarchical
    above it."""
    pr = load(path)
    if pr.get("schema", 0) < 4:
        sys.exit(f"{path} is schema {pr.get('schema')} — the planner "
                 f"gate needs schema >= 4 (regenerate the file)")
    planner = pr["planner"]
    bad = []
    for key, preds in sorted(planner["predicted_ns"].items()):
        n = int(key[1:])
        chosen = planner["chosen"][key]
        best_ns = min(preds.values())
        ok = preds.get(chosen) == best_ns
        marker = "argmin" if ok else "NOT THE ARGMIN"
        print(f"n={n:3d}: chose {chosen:<22} "
              f"{preds.get(chosen, math.nan):>9} ns {marker} "
              f"(best {best_ns} ns over {len(preds)} candidates)")
        if not ok:
            bad.append(f"{key}: chose {chosen} but the minimum is "
                       f"{best_ns} ns")
        if n < THRESHOLDS["planner_flat_below_n"] \
                and chosen.startswith("hier"):
            bad.append(f"{key}: picked {chosen} below the crossover")
        if n >= THRESHOLDS["planner_hier_from_n"] \
                and not chosen.startswith("hier"):
            bad.append(f"{key}: picked {chosen} at scale — the "
                       f"hierarchy should win from "
                       f"n={THRESHOLDS['planner_hier_from_n']}")
    if bad:
        sys.exit("planner gate failed:\n  " + "\n  ".join(bad))


def gate_compute(path):
    """Compute-kernel gate, dispatched on file content:

    - BENCH_pr.json (schema >= 5): the closed-form compute block's
      MFLOP/s must strictly increase from t1 to t4, and the modeled
      small-shape GEMM time must be thread-invariant (the engine's
      inline serial cutoff is part of the contract).
    - runtime_microbench --json output: measured GFLOP/s — the
      large-shape "nn" GEMM at 4 threads must beat 1 thread by the
      threshold factor (the tn/nt kernels are printed but not gated:
      they share the pool, so the nn result is the signal).
    """
    doc = load(path)
    if "compute" in doc:
        if doc.get("schema", 0) < 5:
            sys.exit(f"{path} is schema {doc.get('schema')} — the "
                     f"compute gate needs schema >= 5 (regenerate)")
        comp = doc["compute"]
        t1, t4 = comp["mflops"]["t1"], comp["mflops"]["t4"]
        print(f"modeled GEMM throughput: t1 {t1:.0f} MFLOP/s, "
              f"t4 {t4:.0f} MFLOP/s")
        if not t4 > t1:
            sys.exit("compute gate failed: modeled t4 MFLOP/s does "
                     "not beat t1")
        small = comp["gemm_time_ns"]["small"]
        if len(set(small.values())) != 1:
            sys.exit(f"compute gate failed: the small shape must be "
                     f"thread-invariant (inline cutoff), got {small}")
        print(f"small-shape GEMM time is thread-invariant "
              f"({next(iter(small.values())):.0f} ns) — the inline "
              f"cutoff holds")
        return
    gflops = doc.get("compute_gflops")
    if gflops is None:
        sys.exit(f"{path} has neither a compute block nor a "
                 f"compute_gflops table")
    floor = THRESHOLDS["compute_t4_speedup_min"]
    bad = []
    for kernel in ("nn", "tn", "nt"):
        t1 = gflops[f"{kernel}/large/t1"]
        t4 = gflops[f"{kernel}/large/t4"]
        speedup = t4 / t1
        gated = kernel == "nn"
        status = "ok" if speedup >= floor or not gated else "REGRESSION"
        print(f"{kernel}: large-shape {t1:.2f} -> {t4:.2f} GFLOP/s "
              f"({speedup:.2f}x{', gated' if gated else ''}) {status}")
        if gated and speedup < floor:
            bad.append(kernel)
    if bad:
        sys.exit(f"compute gate failed: pool speedup below {floor}x "
                 f"for {bad}")


def gate_staleness(path):
    """The committed file must be tracked AND match the regenerated
    one. `git diff` exits 0 for untracked paths, which would make the
    gate vacuous in exactly the forgot-to-commit case it exists to
    catch — so require tracking first."""
    regen = ("run 'cargo bench --bench allreduce_scaling -- --ci "
             f"--pr-json ../{path}' and commit the result")
    if subprocess.run(["git", "ls-files", "--error-unmatch", path],
                      capture_output=True).returncode != 0:
        print(f"::error::{path} is not committed — {regen}")
        sys.exit(1)
    if subprocess.run(["git", "diff", "--exit-code", path]).returncode:
        print(f"::error::{path} is stale — {regen}")
        sys.exit(1)
    print(f"{path} is tracked and matches the regenerated output")


def parse_autotune_log(path):
    """Split a quickstart/train log into sweeps: each `[planner] chose`
    line closes the run of `[planner] candidate` lines before it."""
    sweeps, cands = [], []
    with open(path) as f:
        for line in f:
            m = CANDIDATE_RE.search(line)
            if m:
                cands.append((m.group(1), float(m.group(2))))
                continue
            m = CHOSE_RE.search(line)
            if m:
                chosen = f"{m.group(1)}|{m.group(2)}"
                sweeps.append((cands, chosen, float(m.group(3))))
                cands = []
    return sweeps


def gate_autotune_log(path):
    """Live-run gate: the plan the `--auto` run logged must be the
    argmin of the candidate predictions it logged next to it."""
    sweeps = parse_autotune_log(path)
    if not sweeps:
        sys.exit(f"no '[planner] chose' line in {path} — did the run "
                 f"actually auto-tune?")
    for i, (cands, chosen, chosen_s) in enumerate(sweeps):
        if not cands:
            sys.exit(f"sweep {i}: a chose line with no candidate "
                     f"lines before it")
        best_key, best_s = min(cands, key=lambda kv: kv[1])
        print(f"sweep {i}: chose {chosen} at {chosen_s:.3e}s/round "
              f"over {len(cands)} candidates "
              f"(argmin {best_key} at {best_s:.3e}s)")
        if chosen_s > best_s:
            sys.exit(f"sweep {i}: chose {chosen} "
                     f"({chosen_s:.3e}s/round) but {best_key} "
                     f"predicted {best_s:.3e}s — not the argmin")
        if chosen != best_key and chosen_s != best_s:
            sys.exit(f"sweep {i}: chose {chosen} which is not among "
                     f"the minimal candidates")
    print(f"{len(sweeps)} sweep(s) OK: every chosen plan is its "
          f"sweep's argmin")


def sweep_summary(path):
    """Not a gate: print the planner columns of an allreduce_scaling
    sweep JSON (nightly log surface)."""
    doc = load(path)
    chosen = doc.get("planner_chosen", {})
    sims = doc.get("simulated_s", {})
    if not chosen:
        sys.exit(f"{path} has no planner_chosen block — bench too old?")
    print(f"{'ranks':>6} {'chosen plan':<22} {'predicted round':>16}")
    for key in sorted(chosen, key=lambda k: int(k[1:])):
        pred = sims.get(f"planner_pred_round/{key}")
        pred_str = f"{pred * 1e3:.3f} ms" if pred is not None else "?"
        print(f"{key[1:]:>6} {chosen[key]:<22} {pred_str:>16}")


GATES = {
    "fp16-volume": gate_fp16_volume,
    "hier-vs-flat": gate_hier_vs_flat,
    "overlap": gate_overlap,
    "planner": gate_planner,
    "compute": gate_compute,
    "staleness": gate_staleness,
    "autotune-log": gate_autotune_log,
    "sweep-summary": sweep_summary,
}


def main(argv):
    if len(argv) != 2 or argv[0] not in GATES:
        names = " | ".join(GATES)
        sys.exit(f"usage: bench_gate.py <{names}> <path>")
    GATES[argv[0]](argv[1])


if __name__ == "__main__":
    main(sys.argv[1:])
