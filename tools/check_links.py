#!/usr/bin/env python3
"""Markdown link checker for the repo docs (stdlib only).

Validates every relative link and in-page anchor in the files given on
the command line (CI runs it over README.md, DESIGN.md and
docs/RUNBOOK.md). External http(s) links are NOT fetched — CI must not
depend on the network — only their syntax is accepted.

Checked:
  * [text](path)          -> path exists, relative to the linking file
  * [text](path#anchor)   -> path exists AND the .md target contains a
                             heading whose GitHub slug == anchor
  * [text](#anchor)       -> heading with that slug in the same file

Exit status: number of broken links (0 == success).
"""

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^\s*(```|~~~)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")


def strip_fences(text):
    """Drop fenced code blocks — their brackets are not links."""
    out, fenced = [], False
    for line in text.splitlines():
        if FENCE_RE.match(line):
            fenced = not fenced
            out.append("")
        else:
            out.append("" if fenced else line)
    return "\n".join(out)


def github_slug(heading):
    """GitHub's anchor slug: lowercase, drop punctuation, spaces->'-'."""
    # inline code/links inside the heading contribute their text only
    heading = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", heading)
    heading = heading.replace("`", "")
    slug = heading.strip().lower()
    slug = re.sub(r"[^\w\- ]", "", slug)
    return slug.replace(" ", "-")


def slugs_of(path):
    """All heading anchors of a markdown file, with -1/-2 dup suffixes."""
    seen, slugs = {}, set()
    for line in strip_fences(path.read_text()).splitlines():
        m = HEADING_RE.match(line)
        if not m:
            continue
        base = github_slug(m.group(2))
        n = seen.get(base, 0)
        seen[base] = n + 1
        slugs.add(base if n == 0 else f"{base}-{n}")
    return slugs


def check_file(path, errors):
    text = strip_fences(path.read_text())
    for n, line in enumerate(text.splitlines(), 1):
        for target in LINK_RE.findall(line):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:
                continue
            raw_path, _, anchor = target.partition("#")
            dest = path if not raw_path \
                else (path.parent / raw_path).resolve()
            if not dest.exists():
                errors.append(f"{path}:{n}: missing file: {target}")
                continue
            if anchor:
                if dest.suffix != ".md":
                    continue  # anchors into non-markdown: not checked
                if anchor.lower() not in slugs_of(dest):
                    errors.append(
                        f"{path}:{n}: missing anchor: {target}")


def main(argv):
    if len(argv) < 2:
        print(f"usage: {argv[0]} FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    errors = []
    for name in argv[1:]:
        p = Path(name)
        if not p.exists():
            errors.append(f"{name}: file not found")
            continue
        check_file(p, errors)
    for e in errors:
        print(e, file=sys.stderr)
    checked = len(argv) - 1
    print(f"checked {checked} file(s): "
          f"{'OK' if not errors else f'{len(errors)} broken link(s)'}")
    return min(len(errors), 125)


if __name__ == "__main__":
    sys.exit(main(sys.argv))
