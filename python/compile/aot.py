"""AOT compiler: lower the model zoo to HLO text + a manifest for Rust.

Interchange format is HLO *text*, not serialized HloModuleProto — jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what
the published `xla` 0.1.6 crate links) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Emits, per (model, batch):
    artifacts/{key}_grad.hlo.txt     (*params, x, y) -> (loss, *grads)
    artifacts/{key}_eval.hlo.txt     (*params, x, y) -> (loss, ncorrect)
    artifacts/{key}_predict.hlo.txt  (*params, x)    -> (logits,)
plus one artifacts/meta.json manifest describing every artifact's
parameter names/shapes and input shapes, in the exact positional order the
Rust runtime must feed.

Usage:
    python -m compile.aot --out-dir ../artifacts [--quick]
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# Default artifact set. Table I of the paper sweeps batch size
# {10, 100, 500, 1000} at 20 workers; Figs 2-4 use batch 100.
DEFAULT_SPECS = [
    ("lstm", M.PAPER_LSTM, [10, 100, 500, 1000]),
    ("mlp", M.QUICKSTART_MLP, [100]),
    ("transformer", M.TRANSFORMER, [16]),
]
QUICK_SPECS = [
    ("lstm", M.PAPER_LSTM, [10, 100]),
    ("mlp", M.QUICKSTART_MLP, [100]),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(cfg: M.ModelConfig, batch: int, out_dir: str, key: str):
    names = M.param_names(cfg)
    params = M.init_params(cfg)
    param_specs = [
        jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names
    ]
    x_spec = jax.ShapeDtypeStruct(
        (batch, cfg.seq_len, cfg.features), jnp.float32)
    y_spec = jax.ShapeDtypeStruct((batch,), jnp.int32)

    entries = {}
    for kind, fn, specs in [
        ("grad", M.make_grad_fn(cfg), param_specs + [x_spec, y_spec]),
        ("eval", M.make_eval_fn(cfg), param_specs + [x_spec, y_spec]),
        ("predict", M.make_predict_fn(cfg), param_specs + [x_spec]),
    ]:
        t0 = time.time()
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{key}_{kind}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        print(f"  {fname}: {len(text)/1e6:.2f} MB in {time.time()-t0:.1f}s")
        entries[kind] = fname

    return {
        "model": cfg.name,
        "batch": batch,
        "seq_len": cfg.seq_len,
        "features": cfg.features,
        "classes": cfg.classes,
        "hidden": cfg.hidden,
        "params": [
            {"name": n, "shape": list(params[n].shape)} for n in names
        ],
        "param_count": int(sum(p.size for p in params.values())),
        "inputs": {
            "x": [batch, cfg.seq_len, cfg.features],
            "y": [batch],
        },
        "artifacts": entries,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only the artifacts needed for tests")
    ap.add_argument("--models", default=None,
                    help="comma list filter, e.g. lstm,mlp")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    specs = QUICK_SPECS if args.quick else DEFAULT_SPECS
    if args.models:
        allow = set(args.models.split(","))
        specs = [s for s in specs if s[0] in allow]

    manifest = {"format_version": 1, "models": {}}
    for name, cfg, batches in specs:
        for batch in batches:
            key = f"{name}_b{batch}"
            print(f"[aot] lowering {key} ...")
            manifest["models"][key] = lower_model(cfg, batch, args.out_dir,
                                                  key)
    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {os.path.join(args.out_dir, 'meta.json')} "
          f"({len(manifest['models'])} model variants)")


if __name__ == "__main__":
    main()
