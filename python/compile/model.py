"""L2 — model zoo in JAX, built on the L1 Pallas kernels.

The paper's benchmark model is an LSTM(20) + softmax(3) classifying
sequences of simulated LHC collision-event features; `lstm` below is that
model. `mlp` is the quickstart model, and `transformer` is a larger
encoder-style classifier over the same (x: f32[B,T,F], y: i32[B])
interface, included to show the stack handles non-trivial models.

Every model exposes:
  init(rng)            -> params dict (name -> f32 array)
  apply(params, x)     -> logits [B, C]
and the module-level helpers build the AOT entry points:
  grad_fn:  (*param_leaves, x, y) -> (loss, *grad_leaves)
  eval_fn:  (*param_leaves, x, y) -> (loss, ncorrect)
  predict_fn: (*param_leaves, x)  -> (logits,)

Parameter leaves are ordered by sorted name — the same order `meta.json`
records and the Rust runtime feeds.
"""

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import dense, lstm_cell, softmax_xent


# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    """Static architecture description (mirrors the paper's ModelBuilder)."""

    name: str
    seq_len: int = 30
    features: int = 16
    classes: int = 3
    hidden: int = 20          # LSTM hidden units (paper: 20)
    mlp_widths: Tuple[int, ...] = (64, 32)
    d_model: int = 128        # transformer width
    n_layers: int = 4
    n_heads: int = 4


PAPER_LSTM = ModelConfig(name="lstm")
QUICKSTART_MLP = ModelConfig(name="mlp")
TRANSFORMER = ModelConfig(name="transformer")
TRANSFORMER_BIG = ModelConfig(
    name="transformer", d_model=256, n_layers=6, n_heads=8
)


def _glorot(rng, shape):
    fan_in, fan_out = shape[0], shape[-1]
    lim = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(rng, shape, jnp.float32, -lim, lim)


# ---------------------------------------------------------------------------
# LSTM classifier (the paper's benchmark)
# ---------------------------------------------------------------------------

def lstm_init(cfg: ModelConfig, rng) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(rng, 4)
    h4 = 4 * cfg.hidden
    return {
        "lstm_wx": _glorot(ks[0], (cfg.features, h4)),
        "lstm_wh": _glorot(ks[1], (cfg.hidden, h4)),
        "lstm_b": jnp.zeros((h4,), jnp.float32),
        "out_w": _glorot(ks[2], (cfg.hidden, cfg.classes)),
        "out_b": jnp.zeros((cfg.classes,), jnp.float32),
    }


def lstm_apply(cfg: ModelConfig, params, x):
    """x: [B, T, F] -> logits [B, C]. Scans the fused Pallas cell over T."""
    bsz = x.shape[0]
    h0 = jnp.zeros((bsz, cfg.hidden), jnp.float32)
    c0 = jnp.zeros((bsz, cfg.hidden), jnp.float32)
    xs = jnp.transpose(x, (1, 0, 2))  # [T, B, F] for scan

    def step(carry, x_t):
        h, c = carry
        h, c = lstm_cell(x_t, h, c, params["lstm_wx"], params["lstm_wh"],
                         params["lstm_b"])
        return (h, c), None

    (h, _), _ = jax.lax.scan(step, (h0, c0), xs)
    return dense(h, params["out_w"], params["out_b"])


# ---------------------------------------------------------------------------
# MLP classifier (quickstart)
# ---------------------------------------------------------------------------

def mlp_init(cfg: ModelConfig, rng) -> Dict[str, jnp.ndarray]:
    widths = (cfg.seq_len * cfg.features,) + tuple(cfg.mlp_widths) + (
        cfg.classes,)
    ks = jax.random.split(rng, len(widths))
    params = {}
    for li in range(len(widths) - 1):
        params[f"fc{li}_w"] = _glorot(ks[li], (widths[li], widths[li + 1]))
        params[f"fc{li}_b"] = jnp.zeros((widths[li + 1],), jnp.float32)
    return params


def mlp_apply(cfg: ModelConfig, params, x):
    bsz = x.shape[0]
    h = jnp.reshape(x, (bsz, -1))
    n_layers = len(cfg.mlp_widths) + 1
    for li in range(n_layers):
        h = dense(h, params[f"fc{li}_w"], params[f"fc{li}_b"])
        if li < n_layers - 1:
            h = jnp.tanh(h)
    return h


# ---------------------------------------------------------------------------
# Transformer encoder classifier
# ---------------------------------------------------------------------------

def transformer_init(cfg: ModelConfig, rng) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    ks = jax.random.split(rng, 2 + 6 * cfg.n_layers)
    params = {
        "embed_w": _glorot(ks[0], (cfg.features, d)),
        "embed_b": jnp.zeros((d,), jnp.float32),
        "pos": 0.02 * jax.random.normal(ks[1], (cfg.seq_len, d)),
        "cls_w": _glorot(ks[-1], (d, cfg.classes)),
        "cls_b": jnp.zeros((cfg.classes,), jnp.float32),
    }
    for li in range(cfg.n_layers):
        k = ks[2 + 6 * li : 2 + 6 * (li + 1)]
        params[f"l{li}_qkv_w"] = _glorot(k[0], (d, 3 * d))
        params[f"l{li}_qkv_b"] = jnp.zeros((3 * d,), jnp.float32)
        params[f"l{li}_proj_w"] = _glorot(k[1], (d, d))
        params[f"l{li}_proj_b"] = jnp.zeros((d,), jnp.float32)
        params[f"l{li}_mlp1_w"] = _glorot(k[2], (d, 4 * d))
        params[f"l{li}_mlp1_b"] = jnp.zeros((4 * d,), jnp.float32)
        params[f"l{li}_mlp2_w"] = _glorot(k[3], (4 * d, d))
        params[f"l{li}_mlp2_b"] = jnp.zeros((d,), jnp.float32)
        params[f"l{li}_ln1_g"] = jnp.ones((d,), jnp.float32)
        params[f"l{li}_ln2_g"] = jnp.ones((d,), jnp.float32)
    return params


def _layernorm(x, gain):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return gain * (x - mu) / jnp.sqrt(var + 1e-5)


def _dense_seq(x, w, b):
    """dense() over a [B,T,D] tensor by folding T into the batch tile."""
    bsz, t, d = x.shape
    y = dense(jnp.reshape(x, (bsz * t, d)), w, b)
    return jnp.reshape(y, (bsz, t, -1))


def transformer_apply(cfg: ModelConfig, params, x):
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    bsz, t, _ = x.shape
    h = _dense_seq(x, params["embed_w"], params["embed_b"]) + params["pos"]
    for li in range(cfg.n_layers):
        z = _layernorm(h, params[f"l{li}_ln1_g"])
        qkv = _dense_seq(z, params[f"l{li}_qkv_w"], params[f"l{li}_qkv_b"])
        qkv = jnp.reshape(qkv, (bsz, t, 3, nh, hd))
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]  # [B,T,nh,hd]
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v)
        o = jnp.reshape(o, (bsz, t, d))
        h = h + _dense_seq(o, params[f"l{li}_proj_w"], params[f"l{li}_proj_b"])
        z = _layernorm(h, params[f"l{li}_ln2_g"])
        z = _dense_seq(z, params[f"l{li}_mlp1_w"], params[f"l{li}_mlp1_b"])
        z = jax.nn.gelu(z)
        h = h + _dense_seq(z, params[f"l{li}_mlp2_w"], params[f"l{li}_mlp2_b"])
    pooled = jnp.mean(h, axis=1)
    return dense(pooled, params["cls_w"], params["cls_b"])


# ---------------------------------------------------------------------------
# Registry + AOT entry points
# ---------------------------------------------------------------------------

MODELS: Dict[str, Tuple[Callable, Callable]] = {
    "lstm": (lstm_init, lstm_apply),
    "mlp": (mlp_init, mlp_apply),
    "transformer": (transformer_init, transformer_apply),
}


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jnp.ndarray]:
    init, _ = MODELS[cfg.name]
    return init(cfg, jax.random.PRNGKey(seed))


def param_names(cfg: ModelConfig) -> List[str]:
    return sorted(init_params(cfg).keys())


def loss_and_logits(cfg: ModelConfig, params, x, y):
    _, apply = MODELS[cfg.name]
    logits = apply(cfg, params, x)
    return softmax_xent(logits, y), logits


def make_grad_fn(cfg: ModelConfig):
    """(*param_leaves, x, y) -> (loss, *grad_leaves); leaf order = sorted names."""
    names = param_names(cfg)

    def fn(*args):
        leaves, x, y = args[:-2], args[-2], args[-1]
        params = dict(zip(names, leaves))

        def loss_fn(p):
            loss, _ = loss_and_logits(cfg, p, x, y)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return (loss,) + tuple(grads[n] for n in names)

    return fn


def make_eval_fn(cfg: ModelConfig):
    """(*param_leaves, x, y) -> (loss, ncorrect f32)."""
    names = param_names(cfg)

    def fn(*args):
        leaves, x, y = args[:-2], args[-2], args[-1]
        params = dict(zip(names, leaves))
        loss, logits = loss_and_logits(cfg, params, x, y)
        ncorrect = jnp.sum(
            (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return loss, ncorrect

    return fn


def make_predict_fn(cfg: ModelConfig):
    """(*param_leaves, x) -> (logits,)."""
    names = param_names(cfg)

    def fn(*args):
        leaves, x = args[:-1], args[-1]
        params = dict(zip(names, leaves))
        _, apply = MODELS[cfg.name]
        return (apply(cfg, params, x),)

    return fn
