# L1: Pallas kernels for the compute hot-spots (+ pure-jnp oracle in ref.py).
from .dense import dense
from .lstm import lstm_cell
from .xent import softmax_xent

__all__ = ["dense", "lstm_cell", "softmax_xent"]
