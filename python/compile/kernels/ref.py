"""Pure-jnp reference oracles for every Pallas kernel (L1 correctness).

Each function here is the mathematical specification of the corresponding
Pallas kernel in this package. `python/tests/test_kernel.py` sweeps shapes
and dtypes with hypothesis and asserts allclose between kernel and oracle,
including gradients (the kernels carry custom VJPs; the oracles are plain
jnp so `jax.grad` differentiates them natively).
"""

import jax.numpy as jnp

FORGET_BIAS = 1.0  # Keras LSTM `unit_forget_bias=True` analogue


def dense_ref(x, w, b):
    """y = x @ w + b  — [B,I] @ [I,O] + [O] -> [B,O]."""
    return x @ w + b


def lstm_cell_ref(x, h, c, wx, wh, b):
    """One LSTM cell step (Keras gate order i, f, g, o).

    x: [B,F]; h,c: [B,H]; wx: [F,4H]; wh: [H,4H]; b: [4H]
    Returns (h_new, c_new), each [B,H].
    """
    hsz = h.shape[-1]
    gates = x @ wx + h @ wh + b
    i = gates[:, 0 * hsz : 1 * hsz]
    f = gates[:, 1 * hsz : 2 * hsz]
    g = gates[:, 2 * hsz : 3 * hsz]
    o = gates[:, 3 * hsz : 4 * hsz]
    i = 1.0 / (1.0 + jnp.exp(-i))
    f = 1.0 / (1.0 + jnp.exp(-(f + FORGET_BIAS)))
    o = 1.0 / (1.0 + jnp.exp(-o))
    g = jnp.tanh(g)
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def softmax_xent_ref(logits, labels):
    """Mean softmax cross-entropy. logits: [B,C]; labels: int [B] -> scalar."""
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    logz = jnp.log(jnp.sum(jnp.exp(z), axis=-1))
    ll = jnp.take_along_axis(z, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - ll)


def softmax_ref(logits):
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / jnp.sum(e, axis=-1, keepdims=True)
