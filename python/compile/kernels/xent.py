"""Pallas softmax cross-entropy kernel with custom VJP.

Forward emits the mean NLL *and* the softmax probabilities in one pass
(the probs are exactly the residual the backward needs, so nothing is
recomputed). Backward is the classic (p - onehot)/B, fused in Pallas.

Labels travel as int32 [B]; onehot comparison is done with broadcasted
iota inside the kernel so no onehot matrix ever hits HBM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _fwd_kernel(logits_ref, labels_ref, loss_ref, probs_ref):
    logits = logits_ref[...]
    bsz, csz = logits.shape
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(z)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    probs = e / denom
    probs_ref[...] = probs
    classes = jax.lax.broadcasted_iota(jnp.int32, (bsz, csz), 1)
    onehot = (classes == labels_ref[...][:, None]).astype(jnp.float32)
    ll = jnp.sum(z * onehot, axis=-1)
    logz = jnp.log(denom[:, 0])
    loss_ref[0] = jnp.mean(logz - ll)


def _bwd_kernel(probs_ref, labels_ref, g_ref, dlogits_ref):
    probs = probs_ref[...]
    bsz, csz = probs.shape
    classes = jax.lax.broadcasted_iota(jnp.int32, (bsz, csz), 1)
    onehot = (classes == labels_ref[...][:, None]).astype(jnp.float32)
    dlogits_ref[...] = g_ref[0] * (probs - onehot) / bsz


def _xent_fwd_impl(logits, labels):
    bsz, csz = logits.shape
    loss, probs = pl.pallas_call(
        _fwd_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((bsz, csz), jnp.float32),
        ),
        interpret=INTERPRET,
    )(logits, labels)
    return loss[0], probs


@jax.custom_vjp
def softmax_xent(logits, labels):
    """Mean softmax cross-entropy. logits [B,C] f32, labels [B] i32."""
    loss, _ = _xent_fwd_impl(logits, labels)
    return loss


def _xent_vjp_fwd(logits, labels):
    loss, probs = _xent_fwd_impl(logits, labels)
    return loss, (probs, labels)


def _xent_vjp_bwd(res, g):
    probs, labels = res
    bsz, csz = probs.shape
    dlogits = pl.pallas_call(
        _bwd_kernel,
        out_shape=jax.ShapeDtypeStruct((bsz, csz), jnp.float32),
        interpret=INTERPRET,
    )(probs, labels, jnp.reshape(g, (1,)))
    return dlogits, None


softmax_xent.defvjp(_xent_vjp_fwd, _xent_vjp_bwd)
