"""Pallas dense (matmul + bias) kernel with a custom VJP.

This is the MXU workhorse shared by every model in the zoo: the LSTM output
projection, every MLP layer, and the transformer's QKV/out/MLP projections
all lower through `dense()`.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the weight block [I, O]
is held VMEM-resident while batch tiles of `x` stream HBM→VMEM; the matmul
itself targets the 128x128 MXU systolic array. On this session's CPU-PJRT
substrate the kernel runs under `interpret=True`, which lowers the same
block program to plain HLO — numerics identical, scheduling simulated.

The backward pass is itself a pair of Pallas kernels (dx and (dw, db)),
so the whole fwd+bwd graph is kernel-composed rather than falling back to
XLA autodiff through the forward.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT cannot execute Mosaic custom-calls.

# Batch tile: on a real TPU this bounds the activation VMEM slab; full
# I/O (feature) extents stay resident. Perf pass (EXPERIMENTS.md §Perf):
# 128 -> 512 -> 1024 cut the b1000 grad step 76 -> 60 -> 53 ms on the
# CPU-interpret substrate (fewer grid iterations); at 1024 rows the
# worst-case activation slab (transformer qkv: 1024 x 3*128 x 4 B ~
# 1.5 MB) still sits well inside a 16 MB VMEM budget, and the batch
# dimension streams through the 128x128 MXU in row-groups regardless of
# tile height, so the TPU mapping is unaffected.
BATCH_TILE = 1024


def _fwd_kernel(x_ref, w_ref, b_ref, o_ref):
    # One batch tile: [tb, I] @ [I, O] + [O]
    o_ref[...] = (
        jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...][None, :]
    )


def _dx_kernel(g_ref, w_ref, dx_ref):
    # dx = g @ w^T : [tb, O] @ [O, I]
    dx_ref[...] = jnp.dot(
        g_ref[...], w_ref[...].T, preferred_element_type=jnp.float32
    )


def _dw_db_kernel(x_ref, g_ref, dw_ref, db_ref):
    # Weight grads reduce over the *whole* batch — run un-gridded so the
    # reduction stays inside one kernel invocation (no cross-tile accum).
    dw_ref[...] = jnp.dot(
        x_ref[...].T, g_ref[...], preferred_element_type=jnp.float32
    )
    db_ref[...] = jnp.sum(g_ref[...], axis=0)


def _tile(b):
    return min(b, BATCH_TILE)


def _dense_fwd_impl(x, w, b):
    bsz, _ = x.shape
    osz = w.shape[1]
    tb = _tile(bsz)
    grid = (pl.cdiv(bsz, tb),)
    return pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, x.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((w.shape[0], osz), lambda i: (0, 0)),
            pl.BlockSpec((osz,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tb, osz), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, osz), jnp.float32),
        interpret=INTERPRET,
    )(x, w, b)


@jax.custom_vjp
def dense(x, w, b):
    """y = x @ w + b with Pallas fwd and bwd. x:[B,I] w:[I,O] b:[O]."""
    return _dense_fwd_impl(x, w, b)


def _dense_fwd(x, w, b):
    return _dense_fwd_impl(x, w, b), (x, w)


def _dense_bwd(res, g):
    x, w = res
    bsz, isz = x.shape
    osz = w.shape[1]
    tb = _tile(bsz)
    dx = pl.pallas_call(
        _dx_kernel,
        grid=(pl.cdiv(bsz, tb),),
        in_specs=[
            pl.BlockSpec((tb, osz), lambda i: (i, 0)),
            pl.BlockSpec((isz, osz), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, isz), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, isz), jnp.float32),
        interpret=INTERPRET,
    )(g, w)
    dw, db = pl.pallas_call(
        _dw_db_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((isz, osz), jnp.float32),
            jax.ShapeDtypeStruct((osz,), jnp.float32),
        ),
        interpret=INTERPRET,
    )(x, g)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)
