"""Fused Pallas LSTM cell — the benchmark model's compute hot-spot.

The paper's benchmark is a Keras LSTM(20) classifying LHC collision-event
sequences; on GPU that work lands in cuDNN's fused LSTM kernel. The TPU
re-think (DESIGN.md §Hardware-Adaptation): fuse the four gate projections
into ONE [F+H, 4H] matmul so a single MXU pass produces all gate
pre-activations, then apply the gate nonlinearities on the VPU while the
tile is still VMEM-resident, writing back only h' and c'.

Weights (wx ⊕ wh as conceptually one [F+H, 4H] operand — kept as two refs
to avoid a concat copy) stay VMEM-resident across the whole sequence scan;
per-step activations stream. At the paper's size (F=16, H=20) the weight
slab is ~12 KB — VMEM-trivial; the same BlockSpec scales to H≈1024 before
VMEM pressure forces gate-dimension tiling.

Backward is a fused Pallas kernel too: it recomputes the cheap pointwise
path from saved gate pre-activations (rematerialization: saving post-
nonlinearity gates would cost 4 extra [B,4H] HBM writes per step) and
emits dgates, dc in one pass; the matmul grads reuse kernel-level dots.

Gate order follows Keras: i, f, g (cell candidate), o, with the Keras
`unit_forget_bias` +1.0 applied to the forget gate pre-activation.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import FORGET_BIAS

INTERPRET = True
# See dense.py for the tile-size derivation (perf pass iter 3/4).
BATCH_TILE = 1024


def _sigmoid(z):
    return 1.0 / (1.0 + jnp.exp(-z))


def _cell_fwd_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref,
                     hn_ref, cn_ref, gates_ref):
    """One fused cell step for one batch tile.

    Emits h', c' and the raw gate pre-activations (saved for bwd).
    """
    hsz = h_ref.shape[-1]
    # Single fused MXU pass: [tb, F]@[F,4H] + [tb,H]@[H,4H] + [4H]
    gates = (
        jnp.dot(x_ref[...], wx_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(h_ref[...], wh_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...][None, :]
    )
    gates_ref[...] = gates
    i = _sigmoid(gates[:, 0 * hsz : 1 * hsz])
    f = _sigmoid(gates[:, 1 * hsz : 2 * hsz] + FORGET_BIAS)
    g = jnp.tanh(gates[:, 2 * hsz : 3 * hsz])
    o = _sigmoid(gates[:, 3 * hsz : 4 * hsz])
    c_new = f * c_ref[...] + i * g
    hn_ref[...] = o * jnp.tanh(c_new)
    cn_ref[...] = c_new


def _cell_bwd_pointwise_kernel(gates_ref, c_ref, cn_ref, dh_ref, dc_ref,
                               dg_ref, dcp_ref):
    """Pointwise half of the cell backward: dgates and dc_prev.

    Recomputes gate activations from saved pre-activations (remat), then
    the standard LSTM chain rule. The matmul half (dx, dh_prev, dwx, dwh,
    db) is done with shared dense-style dots outside.
    """
    hsz = c_ref.shape[-1]
    gates = gates_ref[...]
    i = _sigmoid(gates[:, 0 * hsz : 1 * hsz])
    f = _sigmoid(gates[:, 1 * hsz : 2 * hsz] + FORGET_BIAS)
    g = jnp.tanh(gates[:, 2 * hsz : 3 * hsz])
    o = _sigmoid(gates[:, 3 * hsz : 4 * hsz])
    tanh_cn = jnp.tanh(cn_ref[...])
    dh = dh_ref[...]
    # total dc: incoming dc' plus dh' through h' = o * tanh(c')
    dct = dc_ref[...] + dh * o * (1.0 - tanh_cn * tanh_cn)
    di = dct * g * i * (1.0 - i)
    df = dct * c_ref[...] * f * (1.0 - f)
    dg = dct * i * (1.0 - g * g)
    do = dh * tanh_cn * o * (1.0 - o)
    dg_ref[...] = jnp.concatenate([di, df, dg, do], axis=-1)
    dcp_ref[...] = dct * f


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...],
                         preferred_element_type=jnp.float32)


def _pallas_matmul(a, b):
    """[M,K]@[K,N] as an un-gridded Pallas dot (interpret mode)."""
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((a.shape[0], b.shape[1]), jnp.float32),
        interpret=INTERPRET,
    )(a, b)


def _cell_fwd_impl(x, h, c, wx, wh, b):
    bsz = x.shape[0]
    fsz = x.shape[1]
    hsz = h.shape[1]
    tb = min(bsz, BATCH_TILE)
    grid = (pl.cdiv(bsz, tb),)
    hn, cn, gates = pl.pallas_call(
        _cell_fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, fsz), lambda i: (i, 0)),
            pl.BlockSpec((tb, hsz), lambda i: (i, 0)),
            pl.BlockSpec((tb, hsz), lambda i: (i, 0)),
            pl.BlockSpec((fsz, 4 * hsz), lambda i: (0, 0)),  # VMEM-resident
            pl.BlockSpec((hsz, 4 * hsz), lambda i: (0, 0)),  # VMEM-resident
            pl.BlockSpec((4 * hsz,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tb, hsz), lambda i: (i, 0)),
            pl.BlockSpec((tb, hsz), lambda i: (i, 0)),
            pl.BlockSpec((tb, 4 * hsz), lambda i: (i, 0)),
        ],
        out_shape=(
            jax.ShapeDtypeStruct((bsz, hsz), jnp.float32),
            jax.ShapeDtypeStruct((bsz, hsz), jnp.float32),
            jax.ShapeDtypeStruct((bsz, 4 * hsz), jnp.float32),
        ),
        interpret=INTERPRET,
    )(x, h, c, wx, wh, b)
    return hn, cn, gates


@jax.custom_vjp
def lstm_cell(x, h, c, wx, wh, b):
    """Fused LSTM cell step. Returns (h_new, c_new).

    x: [B,F]; h,c: [B,H]; wx: [F,4H]; wh: [H,4H]; b: [4H].
    """
    hn, cn, _ = _cell_fwd_impl(x, h, c, wx, wh, b)
    return hn, cn


def _lstm_cell_fwd(x, h, c, wx, wh, b):
    hn, cn, gates = _cell_fwd_impl(x, h, c, wx, wh, b)
    return (hn, cn), (x, h, c, cn, gates, wx, wh)


def _lstm_cell_bwd(res, grads):
    dh, dc = grads
    x, h, c, cn, gates, wx, wh = res
    bsz = x.shape[0]
    hsz = h.shape[1]
    tb = min(bsz, BATCH_TILE)
    grid = (pl.cdiv(bsz, tb),)
    dgates, dc_prev = pl.pallas_call(
        _cell_bwd_pointwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, 4 * hsz), lambda i: (i, 0)),
            pl.BlockSpec((tb, hsz), lambda i: (i, 0)),
            pl.BlockSpec((tb, hsz), lambda i: (i, 0)),
            pl.BlockSpec((tb, hsz), lambda i: (i, 0)),
            pl.BlockSpec((tb, hsz), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, 4 * hsz), lambda i: (i, 0)),
            pl.BlockSpec((tb, hsz), lambda i: (i, 0)),
        ],
        out_shape=(
            jax.ShapeDtypeStruct((bsz, 4 * hsz), jnp.float32),
            jax.ShapeDtypeStruct((bsz, hsz), jnp.float32),
        ),
        interpret=INTERPRET,
    )(gates, c, cn, dh, dc)
    dx = _pallas_matmul(dgates, wx.T)
    dh_prev = _pallas_matmul(dgates, wh.T)
    dwx = _pallas_matmul(x.T, dgates)
    dwh = _pallas_matmul(h.T, dgates)
    db = jnp.sum(dgates, axis=0)
    return dx, dh_prev, dc_prev, dwx, dwh, db


lstm_cell.defvjp(_lstm_cell_fwd, _lstm_cell_bwd)
