"""AOT manifest + HLO artifact consistency.

These tests validate what the Rust runtime consumes: that meta.json
accurately describes each HLO artifact's positional interface, and that the
HLO text round-trips through XLA's own parser with the declared shapes.
"""

import json
import os
import re
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile import model as M  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
META = os.path.join(ART, "meta.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(META), reason="run `make artifacts` first")


def _manifest():
    with open(META) as f:
        return json.load(f)


def test_manifest_lists_existing_files():
    man = _manifest()
    assert man["format_version"] == 1
    assert man["models"], "empty manifest"
    for key, entry in man["models"].items():
        for kind, fname in entry["artifacts"].items():
            path = os.path.join(ART, fname)
            assert os.path.exists(path), f"{key}/{kind} missing: {fname}"
            assert os.path.getsize(path) > 1000


def test_manifest_param_shapes_match_model():
    man = _manifest()
    for key, entry in man["models"].items():
        cfg = M.ModelConfig(
            name=entry["model"], seq_len=entry["seq_len"],
            features=entry["features"], classes=entry["classes"],
            hidden=entry["hidden"])
        params = M.init_params(cfg)
        names = M.param_names(cfg)
        assert [p["name"] for p in entry["params"]] == names
        for p in entry["params"]:
            assert list(params[p["name"]].shape) == p["shape"], p["name"]
        assert entry["param_count"] == sum(
            int(params[n].size) for n in names)


def _entry_param_layout(text):
    """Parse `ENTRY ... { ... parameter(i) ... }` shapes from HLO text."""
    entry = text[text.index("ENTRY"):]
    params = {}
    for m in re.finditer(
            r"=\s*([a-z0-9\[\],]+)\{?[0-9,]*\}?\s+parameter\((\d+)\)", entry):
        shape, idx = m.group(1), int(m.group(2))
        params[idx] = shape
    return params


def _shape_str(dtype, dims):
    return f"{dtype}[{','.join(str(d) for d in dims)}]"


def test_grad_hlo_entry_signature_matches_manifest():
    man = _manifest()
    for key, entry in man["models"].items():
        path = os.path.join(ART, entry["artifacts"]["grad"])
        with open(path) as f:
            text = f.read()
        layout = _entry_param_layout(text)
        n = len(entry["params"])
        assert len(layout) == n + 2, f"{key}: {len(layout)} params"
        for i, p in enumerate(entry["params"]):
            want = _shape_str("f32", p["shape"])
            assert layout[i].startswith(want), (key, p["name"], layout[i])
        assert layout[n].startswith(_shape_str("f32", entry["inputs"]["x"]))
        assert layout[n + 1].startswith(
            _shape_str("s32", entry["inputs"]["y"]))


def test_hlo_has_no_mosaic_custom_calls():
    """interpret=True must be used everywhere: a Mosaic custom-call would be
    unexecutable on the CPU PJRT client."""
    man = _manifest()
    for key, entry in man["models"].items():
        for kind, fname in entry["artifacts"].items():
            with open(os.path.join(ART, fname)) as f:
                text = f.read()
            assert "tpu_custom_call" not in text, (key, kind)
            assert "mosaic" not in text.lower(), (key, kind)


def test_table1_batch_sizes_present_unless_quick():
    """Table I needs lstm batch {10,100,500,1000}; tolerate --quick builds
    but require at least {10,100}."""
    man = _manifest()
    lstm_batches = sorted(
        e["batch"] for e in man["models"].values() if e["model"] == "lstm")
    assert 10 in lstm_batches and 100 in lstm_batches
