"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes; every check covers BOTH the forward value and the
custom-VJP gradients (compared against jax.grad through the jnp oracle).
This is the core correctness signal for the compute stack: everything the
Rust runtime executes lowers through these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.kernels import dense, lstm_cell, softmax_xent  # noqa: E402
from compile.kernels import ref  # noqa: E402

SETTLE = dict(deadline=None, max_examples=12)


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape)


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------

@settings(**SETTLE)
@given(
    b=st.integers(1, 200),
    i=st.integers(1, 64),
    o=st.integers(1, 48),
    seed=st.integers(0, 2**16),
)
def test_dense_forward_matches_ref(b, i, o, seed):
    x = _rand(seed, (b, i))
    w = _rand(seed + 1, (i, o), 0.5)
    bias = _rand(seed + 2, (o,), 0.1)
    np.testing.assert_allclose(
        dense(x, w, bias), ref.dense_ref(x, w, bias), rtol=2e-5, atol=1e-5)


@settings(**SETTLE)
@given(
    b=st.integers(1, 160),
    i=st.integers(1, 32),
    o=st.integers(1, 16),
    seed=st.integers(0, 2**16),
)
def test_dense_grads_match_ref(b, i, o, seed):
    x = _rand(seed, (b, i))
    w = _rand(seed + 1, (i, o), 0.5)
    bias = _rand(seed + 2, (o,), 0.1)

    def f_k(x, w, bias):
        return jnp.sum(jnp.sin(dense(x, w, bias)))

    def f_r(x, w, bias):
        return jnp.sum(jnp.sin(ref.dense_ref(x, w, bias)))

    gk = jax.grad(f_k, argnums=(0, 1, 2))(x, w, bias)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(x, w, bias)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, rtol=2e-4, atol=1e-5)


def test_dense_batch_tiling_boundary():
    """Batches straddling BATCH_TILE must agree with the oracle."""
    for b in (127, 128, 129, 256, 257):
        x = _rand(b, (b, 8))
        w = _rand(1, (8, 4), 0.5)
        bias = _rand(2, (4,), 0.1)
        np.testing.assert_allclose(
            dense(x, w, bias), ref.dense_ref(x, w, bias),
            rtol=2e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# lstm_cell
# ---------------------------------------------------------------------------

@settings(**SETTLE)
@given(
    b=st.integers(1, 150),
    f=st.integers(1, 32),
    h=st.integers(1, 24),
    seed=st.integers(0, 2**16),
)
def test_lstm_cell_forward_matches_ref(b, f, h, seed):
    x = _rand(seed, (b, f))
    h0 = _rand(seed + 1, (b, h), 0.5)
    c0 = _rand(seed + 2, (b, h), 0.5)
    wx = _rand(seed + 3, (f, 4 * h), 0.3)
    wh = _rand(seed + 4, (h, 4 * h), 0.3)
    bias = _rand(seed + 5, (4 * h,), 0.1)
    hn, cn = lstm_cell(x, h0, c0, wx, wh, bias)
    hr, cr = ref.lstm_cell_ref(x, h0, c0, wx, wh, bias)
    np.testing.assert_allclose(hn, hr, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(cn, cr, rtol=2e-5, atol=1e-6)


@settings(deadline=None, max_examples=8)
@given(
    b=st.integers(1, 64),
    f=st.integers(1, 16),
    h=st.integers(1, 20),
    seed=st.integers(0, 2**16),
)
def test_lstm_cell_grads_match_ref(b, f, h, seed):
    x = _rand(seed, (b, f))
    h0 = _rand(seed + 1, (b, h), 0.5)
    c0 = _rand(seed + 2, (b, h), 0.5)
    wx = _rand(seed + 3, (f, 4 * h), 0.3)
    wh = _rand(seed + 4, (h, 4 * h), 0.3)
    bias = _rand(seed + 5, (4 * h,), 0.1)

    def f_k(*a):
        hn, cn = lstm_cell(*a)
        return jnp.sum(hn * hn) + jnp.sum(jnp.cos(cn))

    def f_r(*a):
        hn, cn = ref.lstm_cell_ref(*a)
        return jnp.sum(hn * hn) + jnp.sum(jnp.cos(cn))

    gk = jax.grad(f_k, argnums=tuple(range(6)))(x, h0, c0, wx, wh, bias)
    gr = jax.grad(f_r, argnums=tuple(range(6)))(x, h0, c0, wx, wh, bias)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, rtol=5e-4, atol=2e-5)


def test_lstm_cell_forget_bias_saturation():
    """With large positive cell state + forget bias, c' ≈ c + i*g regime:
    the kernel must match the oracle even in saturated-gate regions."""
    b, f, h = 4, 3, 5
    x = 10.0 * jnp.ones((b, f))
    h0 = jnp.zeros((b, h))
    c0 = 100.0 * jnp.ones((b, h))
    wx = jnp.ones((f, 4 * h))
    wh = jnp.zeros((h, 4 * h))
    bias = jnp.zeros((4 * h,))
    hn, cn = lstm_cell(x, h0, c0, wx, wh, bias)
    hr, cr = ref.lstm_cell_ref(x, h0, c0, wx, wh, bias)
    np.testing.assert_allclose(hn, hr, rtol=1e-6)
    np.testing.assert_allclose(cn, cr, rtol=1e-6)


def test_lstm_cell_zero_state_is_stateless_gate():
    """h=c=0 ⇒ cell output depends only on x (regression guard for gate
    order: i,f,g,o)."""
    b, f, h = 2, 4, 3
    x = _rand(0, (b, f))
    hn, cn = lstm_cell(x, jnp.zeros((b, h)), jnp.zeros((b, h)),
                       _rand(1, (f, 4 * h), 0.3), jnp.zeros((h, 4 * h)),
                       jnp.zeros((4 * h,)))
    hr, cr = ref.lstm_cell_ref(x, jnp.zeros((b, h)), jnp.zeros((b, h)),
                               _rand(1, (f, 4 * h), 0.3),
                               jnp.zeros((h, 4 * h)), jnp.zeros((4 * h,)))
    np.testing.assert_allclose(hn, hr, rtol=1e-6)
    np.testing.assert_allclose(cn, cr, rtol=1e-6)


# ---------------------------------------------------------------------------
# softmax cross-entropy
# ---------------------------------------------------------------------------

@settings(**SETTLE)
@given(
    b=st.integers(1, 300),
    c=st.integers(2, 12),
    seed=st.integers(0, 2**16),
)
def test_xent_forward_matches_ref(b, c, seed):
    logits = _rand(seed, (b, c), 3.0)
    labels = jax.random.randint(jax.random.PRNGKey(seed + 1), (b,), 0, c)
    np.testing.assert_allclose(
        softmax_xent(logits, labels),
        ref.softmax_xent_ref(logits, labels), rtol=2e-5, atol=1e-6)


@settings(**SETTLE)
@given(
    b=st.integers(1, 128),
    c=st.integers(2, 8),
    seed=st.integers(0, 2**16),
)
def test_xent_grad_matches_ref(b, c, seed):
    logits = _rand(seed, (b, c), 3.0)
    labels = jax.random.randint(jax.random.PRNGKey(seed + 1), (b,), 0, c)
    gk = jax.grad(lambda l: softmax_xent(l, labels))(logits)
    gr = jax.grad(lambda l: ref.softmax_xent_ref(l, labels))(logits)
    np.testing.assert_allclose(gk, gr, rtol=2e-4, atol=1e-7)


def test_xent_extreme_logits_stable():
    """Max-subtraction must keep the kernel finite for huge logits."""
    logits = jnp.array([[1e4, -1e4, 0.0], [5e3, 5e3, 5e3]], jnp.float32)
    labels = jnp.array([0, 1], jnp.int32)
    loss = softmax_xent(logits, labels)
    assert np.isfinite(float(loss))
    np.testing.assert_allclose(
        loss, ref.softmax_xent_ref(logits, labels), rtol=1e-5)


def test_xent_grad_sums_to_zero_per_row():
    """Softmax-xent gradient rows sum to 0 (probability simplex invariant)."""
    logits = _rand(7, (32, 5), 2.0)
    labels = jax.random.randint(jax.random.PRNGKey(3), (32,), 0, 5)
    g = jax.grad(lambda l: softmax_xent(l, labels))(logits)
    np.testing.assert_allclose(jnp.sum(g, axis=-1), jnp.zeros(32), atol=1e-7)


def test_xent_perfect_prediction_low_loss():
    logits = 20.0 * jax.nn.one_hot(jnp.array([0, 1, 2]), 3)
    labels = jnp.array([0, 1, 2], jnp.int32)
    assert float(softmax_xent(logits, labels)) < 1e-3
