"""L2 correctness: model zoo — shapes, grads, train-ability, AOT entry points."""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile import model as M  # noqa: E402
from compile.kernels import ref  # noqa: E402

CFGS = [M.PAPER_LSTM, M.QUICKSTART_MLP, M.TRANSFORMER]


def _batch(cfg, b=8, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (b, cfg.seq_len, cfg.features))
    y = jax.random.randint(ky, (b,), 0, cfg.classes)
    return x, y


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_apply_shapes(cfg):
    params = M.init_params(cfg)
    x, _ = _batch(cfg)
    logits = M.MODELS[cfg.name][1](cfg, params, x)
    assert logits.shape == (8, cfg.classes)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_param_names_sorted_and_stable(cfg):
    names = M.param_names(cfg)
    assert names == sorted(names)
    assert names == M.param_names(cfg)  # deterministic


def test_paper_lstm_param_count():
    """Paper model: LSTM(20) over 16 features + softmax(3).
    4H(F+H+1) + H*C + C = 80*37 + 63 = 3023."""
    params = M.init_params(M.PAPER_LSTM)
    n = sum(int(np.prod(p.shape)) for p in params.values())
    assert n == 4 * 20 * (16 + 20 + 1) + 20 * 3 + 3


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_grad_fn_positional_interface(cfg):
    """AOT grad entry point: (*params, x, y) -> (loss, *grads), sorted order."""
    names = M.param_names(cfg)
    params = M.init_params(cfg)
    x, y = _batch(cfg)
    out = M.make_grad_fn(cfg)(*[params[n] for n in names], x, y)
    assert len(out) == 1 + len(names)
    loss = out[0]
    assert loss.shape == () and np.isfinite(float(loss))
    for n, g in zip(names, out[1:]):
        assert g.shape == params[n].shape, n
        assert np.all(np.isfinite(np.asarray(g))), n


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_eval_fn_counts_correct(cfg):
    names = M.param_names(cfg)
    params = M.init_params(cfg)
    x, y = _batch(cfg, b=16)
    loss, ncorrect = M.make_eval_fn(cfg)(*[params[n] for n in names], x, y)
    assert 0.0 <= float(ncorrect) <= 16.0
    # cross-check against explicit argmax
    logits = M.MODELS[cfg.name][1](cfg, params, x)
    expected = int(np.sum(np.argmax(np.asarray(logits), -1) == np.asarray(y)))
    assert int(ncorrect) == expected


def test_lstm_grad_matches_pure_jnp_model():
    """End-to-end L2 check: full scanned LSTM grads vs an all-jnp clone."""
    cfg = M.ModelConfig(name="lstm", seq_len=5, features=4, hidden=6,
                        classes=3)
    params = M.init_params(cfg, seed=1)
    x, y = _batch(cfg, b=9, seed=2)

    def jnp_model_loss(params):
        h = jnp.zeros((9, cfg.hidden))
        c = jnp.zeros((9, cfg.hidden))
        for t in range(cfg.seq_len):
            h, c = ref.lstm_cell_ref(x[:, t], h, c, params["lstm_wx"],
                                     params["lstm_wh"], params["lstm_b"])
        logits = ref.dense_ref(h, params["out_w"], params["out_b"])
        return ref.softmax_xent_ref(logits, y)

    def kernel_model_loss(params):
        loss, _ = M.loss_and_logits(cfg, params, x, y)
        return loss

    lk, gk = jax.value_and_grad(kernel_model_loss)(params)
    lr, gr = jax.value_and_grad(jnp_model_loss)(params)
    np.testing.assert_allclose(lk, lr, rtol=1e-5)
    for n in params:
        np.testing.assert_allclose(gk[n], gr[n], rtol=1e-3, atol=1e-6,
                                   err_msg=n)


@pytest.mark.parametrize("cfg", [M.PAPER_LSTM, M.QUICKSTART_MLP],
                         ids=lambda c: c.name)
def test_sgd_steps_reduce_loss(cfg):
    """A few SGD steps on a fixed batch must reduce the loss (train-ability)."""
    names = M.param_names(cfg)
    params = M.init_params(cfg)
    x, y = _batch(cfg, b=32, seed=3)
    grad_fn = jax.jit(M.make_grad_fn(cfg))
    leaves = [params[n] for n in names]
    out0 = grad_fn(*leaves, x, y)
    loss0 = float(out0[0])
    for _ in range(20):
        out = grad_fn(*leaves, x, y)
        leaves = [p - 0.2 * g for p, g in zip(leaves, out[1:])]
    lossn = float(grad_fn(*leaves, x, y)[0])
    assert lossn < loss0, (loss0, lossn)


def test_transformer_permutation_sensitivity():
    """Positional embeddings make the transformer order-sensitive."""
    cfg = M.TRANSFORMER
    params = M.init_params(cfg)
    x, _ = _batch(cfg, b=2)
    logits1 = M.MODELS[cfg.name][1](cfg, params, x)
    logits2 = M.MODELS[cfg.name][1](cfg, params, x[:, ::-1, :])
    assert not np.allclose(np.asarray(logits1), np.asarray(logits2))
