//! # mpi-learn
//!
//! A Rust + JAX + Pallas reproduction of *"An MPI-Based Python Framework
//! for Distributed Training with Keras"* (Anderson, Vlimant, Spiropulu —
//! Caltech, 2017; the `mpi_learn` package).
//!
//! The paper's contribution is a lightweight coordination layer that
//! distributes Keras model training over MPI ranks with Downpour SGD
//! (async gradients to a master that owns the weights) or Elastic
//! Averaging SGD. This crate reproduces that layer in Rust, with the model
//! compute (the paper's Keras/cuDNN layer) AOT-compiled from JAX + Pallas
//! kernels into HLO artifacts executed through PJRT — Python never runs at
//! training time. Offline builds (the default) execute the same model
//! math through the built-in native CPU backend instead, so a fresh
//! checkout trains with zero setup; the `pjrt` cargo feature restores
//! the artifact path.
//!
//! # Front door
//!
//! The documented user API is the fluent [`coordinator::Experiment`]
//! facade — model, data, training procedure, and Keras-style callbacks
//! in one chain:
//!
//! ```no_run
//! use mpi_learn::coordinator::Experiment;
//! let session = mpi_learn::runtime::Session::open_default()?;
//! let result = Experiment::new("lstm")
//!     .batch(100)
//!     .workers(8)
//!     .allreduce()
//!     .early_stopping(3)
//!     .checkpoint("runs/ckpt")
//!     .run(&session)?;
//! # Ok::<(), Box<dyn std::error::Error>>(())
//! ```
//!
//! Internally a [`coordinator::WorldPlan`] maps the configuration to
//! per-rank roles, and one `run_role` path executes them — identically
//! for in-process thread worlds (`train`) and `mpirun`-style TCP
//! deployments (`run_rank`). Training conveniences (checkpointing,
//! early stopping, LR schedules, metric streaming) are
//! [`coordinator::Callback`]s observed by the master / ring rank 0.
//!
//! # Training modes
//!
//! - **Downpour SGD** (`Mode::Downpour`, paper default): workers stream
//!   gradients to a master that owns the weights; async one-by-one or
//!   behind a synchronous barrier. Scales until the master's per-gradient
//!   service time saturates (the paper's Figs 3/4 knee, ~30x at 60
//!   workers).
//! - **EASGD** (`Mode::Easgd`): workers train locally and exchange
//!   elastically with the master's center variable every `tau` batches.
//! - **Ring all-reduce** (`Mode::AllReduce`, flag `--mode allreduce`):
//!   masterless synchronous data-parallel. Every rank computes a
//!   gradient; the world averages them with a chunked ring all-reduce
//!   ([`mpi::collective`]) costing `2(n-1)/n` payload volumes per rank;
//!   each rank applies an identical replicated optimizer step, so all
//!   ranks hold bitwise-identical weights at every round. Prefer it over
//!   Downpour/EASGD when worker count (or gradient size) is large enough
//!   to saturate a master — there is no per-gradient serial bottleneck,
//!   at the price of per-round latency `2(n-1)·lat` and lockstep
//!   synchronicity (no stale-gradient tolerance). `mpi-learn simulate
//!   --algo allreduce` projects the crossover for a given cost model.
//! - **Hierarchical all-reduce** (`Mode::AllReduce` + a hierarchy
//!   spec; flags `--mode allreduce --hierarchy --groups G`, or
//!   `Experiment::allreduce_grouped`): the masterless world splits
//!   into `G` intra-group rings joined by an inter-group binary
//!   leader tree (`mpi::collective::GroupLayout`), collapsing the
//!   flat ring's `2(n-1)` latency term to `2(m-1) + O(log G)` —
//!   cheap node-local hops plus a logarithmic number of network hops
//!   (HyPar-Flow's topology argument). The bitwise-identical-weights
//!   guarantee is unchanged, raw or compressed (DESIGN.md §Topology);
//!   `mpi-learn simulate --algo hier-allreduce` prices it.
//! - **Bucketed overlapped all-reduce** (`Algo::buckets`; flags
//!   `--mode allreduce --buckets`, or [`coordinator::Experiment`]'s
//!   `buckets()`): the native backend's layer DAG
//!   ([`runtime::GradSink`]) launches one windowed collective per
//!   layer bucket *while backprop continues*, overlapping the wire
//!   with compute — identical results (fp32/fp16 bitwise-equal to the
//!   monolithic path), composing with compression and the
//!   hierarchical topology (DESIGN.md §Layer DAG & bucketed overlap).
//!
//! All modes accept wire-level **gradient compression**
//! ([`mpi::codec`], flag `--compression fp16|topk:<k>`): fp16
//! quantization or magnitude top-k sparsification with an
//! error-feedback residual, cutting bytes on the wire without
//! breaking the all-reduce mode's bitwise-identical-weights guarantee
//! (DESIGN.md §Gradient compression).
//!
//! Architecture (DESIGN.md has the full inventory):
//! - [`mpi`] — MPI-style tagged point-to-point substrate
//!   (threads+channels or TCP mesh) plus the [`mpi::collective`] layer
//!   (ring all-reduce/broadcast, tree reduce/broadcast, hierarchical
//!   all-reduce) and the [`mpi::codec`] wire codecs built on it.
//! - [`runtime`] — artifact manifest + execution backends (native CPU
//!   engine by default, structured as an explicit layer DAG; PJRT
//!   behind the `pjrt` feature).
//! - [`data`] — shard file format, synthetic HEP dataset, batching loader,
//!   even file division.
//! - [`optim`] — master-side optimizers (momentum is the paper's
//!   stale-gradient mitigation); replicated per-rank in all-reduce mode.
//! - [`coordinator`] — the paper's system: the `Experiment` facade,
//!   `WorldPlan` topology, the `Callback` layer, master/worker
//!   processes, Downpour + EASGD + masterless all-reduce, sync/async,
//!   hierarchical masters, validation.
//! - [`simulator`] — discrete-event protocol simulator for cluster-scale
//!   sweeps (Figs 3/4, Table I) with parameter-server, flat-ring, and
//!   hierarchical cost models (separate intra/inter link terms).
//! - [`serving`] — HTTP inference front-end (`serve` subcommand):
//!   request micro-batching into the native backend, optional
//!   rank-sharded replicas over the [`mpi`] substrate, and hot
//!   checkpoint reload that atomically swaps weights published by a
//!   concurrent training run without dropping in-flight requests.
//! - [`tensor`], [`metrics`], [`util`] — support substrates.

pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod mpi;
pub mod optim;
pub mod runtime;
pub mod serving;
pub mod simulator;
pub mod tensor;
pub mod util;
