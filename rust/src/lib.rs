//! # mpi-learn
//!
//! A Rust + JAX + Pallas reproduction of *"An MPI-Based Python Framework
//! for Distributed Training with Keras"* (Anderson, Vlimant, Spiropulu —
//! Caltech, 2017; the `mpi_learn` package).
//!
//! The paper's contribution is a lightweight coordination layer that
//! distributes Keras model training over MPI ranks with Downpour SGD
//! (async gradients to a master that owns the weights) or Elastic
//! Averaging SGD. This crate reproduces that layer in Rust, with the model
//! compute (the paper's Keras/cuDNN layer) AOT-compiled from JAX + Pallas
//! kernels into HLO artifacts executed through PJRT — Python never runs at
//! training time.
//!
//! Architecture (DESIGN.md has the full inventory):
//! - [`mpi`] — MPI-style tagged point-to-point substrate (threads+channels
//!   or TCP mesh).
//! - [`runtime`] — PJRT client, artifact manifest, compiled executables.
//! - [`data`] — shard file format, synthetic HEP dataset, batching loader,
//!   even file division.
//! - [`optim`] — master-side optimizers (momentum is the paper's
//!   stale-gradient mitigation).
//! - [`coordinator`] — the paper's system: master/worker processes,
//!   Downpour + EASGD, sync/async, hierarchical masters, validation.
//! - [`simulator`] — discrete-event protocol simulator for cluster-scale
//!   sweeps (Figs 3/4, Table I).
//! - [`tensor`], [`metrics`], [`util`] — support substrates.

pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod mpi;
pub mod optim;
pub mod runtime;
pub mod simulator;
pub mod tensor;
pub mod util;
