//! Tiny CLI argument substrate (offline environment: no clap).
//!
//! Grammar: `binary [subcommand] [--key value | --flag] [positional...]`.
//! Typed getters with defaults; unknown-flag detection so typos fail loudly.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

#[derive(Debug)]
pub enum CliError {
    MissingValue(String),
    BadValue(String, String, &'static str),
    /// Unconsumed flags, each with the nearest known flag (edit
    /// distance <= 2), if any.
    UnknownFlags(Vec<(String, Option<String>)>),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingValue(flag) => {
                write!(f, "flag --{flag} expects a value")
            }
            CliError::BadValue(flag, value, ty) => {
                write!(f, "flag --{flag}: cannot parse '{value}' as {ty}")
            }
            CliError::UnknownFlags(flags) => {
                for (i, (flag, suggestion)) in flags.iter().enumerate() {
                    if i > 0 {
                        write!(f, "; ")?;
                    }
                    write!(f, "unknown flag --{flag}")?;
                    if let Some(s) = suggestion {
                        write!(f, " (did you mean --{s}?)")?;
                    }
                }
                Ok(())
            }
        }
    }
}

/// Classic dynamic-programming edit distance (typo suggestions).
fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse `std::env::args()` minus the binary name. Drops the bare
    /// `--bench` flag that `cargo bench` appends for libtest harnesses.
    pub fn from_env() -> Self {
        Self::parse(
            std::env::args().skip(1).filter(|a| a != "--bench").collect())
    }

    pub fn parse(raw: Vec<String>) -> Self {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        // first non-flag token is the subcommand
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                let is_flag_next = it
                    .peek()
                    .map(|n| n.starts_with("--"))
                    .unwrap_or(true);
                if is_flag_next {
                    // boolean flag
                    args.flags.insert(name.to_string(), "true".to_string());
                } else {
                    args.flags.insert(name.to_string(), it.next().unwrap());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    fn mark(&self, name: &str) {
        self.consumed.borrow_mut().insert(name.to_string());
    }

    pub fn str_opt(&self, name: &str) -> Option<String> {
        self.mark(name);
        self.flags.get(name).cloned()
    }

    pub fn str(&self, name: &str, default: &str) -> String {
        self.str_opt(name).unwrap_or_else(|| default.to_string())
    }

    pub fn usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(
                |_| CliError::BadValue(name.into(), v, "usize")),
        }
    }

    pub fn u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(
                |_| CliError::BadValue(name.into(), v, "u64")),
        }
    }

    pub fn f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(
                |_| CliError::BadValue(name.into(), v, "f64")),
        }
    }

    pub fn bool(&self, name: &str) -> bool {
        self.str_opt(name).map(|v| v != "false").unwrap_or(false)
    }

    /// Comma-separated list, e.g. `--workers 1,2,4,8`.
    pub fn usize_list(&self, name: &str, default: &[usize])
        -> Result<Vec<usize>, CliError> {
        match self.str_opt(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|tok| tok.trim().parse().map_err(
                    |_| CliError::BadValue(name.into(), tok.into(), "usize")))
                .collect(),
        }
    }

    /// Call after all getters: errors if any flag was never consumed,
    /// suggesting the nearest known (consumed) flag for each typo.
    pub fn finish(&self) -> Result<(), CliError> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<(String, Option<String>)> = self
            .flags
            .keys()
            .filter(|k| !consumed.contains(*k))
            .map(|k| {
                let suggestion = consumed
                    .iter()
                    .map(|known| (levenshtein(k, known), known))
                    .min()
                    .filter(|(d, _)| *d <= 2)
                    .map(|(_, known)| known.clone());
                (k.clone(), suggestion)
            })
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(CliError::UnknownFlags(unknown))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()).collect())
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["train", "--workers", "4", "--sync", "--lr", "0.01"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.usize("workers", 1).unwrap(), 4);
        assert!(a.bool("sync"));
        assert!((a.f64("lr", 0.0).unwrap() - 0.01).abs() < 1e-12);
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["train"]);
        assert_eq!(a.usize("workers", 3).unwrap(), 3);
        assert!(!a.bool("sync"));
    }

    #[test]
    fn unknown_flag_detected() {
        let a = parse(&["train", "--worker", "4"]);
        let _ = a.usize("workers", 1);
        assert!(a.finish().is_err());
    }

    /// Satellite (ISSUE 2): a typo'd flag suggests the nearest known
    /// flag in the error message.
    #[test]
    fn typo_suggests_nearest_flag() {
        let a = parse(&["train", "--worekrs", "4"]);
        let _ = a.usize("workers", 1);
        let _ = a.usize("epochs", 1);
        let err = a.finish().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("--worekrs"), "{msg}");
        assert!(msg.contains("did you mean --workers?"), "{msg}");
        // a flag nothing resembles gets no suggestion
        let a = parse(&["train", "--zzqqxy", "4"]);
        let _ = a.usize("workers", 1);
        let msg = a.finish().unwrap_err().to_string();
        assert!(!msg.contains("did you mean"), "{msg}");
    }

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein("", ""), 0);
        assert_eq!(levenshtein("abc", "abc"), 0);
        assert_eq!(levenshtein("abc", ""), 3);
        assert_eq!(levenshtein("kitten", "sitting"), 3);
        assert_eq!(levenshtein("worker", "workers"), 1);
    }

    #[test]
    fn list_flag() {
        let a = parse(&["bench", "--counts", "1,2,4, 8"]);
        assert_eq!(a.usize_list("counts", &[]).unwrap(), vec![1, 2, 4, 8]);
    }

    #[test]
    fn bad_value_errors() {
        let a = parse(&["train", "--workers", "four"]);
        assert!(a.usize("workers", 1).is_err());
    }

    #[test]
    fn trailing_bool_flag() {
        let a = parse(&["train", "--verbose"]);
        assert!(a.bool("verbose"));
    }
}
