//! Criterion-style measurement harness (offline environment: no criterion).
//!
//! Used by every file in `rust/benches/`: warmup, timed iterations,
//! mean/p50/p95 reporting, and a tabular writer so each bench prints the
//! same rows/series as the paper's corresponding table or figure.

use std::time::Instant;

use crate::util::stats;

/// Result of one measured case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub std_s: f64,
}

impl Measurement {
    pub fn per_sec(&self) -> f64 {
        if self.mean_s > 0.0 {
            1.0 / self.mean_s
        } else {
            f64::INFINITY
        }
    }
}

/// Measure `f` after `warmup` calls, timing `iters` calls individually.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                           mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Measurement {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&samples),
        p50_s: stats::percentile(&samples, 50.0),
        p95_s: stats::percentile(&samples, 95.0),
        std_s: stats::std_dev(&samples),
    }
}

/// Time a single long-running closure (end-to-end training runs).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Pretty table printer: pass header + rows; pads columns.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let head: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    println!("{}", fmt_row(&head));
    println!("{}", widths.iter().map(|w| "-".repeat(*w))
        .collect::<Vec<_>>().join("  "));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Write rows as CSV next to the bench output (for plotting).
pub fn write_csv(path: &str, header: &[&str], rows: &[Vec<String>])
    -> std::io::Result<()> {
    use std::io::Write;
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Write a JSON document next to the bench output (machine-readable
/// summaries for the CI bench-smoke gate; see .github/workflows).
pub fn write_json(path: &str, value: &crate::util::json::Json)
    -> std::io::Result<()> {
    use std::io::Write;
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", value.to_string_pretty())
}

/// Format seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iters() {
        let mut n = 0usize;
        let m = measure("noop", 2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(m.iters, 10);
        assert!(m.mean_s >= 0.0);
        assert!(m.p95_s >= m.p50_s);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(2.0e-9).ends_with("ns"));
        assert!(fmt_secs(2.0e-5).ends_with("µs"));
        assert!(fmt_secs(2.0e-2).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
    }

    #[test]
    fn csv_writer_works() {
        let path = std::env::temp_dir().join("mpi_learn_bench_test.csv");
        write_csv(path.to_str().unwrap(), &["a", "b"],
                  &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }
}
