//! Support substrates built from scratch for the offline environment:
//! PRNG, JSON, CLI parsing, logging, statistics, a bench harness, and a
//! property-test driver. Everything above this module depends only on
//! `std`, the `xla` crate, and these utilities.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
