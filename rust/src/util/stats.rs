//! Small statistics substrate for benches and the simulator.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Linear-interpolated percentile, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Least-squares slope+intercept of y over x — used to check the "linear
/// speedup regime" claims in Figs 3/4.
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    let _n = x.len() as f64;
    let mx = mean(x);
    let my = mean(y);
    let mut num = 0.0;
    let mut den = 0.0;
    for (xi, yi) in x.iter().zip(y) {
        num += (xi - mx) * (yi - my);
        den += (xi - mx) * (xi - mx);
    }
    let slope = if den == 0.0 { 0.0 } else { num / den };
    (slope, my - slope * mx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn fit_recovers_line() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v + 1.0).collect();
        let (slope, icept) = linear_fit(&x, &y);
        assert!((slope - 3.0).abs() < 1e-9);
        assert!((icept - 1.0).abs() < 1e-9);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }
}
