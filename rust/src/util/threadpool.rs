//! Dependency-free persistent compute pool for intra-rank parallelism.
//!
//! The native backend's GEMMs, the optimizer step loops, and the fp16
//! wire codec are all embarrassingly parallel across disjoint index
//! ranges — but spawning OS threads per call would cost more than the
//! loops themselves, and a work-stealing runtime would make the
//! partition (and therefore the floating-point story) depend on timing.
//! This pool does the minimum that preserves determinism:
//!
//! * **Spawn once, reuse forever.** [`ThreadPool::new`] spawns
//!   `threads - 1` helper threads that park on a condvar; the caller of
//!   [`ThreadPool::run`] is always participant 0, so a 1-thread pool
//!   has no helpers and runs every part inline — byte-for-byte the
//!   pre-pool code path.
//! * **Static partitioning.** Work is split into `parts` blocks
//!   *before* execution ([`block_range`]); threads claim whole blocks
//!   from an atomic counter. Which thread runs a block can vary with
//!   timing, but the block boundaries — and therefore every
//!   floating-point accumulation order inside a block — cannot.
//! * **Scoped joins.** `run` does not return until every part has
//!   finished, so the closure may safely borrow the caller's stack
//!   (internally the borrow is lifetime-erased for the helpers; the
//!   join is what makes that sound).
//!
//! One `run` executes at a time per pool (a submit mutex serializes
//! concurrent callers — e.g. several in-process ranks sharing one
//! `ModelExecutables`), which also keeps the helper protocol trivial.
//!
//! Sizing comes from `--threads` / JSON `"threads"` /
//! `Experiment::threads()`; `0` means [`ThreadPool::auto_threads`]
//! (`std::thread::available_parallelism`). See DESIGN.md §Compute
//! kernels for how the kernels keep results bitwise-identical at any
//! thread count.

use std::marker::PhantomData;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Persistent pool of compute threads with scoped, statically
/// partitioned parallel loops. See the module docs for the guarantees.
pub struct ThreadPool {
    shared: Arc<Shared>,
    /// Serializes concurrent `run` callers (one job in flight).
    submit: Mutex<()>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Helpers park here waiting for a job epoch they have not seen.
    work: Condvar,
    /// The submitter parks here waiting for the last part to finish.
    done: Condvar,
}

struct PoolState {
    job: Option<Arc<Job>>,
    /// Bumped once per submitted job so helpers never re-run one.
    epoch: u64,
    shutdown: bool,
}

/// One submitted parallel loop: a lifetime-erased task plus the claim
/// and completion counters.
struct Job {
    task: RawTask,
    parts: usize,
    next: AtomicUsize,
    finished: AtomicUsize,
}

/// Lifetime-erased `&(dyn Fn(usize) + Sync)`. Sound because the
/// submitter blocks in [`ThreadPool::run`] until `finished == parts`,
/// i.e. until no helper can ever dereference this again.
struct RawTask(*const (dyn Fn(usize) + Sync));

unsafe impl Send for RawTask {}
unsafe impl Sync for RawTask {}

impl RawTask {
    fn call(&self, part: usize) {
        unsafe { (*self.0)(part) }
    }
}

/// The `idx`-th of `parts` contiguous blocks covering `0..total`, with
/// the remainder spread one element each over the leading blocks. The
/// deterministic partition every pooled loop uses.
pub fn block_range(total: usize, parts: usize, idx: usize) -> Range<usize> {
    debug_assert!(idx < parts);
    let base = total / parts;
    let rem = total % parts;
    let start = idx * base + idx.min(rem);
    let len = base + usize::from(idx < rem);
    start..start + len
}

impl ThreadPool {
    /// Build a pool of `threads` participants (`0` =>
    /// [`ThreadPool::auto_threads`]). Spawns `threads - 1` helper OS
    /// threads; a 1-thread pool spawns nothing and `run` degenerates
    /// to an inline loop.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = if threads == 0 {
            Self::auto_threads()
        } else {
            threads
        };
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                job: None,
                epoch: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("mpl-compute-{i}"))
                    .spawn(move || helper_loop(&shared))
                    .expect("spawn compute helper")
            })
            .collect();
        ThreadPool {
            shared,
            submit: Mutex::new(()),
            handles,
            threads,
        }
    }

    /// What `threads = 0` resolves to: the host's available
    /// parallelism (1 if the host will not say).
    pub fn auto_threads() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }

    /// Number of participants (helpers + the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0)..f(parts - 1)`, each part exactly once, returning
    /// only when all parts have finished (scoped join). The caller
    /// participates, so a helper-less pool runs everything inline, in
    /// part order. Parts are claimed whole from an atomic counter —
    /// the partition is static, only the part→thread assignment is
    /// timing-dependent.
    pub fn run(&self, parts: usize, f: impl Fn(usize) + Sync) {
        if parts == 0 {
            return;
        }
        if self.handles.is_empty() || parts == 1 {
            for i in 0..parts {
                f(i);
            }
            return;
        }
        let _guard = self.submit.lock().unwrap();
        let task: &(dyn Fn(usize) + Sync) = &f;
        #[allow(clippy::missing_transmute_annotations)]
        let job = Arc::new(Job {
            // Erase the stack lifetime; the join below re-establishes it.
            task: RawTask(unsafe {
                std::mem::transmute::<
                    &(dyn Fn(usize) + Sync),
                    &'static (dyn Fn(usize) + Sync),
                >(task)
            }),
            parts,
            next: AtomicUsize::new(0),
            finished: AtomicUsize::new(0),
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            st.job = Some(Arc::clone(&job));
            st.epoch += 1;
            self.shared.work.notify_all();
        }
        // Participate: claim blocks like any helper.
        loop {
            let i = job.next.fetch_add(1, Ordering::Relaxed);
            if i >= parts {
                break;
            }
            f(i);
            job.finished.fetch_add(1, Ordering::Release);
        }
        // Scoped join: `f` (and everything it borrows) stays alive
        // until the last helper bumps `finished` to `parts`.
        let mut st = self.shared.state.lock().unwrap();
        while job.finished.load(Ordering::Acquire) < parts {
            st = self.shared.done.wait(st).unwrap();
        }
        st.job = None;
    }

    /// Parallel loop over `0..total` in contiguous blocks of at least
    /// `min_per_part` elements (fewer parts when the work is small, so
    /// tiny loops stay inline and fast). `f` receives each block's
    /// index range; ranges are disjoint and cover `0..total`.
    pub fn run_blocks(
        &self,
        total: usize,
        min_per_part: usize,
        f: impl Fn(Range<usize>) + Sync,
    ) {
        if total == 0 {
            return;
        }
        let by_work = if min_per_part == 0 {
            self.threads
        } else {
            total.div_ceil(min_per_part)
        };
        let parts = self.threads.min(by_work).max(1);
        self.run(parts, |i| f(block_range(total, parts, i)));
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn helper_loop(shared: &Shared) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    // The job may already be complete and cleared (we
                    // slept through it); just record the epoch and wait
                    // for the next one.
                    if let Some(j) = st.job.as_ref() {
                        break Arc::clone(j);
                    }
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        loop {
            let i = job.next.fetch_add(1, Ordering::Relaxed);
            if i >= job.parts {
                break;
            }
            job.task.call(i);
            job.finished.fetch_add(1, Ordering::Release);
        }
        // Wake the submitter under the lock so its recheck cannot miss
        // the final increment.
        let _st = shared.state.lock().unwrap();
        shared.done.notify_one();
    }
}

/// A `&mut [T]` that several pool parts may slice **disjointly**. The
/// pooled kernels partition output buffers into non-overlapping ranges
/// (one per part) before running; this wrapper carries the base
/// pointer across the `Sync` closure boundary so each part can
/// reborrow its own range.
pub struct SharedMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Sync for SharedMut<'_, T> {}
unsafe impl<T: Send> Send for SharedMut<'_, T> {}

impl<'a, T> SharedMut<'a, T> {
    /// Wrap a mutable slice for disjoint parallel sub-slicing.
    pub fn new(slice: &'a mut [T]) -> SharedMut<'a, T> {
        SharedMut {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: PhantomData,
        }
    }

    /// Reborrow `range` of the underlying slice.
    ///
    /// # Safety
    /// Callers must guarantee that concurrently live ranges are
    /// disjoint (the pooled loops guarantee it by construction:
    /// [`block_range`] partitions are non-overlapping).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range(&self, range: Range<usize>) -> &mut [T] {
        debug_assert!(range.start <= range.end && range.end <= self.len);
        std::slice::from_raw_parts_mut(
            self.ptr.add(range.start),
            range.end - range.start,
        )
    }

    /// Write one element. For loops whose per-part writes are
    /// element-disjoint but not range-contiguous (e.g. the LSTM gate
    /// buffer, indexed `row*4h + lane`).
    ///
    /// # Safety
    /// No two concurrently running parts may touch the same `idx`.
    pub unsafe fn write(&self, idx: usize, v: T) {
        debug_assert!(idx < self.len);
        self.ptr.add(idx).write(v);
    }

    /// Read one element (same disjointness contract as
    /// [`SharedMut::write`]: only the part that owns `idx` may access
    /// it).
    ///
    /// # Safety
    /// No concurrently running part may write `idx` while this reads.
    pub unsafe fn read(&self, idx: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(idx < self.len);
        *self.ptr.add(idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_range_partitions_exactly() {
        for total in [0usize, 1, 2, 7, 64, 1000, 1003] {
            for parts in 1..=9usize {
                let mut seen = 0usize;
                let mut expect_start = 0usize;
                for idx in 0..parts {
                    let r = block_range(total, parts, idx);
                    assert_eq!(r.start, expect_start,
                               "gap at {total}/{parts}/{idx}");
                    expect_start = r.end;
                    seen += r.len();
                }
                assert_eq!(expect_start, total);
                assert_eq!(seen, total);
            }
        }
    }

    #[test]
    fn run_executes_every_part_exactly_once() {
        for threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            for parts in [1usize, 2, 3, 7, 33] {
                let hits: Vec<AtomicUsize> =
                    (0..parts).map(|_| AtomicUsize::new(0)).collect();
                pool.run(parts, |i| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::Relaxed), 1,
                               "part {i} at {threads} threads");
                }
            }
        }
    }

    #[test]
    fn run_blocks_covers_the_range_disjointly() {
        let pool = ThreadPool::new(4);
        for total in [0usize, 1, 5, 4096, 10_000] {
            let marks: Vec<AtomicUsize> =
                (0..total).map(|_| AtomicUsize::new(0)).collect();
            pool.run_blocks(total, 64, |r| {
                for i in r {
                    marks[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(marks.iter()
                .all(|m| m.load(Ordering::Relaxed) == 1));
        }
    }

    #[test]
    fn pool_is_reusable_and_scoped() {
        // Many consecutive jobs borrowing different stack data: the
        // scoped join must make each borrow sound.
        let pool = ThreadPool::new(3);
        for round in 0..50usize {
            let input: Vec<usize> = (0..257).map(|i| i + round).collect();
            let mut out = vec![0usize; input.len()];
            let view = SharedMut::new(&mut out);
            pool.run_blocks(input.len(), 16, |r| {
                let o = unsafe { view.range(r.clone()) };
                for (dst, &src) in o.iter_mut().zip(&input[r]) {
                    *dst = src * 2;
                }
            });
            assert!(out.iter().zip(&input)
                .all(|(&o, &i)| o == i * 2), "round {round}");
        }
    }

    #[test]
    fn concurrent_submitters_serialize() {
        // Several threads sharing one pool (the in-process multi-rank
        // shape): the submit mutex must keep their jobs isolated.
        let pool = Arc::new(ThreadPool::new(4));
        let mut joins = Vec::new();
        for t in 0..4usize {
            let pool = Arc::clone(&pool);
            joins.push(std::thread::spawn(move || {
                for _ in 0..20 {
                    let total = 100 + t;
                    let sum = AtomicUsize::new(0);
                    pool.run_blocks(total, 8, |r| {
                        sum.fetch_add(r.len(), Ordering::Relaxed);
                    });
                    assert_eq!(sum.load(Ordering::Relaxed), total);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }
}
