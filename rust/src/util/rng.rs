//! Deterministic PRNG substrate (offline environment: no `rand` crate).
//!
//! SplitMix64 for seeding, xoshiro256++ as the main generator — the same
//! construction the `rand` ecosystem uses for fast non-crypto simulation.
//! Everything downstream (data generation, shuffling, weight init,
//! simulator jitter, property tests) draws from this, so runs are exactly
//! reproducible from a single `u64` seed.

/// SplitMix64 — used to expand a user seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — main generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller sample.
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = sm.next_u64();
        }
        // xoshiro must not start at the all-zero state.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s, gauss_spare: None }
    }

    /// Derive an independent stream (e.g. per worker rank).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.uniform_range(lo as f64, hi as f64) as f32
    }

    /// Unbiased integer in [0, n) (Lemire rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal (Box-Muller with caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u = self.uniform();
            let v = self.uniform();
            if u > f64::MIN_POSITIVE {
                let r = (-2.0 * u.ln()).sqrt();
                let theta = 2.0 * std::f64::consts::PI * v;
                self.gauss_spare = Some(r * theta.sin());
                return r * theta.cos();
            }
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an exponential with the given mean (simulator arrival jitter).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.uniform()).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small_range() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.usize_below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(123);
        let mut w0 = base.fork(0);
        let mut w1 = base.fork(1);
        let same = (0..64).filter(|_| w0.next_u64() == w1.next_u64()).count();
        assert!(same < 4);
    }
}
