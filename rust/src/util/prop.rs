//! Property-testing substrate (offline environment: no proptest).
//!
//! `check` runs a property over `n` random cases drawn from a
//! seed-deterministic RNG. On failure it retries from the same case seed to
//! confirm, then panics with the *case seed* so the exact failing input can
//! be replayed with `replay`. No shrinking — cases are generated small to
//! mid-sized by construction.

use crate::util::rng::Rng;

pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 100, seed: 0xC0FFEE }
    }
}

/// Run `prop` on `cfg.cases` random cases. `prop` gets a per-case RNG and
/// returns `Err(msg)` to signal a violation.
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut meta = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = meta.next_u64();
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} \
                 (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn replay<F>(seed: u64, mut prop: F) -> Result<(), String>
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    prop(&mut Rng::new(seed))
}

/// Helpers for common generators.
pub mod gen {
    use super::Rng;

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.usize_below(hi - lo + 1)
    }

    pub fn f32_vec(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.normal_f32(0.0, scale)).collect()
    }

    pub fn labels(rng: &mut Rng, len: usize, classes: usize) -> Vec<i32> {
        (0..len).map(|_| rng.usize_below(classes) as i32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("sum-commutes", PropConfig::default(), |rng| {
            let a = rng.uniform();
            let b = rng.uniform();
            if (a + b - (b + a)).abs() < 1e-15 {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check("always-fails", PropConfig { cases: 5, seed: 1 }, |_| {
            Err("nope".into())
        });
    }

    #[test]
    fn replay_reproduces_case() {
        let mut first: Option<f64> = None;
        replay(99, |rng| {
            first = Some(rng.uniform());
            Ok(())
        })
        .unwrap();
        let mut second: Option<f64> = None;
        replay(99, |rng| {
            second = Some(rng.uniform());
            Ok(())
        })
        .unwrap();
        assert_eq!(first, second);
    }
}
