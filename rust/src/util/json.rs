//! Minimal-but-complete JSON substrate (offline environment: no serde).
//!
//! Parses and serializes the JSON subset-of-nothing: the full grammar —
//! objects, arrays, strings with escapes (incl. \uXXXX surrogate pairs),
//! numbers, bools, null. Used for the artifact manifest (`meta.json`),
//! training configs, and metric dumps. Errors carry byte offsets.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------- accessors ----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required manifest fields.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            pos: 0,
            msg: format!("missing required key '{key}'"),
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---------------- constructors ----------------

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---------------- parsing ----------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---------------- serialization ----------------

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, item)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    item.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pair?
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(
                                || self.err("invalid \\u escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = &self.bytes[self.pos..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(
                        rest.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = chunk.chars().next().unwrap();
                    if (ch as u32) < 0x20 {
                        return Err(self.err("raw control char in string"));
                    }
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b < 0xE0 {
        2
    } else if b < 0xF0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#)
            .unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_i64(), Some(2));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "line\n\ttab \"quote\" back\\slash unicode \u{1F600} end";
        let j = Json::Str(s.to_string());
        let text = j.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn unicode_escape_surrogate_pair() {
        let j = Json::parse(r#""😀""#).unwrap();
        assert_eq!(j.as_str(), Some("\u{1F600}"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn pretty_roundtrip() {
        let j = Json::obj(vec![
            ("model", Json::str("lstm")),
            ("batch", Json::num(100.0)),
            ("shapes", Json::Arr(vec![Json::num(16.0), Json::num(80.0)])),
        ]);
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(Json::num(100.0).to_string_compact(), "100");
        assert_eq!(Json::num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn parses_own_manifest_style() {
        let text = r#"{"format_version": 1, "models": {"lstm_b100":
            {"batch": 100, "params": [{"name": "lstm_b", "shape": [80]}]}}}"#;
        let j = Json::parse(text).unwrap();
        let m = j.get("models").unwrap().get("lstm_b100").unwrap();
        assert_eq!(m.get("batch").unwrap().as_usize(), Some(100));
    }
}
