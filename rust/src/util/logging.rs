//! Leveled logger backing the `log` facade (offline: no env_logger).
//!
//! Level comes from `MPI_LEARN_LOG` (error|warn|info|debug|trace; default
//! info). Lines carry elapsed-seconds timestamps and the rank tag that the
//! coordinator threads set via [`set_rank_tag`] — so interleaved
//! master/worker logs read like an MPI job's output.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use log::{Level, LevelFilter, Log, Metadata, Record};

static INIT: AtomicBool = AtomicBool::new(false);

thread_local! {
    static RANK_TAG: std::cell::RefCell<String> =
        const { std::cell::RefCell::new(String::new()) };
}

/// Tag this thread's log lines (e.g. "master", "worker-3").
pub fn set_rank_tag(tag: &str) {
    RANK_TAG.with(|t| *t.borrow_mut() = tag.to_string());
}

struct Logger {
    start: Instant,
}

impl Log for Logger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let tag = RANK_TAG.with(|t| t.borrow().clone());
        let tag = if tag.is_empty() { String::new() } else {
            format!("[{tag}] ")
        };
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!(
            "[{:9.3}s] {lvl} {tag}{}",
            self.start.elapsed().as_secs_f64(),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger once; later calls are no-ops.
pub fn init() {
    if INIT.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = match std::env::var("MPI_LEARN_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    let logger = Box::leak(Box::new(Logger { start: Instant::now() }));
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        log::info!("logging smoke line");
    }

    #[test]
    fn rank_tag_is_thread_local() {
        init();
        set_rank_tag("worker-1");
        let handle = std::thread::spawn(|| {
            set_rank_tag("worker-2");
            RANK_TAG.with(|t| t.borrow().clone())
        });
        assert_eq!(handle.join().unwrap(), "worker-2");
        assert_eq!(RANK_TAG.with(|t| t.borrow().clone()), "worker-1");
    }
}
