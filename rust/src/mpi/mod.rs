//! MPI-style message-passing substrate (the paper's OpenMPI/mpi4py
//! substitute).
//!
//! `mpi_learn` drives training entirely with tagged point-to-point
//! messages between a master rank and worker ranks. This module provides
//! the same primitives over two transports:
//!
//! - [`transport::inproc`] — threads + channels, the paper's shared-memory
//!   single-node case;
//! - [`transport::tcp`] — localhost socket mesh with the same framing a
//!   multi-node deployment would use.
//!
//! See DESIGN.md §Substitutions for the fidelity argument.

pub mod codec;
pub mod collective;
pub mod comm;
pub mod message;
pub mod transport;

pub use codec::{Codec, Compressor, PackedF32};
pub use collective::{Collective, GroupLayout, ReduceOp};
pub use comm::{Comm, CommError};
pub use message::{Envelope, Payload, Rank, Tag, WorkerStats};

/// Build an in-process world of `n` ranks (rank 0 first).
pub fn inproc_world(n: usize) -> Vec<Comm> {
    transport::inproc::world(n)
}

/// Build a localhost TCP world of `n` ranks.
pub fn tcp_world(n: usize, base_port: u16)
    -> Result<Vec<Comm>, CommError> {
    transport::tcp::world(n, base_port)
}
