//! MPI-style message-passing substrate (the paper's OpenMPI/mpi4py
//! substitute).
//!
//! `mpi_learn` drives training entirely with tagged point-to-point
//! messages between a master rank and worker ranks. This module provides
//! the same primitives over two transports:
//!
//! - [`transport::inproc`] — threads + channels, the paper's shared-memory
//!   single-node case;
//! - [`transport::tcp`] — localhost socket mesh with the same framing a
//!   multi-node deployment would use.
//!
//! See DESIGN.md §Substitutions for the fidelity argument.

pub mod codec;
pub mod collective;
pub mod comm;
pub mod message;
pub mod transport;

pub use codec::{Codec, Compressor, PackedF32};
pub use collective::{Collective, GroupLayout, ReduceOp};
pub use comm::{Comm, CommError};
pub use message::{BucketPhase, Envelope, Payload, Rank, Tag,
                  WorkerStats};

/// Central wire-tag registry: the single table every protocol tag's
/// numeric value is pinned by.
///
/// PR 4 hit a real wrong-source race from two collectives sharing a tag
/// ad hoc (`GroupChunk` had to be split from `RingChunk`); this module
/// makes tag allocation explicit. The fixed tags (point-to-point
/// protocol, collective lanes, and the elastic membership-agreement
/// control lanes `ElasticSuspect..ElasticJoin`) occupy
/// `0..BUCKET_TAG_BASE`; the
/// per-bucket collective block for the overlapped all-reduce occupies
/// `[BUCKET_TAG_BASE, BUCKET_TAG_BASE + MAX_BUCKETS * BUCKET_PHASES)`,
/// one lane per (bucket, phase); the serving RPC block
/// (`ServeRequest`/`ServeReply`) sits directly above it at
/// [`tags::SERVE_TAG_BASE`]. Uniqueness and ordering are checked at
/// compile time — adding a clashing entry fails the build.
pub mod tags {
    use super::message::{BucketPhase, Tag};

    /// Every fixed protocol tag, in wire order. New fixed tags must be
    /// appended here with the next free value below [`BUCKET_TAG_BASE`].
    pub const REGISTRY: &[(u32, &str)] = &[
        (0, "Ready"),
        (1, "Gradients"),
        (2, "Weights"),
        (3, "ExchangeWeights"),
        (4, "Center"),
        (5, "Exit"),
        (6, "TrainStats"),
        (7, "AggGradients"),
        (8, "Ping"),
        (9, "RingChunk"),
        (10, "Bcast"),
        (11, "TreeReduce"),
        (12, "TreeBcast"),
        (13, "GroupGather"),
        (14, "GroupChunk"),
        (15, "GroupBcast"),
        (16, "ElasticSuspect"),
        (17, "ElasticProbe"),
        (18, "ElasticAlive"),
        (19, "ElasticPlan"),
        (20, "ElasticJoin"),
    ];

    /// First wire value of the bucket-tag block.
    pub const BUCKET_TAG_BASE: u32 = 21;
    /// Tag lanes per bucket — one per [`BucketPhase`] variant.
    pub const BUCKET_PHASES: u32 = 5;
    /// Maximum concurrently-addressable buckets per round (the tail
    /// loss/stop bucket counts as one).
    pub const MAX_BUCKETS: u32 = 32;

    /// First wire value of the serving block, directly above the bucket
    /// block. The inference front-end's frontend<->replica RPC rides the
    /// same `Comm` substrate as training, so its tags are pinned here
    /// like every other lane: `ServeRequest` = SERVE_TAG_BASE,
    /// `ServeReply` = SERVE_TAG_BASE + 1. (They are deliberately NOT in
    /// [`REGISTRY`], which by invariant covers exactly the fixed values
    /// below [`BUCKET_TAG_BASE`].)
    pub const SERVE_TAG_BASE: u32 =
        BUCKET_TAG_BASE + MAX_BUCKETS * BUCKET_PHASES;
    /// Wire values in the serving block (`ServeRequest`, `ServeReply`).
    pub const SERVE_TAGS: u32 = 2;

    /// First wire value of the topology-probe block, directly above the
    /// serving block. The self-tuning planner's link probe
    /// (`ProbePing`/`ProbePong` ping-pong + ramped-size bandwidth
    /// transfers, see DESIGN.md §Autotuning) gets its own lane so probe
    /// traffic can never be mistaken for training or serving messages —
    /// the same isolation argument as every other block here. Like the
    /// serving tags, these are NOT in [`REGISTRY`] (which covers exactly
    /// the fixed values below [`BUCKET_TAG_BASE`]).
    pub const PROBE_TAG_BASE: u32 = SERVE_TAG_BASE + SERVE_TAGS;
    /// Wire values in the probe block (`ProbePing`, `ProbePong`).
    pub const PROBE_TAGS: u32 = 2;

    const fn strictly_increasing(t: &[(u32, &str)]) -> bool {
        let mut i = 1;
        while i < t.len() {
            if t[i].0 <= t[i - 1].0 {
                return false;
            }
            i += 1;
        }
        true
    }

    // Compile-time-unique listing: values strictly increase (hence no
    // duplicates), start at 0, and stay below the bucket block.
    const _: () = assert!(strictly_increasing(REGISTRY));
    const _: () = assert!(REGISTRY[0].0 == 0);
    const _: () =
        assert!(REGISTRY[REGISTRY.len() - 1].0 < BUCKET_TAG_BASE);
    const _: () = assert!(BUCKET_PHASES >= 1 && MAX_BUCKETS >= 1);
    // The serving block starts exactly where the bucket block ends,
    // and the probe block exactly where the serving block ends.
    const _: () = assert!(
        SERVE_TAG_BASE == BUCKET_TAG_BASE + MAX_BUCKETS * BUCKET_PHASES
    );
    const _: () = assert!(SERVE_TAGS == 2);
    const _: () = assert!(PROBE_TAG_BASE == SERVE_TAG_BASE + SERVE_TAGS);
    const _: () = assert!(PROBE_TAGS == 2);

    /// The wire tag for one (bucket, phase) collective lane.
    pub fn bucket_tag(bucket: usize, phase: BucketPhase) -> Tag {
        assert!(
            (bucket as u32) < MAX_BUCKETS,
            "bucket {bucket} exceeds MAX_BUCKETS ({MAX_BUCKETS})"
        );
        Tag::Bucket { bucket: bucket as u16, phase }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        /// Registry entries decode to tags whose Debug names match the
        /// registered names and whose wire values roundtrip — the table
        /// cannot drift from the enum.
        #[test]
        fn registry_matches_tag_enum() {
            for &(v, name) in REGISTRY {
                let tag = Tag::from_u32(v)
                    .unwrap_or_else(|| panic!("{name} ({v}) missing"));
                assert_eq!(format!("{tag:?}"), name);
                assert_eq!(tag.to_u32(), v);
            }
            // the registry covers every fixed value below the block
            assert_eq!(REGISTRY.len() as u32, BUCKET_TAG_BASE);
        }

        #[test]
        #[should_panic(expected = "exceeds MAX_BUCKETS")]
        fn bucket_tag_bounds_checked() {
            bucket_tag(MAX_BUCKETS as usize, BucketPhase::Chunk);
        }

        /// The serving RPC lanes sit exactly at the top of the bucket
        /// block and roundtrip through the wire mapping.
        #[test]
        fn serve_block_pinned_above_buckets() {
            assert_eq!(SERVE_TAG_BASE,
                       BUCKET_TAG_BASE + MAX_BUCKETS * BUCKET_PHASES);
            assert_eq!(Tag::from_u32(SERVE_TAG_BASE),
                       Some(Tag::ServeRequest));
            assert_eq!(Tag::from_u32(SERVE_TAG_BASE + 1),
                       Some(Tag::ServeReply));
            assert_eq!(Tag::ServeRequest.to_u32(), SERVE_TAG_BASE);
            assert_eq!(Tag::ServeReply.to_u32(), SERVE_TAG_BASE + 1);
        }

        /// The planner's probe lanes sit exactly at the top of the
        /// serving block and roundtrip through the wire mapping.
        #[test]
        fn probe_block_pinned_above_serve() {
            assert_eq!(PROBE_TAG_BASE, SERVE_TAG_BASE + SERVE_TAGS);
            assert_eq!(Tag::from_u32(PROBE_TAG_BASE),
                       Some(Tag::ProbePing));
            assert_eq!(Tag::from_u32(PROBE_TAG_BASE + 1),
                       Some(Tag::ProbePong));
            assert_eq!(Tag::ProbePing.to_u32(), PROBE_TAG_BASE);
            assert_eq!(Tag::ProbePong.to_u32(), PROBE_TAG_BASE + 1);
        }
    }
}

/// Build an in-process world of `n` ranks (rank 0 first).
pub fn inproc_world(n: usize) -> Vec<Comm> {
    transport::inproc::world(n)
}

/// Build a localhost TCP world of `n` ranks.
pub fn tcp_world(n: usize, base_port: u16)
    -> Result<Vec<Comm>, CommError> {
    transport::tcp::world(n, base_port)
}
