//! Wire-level gradient compression codecs.
//!
//! The paper's scaling measurements (Figs 3/4) show communication volume
//! becoming the binding constraint as the world grows, and the wire
//! format ships every gradient as raw little-endian f32. This module
//! provides the standard ways past that wall (cf. Vishnu et al.,
//! *Distributed TensorFlow with MPI*; Awan et al., *HyPar-Flow*):
//!
//! - [`Codec::Fp32`] — identity (the default; no compression),
//! - [`Codec::Fp16`] — IEEE 754 binary16 quantization with
//!   round-to-nearest-even (~0.5x wire bytes),
//! - [`Codec::TopK`] — magnitude sparsification keeping a fraction `k`
//!   of elements as (index, value) pairs (~2k x wire bytes).
//!
//! Lossy codecs drop mass. The [`Compressor`] keeps an **error-feedback
//! residual** on the sender: what a round drops is added back into the
//! next round's buffer before compressing, so dropped mass is delayed,
//! not lost — the property that keeps top-k training convergent.
//!
//! Where compression sits relative to the collective's determinism
//! guarantee is documented in DESIGN.md §Gradient compression: the
//! reduce phase operates on *decoded* f32 and the all-gather replicates
//! one owner-compressed payload verbatim, so `Mode::AllReduce` keeps its
//! bitwise-identical-weights invariant under every codec.

use std::sync::Arc;

use crate::mpi::message::Payload;
use crate::runtime::kernels::par_blocks;
use crate::util::threadpool::{SharedMut, ThreadPool};

// ---------------------------------------------------------------------------
// IEEE 754 binary16 conversion (round-to-nearest-even)
// ---------------------------------------------------------------------------

/// Convert an f32 to IEEE binary16 bits with round-to-nearest-even.
/// Overflow saturates to the signed infinity; NaN becomes a quiet NaN;
/// tiny values flush through the subnormal range to signed zero.
pub fn f32_to_f16_bits(value: f32) -> u16 {
    let x = value.to_bits();
    let sign = ((x >> 16) & 0x8000) as u16;
    let mut exp = ((x >> 23) & 0xFF) as i32;
    let mut man = x & 0x007F_FFFF;
    if exp == 0xFF {
        // Inf stays Inf; any NaN becomes a quiet NaN
        return sign | if man != 0 { 0x7E00 } else { 0x7C00 };
    }
    exp -= 112; // re-bias 127 -> 15
    if exp >= 0x1F {
        return sign | 0x7C00; // overflow -> Inf
    }
    if exp <= 0 {
        if exp < -10 {
            return sign; // underflows to zero even as a subnormal
        }
        // subnormal: shift the (implicit-1) mantissa into place
        man |= 0x0080_0000;
        let shift = (14 - exp) as u32; // 14..=24
        let mut half_man = (man >> shift) as u16;
        let round_bit = 1u32 << (shift - 1);
        let remainder = man & ((1u32 << shift) - 1);
        if remainder > round_bit
            || (remainder == round_bit && half_man & 1 == 1)
        {
            half_man += 1; // may carry into the exponent: correct
        }
        return sign | half_man;
    }
    let mut h = sign | ((exp as u16) << 10) | ((man >> 13) as u16);
    let remainder = man & 0x1FFF;
    if remainder > 0x1000 || (remainder == 0x1000 && h & 1 == 1) {
        h = h.wrapping_add(1); // carry rounds up to the next binade/Inf
    }
    h
}

/// Convert IEEE binary16 bits to the exactly-representable f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let neg = h & 0x8000 != 0;
    let exp = (h >> 10) & 0x1F;
    let man = (h & 0x3FF) as u32;
    let v = match exp {
        // subnormal or zero: man * 2^-24 (exact in f32)
        0 => man as f32 * (1.0 / 16_777_216.0),
        0x1F => {
            if man == 0 {
                f32::INFINITY
            } else {
                f32::NAN
            }
        }
        e => f32::from_bits(((e as u32 + 112) << 23) | (man << 13)),
    };
    if neg {
        -v
    } else {
        v
    }
}

// ---------------------------------------------------------------------------
// codec selection
// ---------------------------------------------------------------------------

/// Which wire codec compresses float payloads.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Codec {
    /// Identity: raw little-endian f32 (the default).
    Fp32,
    /// Half-precision quantization, round-to-nearest-even.
    Fp16,
    /// Magnitude top-k sparsification: keep fraction `k` in (0, 1] of
    /// the elements (at least one) as (index, value) pairs.
    TopK { k: f32 },
}

impl Codec {
    /// Parse a CLI/config spelling: `fp32`/`none`, `fp16`, `topk`
    /// (default k = 0.1) or `topk:<k>` where `<k>` is a fraction in
    /// (0, 1] or a percentage like `10%`.
    pub fn parse(s: &str) -> Result<Codec, String> {
        let s = s.trim();
        match s {
            "fp32" | "none" | "off" => return Ok(Codec::Fp32),
            "fp16" | "half" => return Ok(Codec::Fp16),
            "topk" => return Ok(Codec::TopK { k: 0.1 }),
            _ => {}
        }
        if let Some(arg) = s.strip_prefix("topk:") {
            let arg = arg.trim();
            let k = match arg.strip_suffix('%') {
                Some(pct) => pct
                    .trim()
                    .parse::<f32>()
                    .map(|p| p / 100.0)
                    .map_err(|_| format!("bad topk percentage '{arg}'"))?,
                None => arg
                    .parse::<f32>()
                    .map_err(|_| format!("bad topk fraction '{arg}'"))?,
            };
            if !(k > 0.0 && k <= 1.0) {
                return Err(format!(
                    "topk fraction must be in (0, 1], got {k}"
                ));
            }
            return Ok(Codec::TopK { k });
        }
        Err(format!(
            "unknown compression '{s}' (fp32 | fp16 | topk:<k>)"
        ))
    }

    /// Canonical spelling (parses back to the same codec).
    pub fn name(&self) -> String {
        match self {
            Codec::Fp32 => "fp32".into(),
            Codec::Fp16 => "fp16".into(),
            Codec::TopK { k } => format!("topk:{k}"),
        }
    }

    /// True for the raw-f32 identity codec.
    pub fn is_identity(&self) -> bool {
        matches!(self, Codec::Fp32)
    }

    /// Approximate wire bytes per original payload byte — the
    /// compression-aware term of the simulator cost model. Top-k pays
    /// 8 bytes (u32 index + f32 value) per kept element against 4 raw.
    pub fn wire_ratio(&self) -> f64 {
        match self {
            Codec::Fp32 => 1.0,
            Codec::Fp16 => 0.5,
            Codec::TopK { k } => 2.0 * *k as f64,
        }
    }

    /// Compress `data`; `None` means "send raw" (identity codec).
    pub fn pack(&self, data: &[f32]) -> Option<PackedF32> {
        self.pack_protect(data, 0)
    }

    /// [`Codec::pack`] with the last `protect` elements exempt from
    /// lossy *dropping*: top-k always includes them (exact f32), so
    /// piggybacked control values (a stop flag, a loss) survive
    /// sparsification. Fp16 still quantizes them — small integers and
    /// 0/1 flags are exactly representable.
    pub fn pack_protect(&self, data: &[f32], protect: usize)
        -> Option<PackedF32> {
        match self {
            Codec::Fp32 => None,
            Codec::Fp16 => Some(PackedF32::F16(
                data.iter().map(|&v| f32_to_f16_bits(v)).collect(),
            )),
            Codec::TopK { k } => {
                Some(pack_topk(data, *k, protect.min(data.len())))
            }
        }
    }

    /// Weight/center payloads (replication hops): only fp16 compresses
    /// them — sparsifying a weight snapshot would zero most of the
    /// model. Returns `None` to send raw.
    pub fn pack_replica(&self, data: &[f32]) -> Option<PackedF32> {
        match self {
            Codec::Fp16 => self.pack(data),
            _ => None,
        }
    }

    /// Build a weight-like payload, fp16-compressed when this codec is
    /// fp16 (shared by the PS master, group masters, and EASGD).
    pub fn weights_payload(&self, step: u64, data: &[f32]) -> Payload {
        match self.pack_replica(data) {
            Some(p) => Payload::packed(step, 0.0, p),
            None => Payload::floats(step, data.to_vec()),
        }
    }
}

impl std::fmt::Display for Codec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Deterministic magnitude top-k: keep `ceil(k * body)` (at least one)
/// of the first `n - protect` elements by |value| (ties broken by lower
/// index), plus every protected trailing element, encoded as
/// index-ascending (index, value) pairs. NaN magnitudes sort largest,
/// so NaNs are kept and surface downstream instead of vanishing.
fn pack_topk(data: &[f32], k: f32, protect: usize) -> PackedF32 {
    let n = data.len();
    let body = n - protect;
    let nnz = if body == 0 {
        0
    } else {
        ((k as f64 * body as f64).ceil() as usize).clamp(1, body)
    };
    let mut order: Vec<u32> = (0..body as u32).collect();
    if nnz < body {
        let cmp = |a: &u32, b: &u32| {
            let (va, vb) =
                (data[*a as usize].abs(), data[*b as usize].abs());
            vb.total_cmp(&va).then_with(|| a.cmp(b))
        };
        order.select_nth_unstable_by(nnz, cmp);
        order.truncate(nnz);
        order.sort_unstable();
    }
    order.extend(body as u32..n as u32);
    let val = order.iter().map(|&i| data[i as usize]).collect();
    PackedF32::Sparse { n: n as u32, idx: order, val }
}

// ---------------------------------------------------------------------------
// compact forms
// ---------------------------------------------------------------------------

/// A codec-compressed f32 buffer — the compact form that travels the
/// wire (see `message::Payload::Packed`).
#[derive(Clone, Debug, PartialEq)]
pub enum PackedF32 {
    /// Dense IEEE binary16 bit patterns, one per element.
    F16(Vec<u16>),
    /// Sparse (index, value) pairs over a logical length `n`; `idx` is
    /// strictly ascending, values are exact f32.
    Sparse { n: u32, idx: Vec<u32>, val: Vec<f32> },
}

impl PackedF32 {
    /// Logical (decoded) element count.
    pub fn len(&self) -> usize {
        match self {
            PackedF32::F16(bits) => bits.len(),
            PackedF32::Sparse { n, .. } => *n as usize,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Compact body size on the wire: a [u32 enc][u32 n] header plus
    /// the encoding-specific payload (see `message::encode`).
    pub fn wire_nbytes(&self) -> usize {
        8 + match self {
            PackedF32::F16(bits) => 2 * bits.len(),
            PackedF32::Sparse { idx, .. } => 4 + 8 * idx.len(),
        }
    }

    /// Decode into a fresh dense buffer.
    pub fn unpack(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len()];
        self.unpack_into(&mut out);
        out
    }

    /// Decode into `out` (out.len() must equal `self.len()`); absent
    /// sparse elements decode to 0.0.
    pub fn unpack_into(&self, out: &mut [f32]) {
        self.unpack_into_pooled(out, None);
    }

    /// [`PackedF32::unpack_into`] with the dense f16 loop partitioned
    /// over `pool`. Each element decodes independently, so the result
    /// is bitwise-identical at any thread count; sparse payloads stay
    /// serial (scattered writes).
    pub fn unpack_into_pooled(&self, out: &mut [f32],
                              pool: Option<&ThreadPool>) {
        assert_eq!(out.len(), self.len(), "packed length mismatch");
        match self {
            PackedF32::F16(bits) => match pool {
                Some(pool) => {
                    let ov = SharedMut::new(out);
                    par_blocks(pool, bits.len(), |r| {
                        let o = unsafe { ov.range(r.clone()) };
                        for (dst, &b) in o.iter_mut().zip(&bits[r]) {
                            *dst = f16_bits_to_f32(b);
                        }
                    });
                }
                None => {
                    for (dst, &b) in out.iter_mut().zip(bits) {
                        *dst = f16_bits_to_f32(b);
                    }
                }
            },
            PackedF32::Sparse { idx, val, .. } => {
                out.fill(0.0);
                for (&i, &v) in idx.iter().zip(val) {
                    out[i as usize] = v;
                }
            }
        }
    }

    /// Sum-accumulate the decoded values into `out` (the ring's reduce
    /// step; absent sparse elements contribute nothing).
    pub fn add_into(&self, out: &mut [f32]) {
        self.add_into_pooled(out, None);
    }

    /// [`PackedF32::add_into`] with the dense f16 loop partitioned over
    /// `pool` (same bitwise contract as
    /// [`PackedF32::unpack_into_pooled`]).
    pub fn add_into_pooled(&self, out: &mut [f32],
                           pool: Option<&ThreadPool>) {
        assert_eq!(out.len(), self.len(), "packed length mismatch");
        match self {
            PackedF32::F16(bits) => match pool {
                Some(pool) => {
                    let ov = SharedMut::new(out);
                    par_blocks(pool, bits.len(), |r| {
                        let o = unsafe { ov.range(r.clone()) };
                        for (dst, &b) in o.iter_mut().zip(&bits[r]) {
                            *dst += f16_bits_to_f32(b);
                        }
                    });
                }
                None => {
                    for (dst, &b) in out.iter_mut().zip(bits) {
                        *dst += f16_bits_to_f32(b);
                    }
                }
            },
            PackedF32::Sparse { idx, val, .. } => {
                for (&i, &v) in idx.iter().zip(val) {
                    out[i as usize] += v;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// error-feedback compressor
// ---------------------------------------------------------------------------

/// Sender-side compression state: the error-feedback residual. What a
/// lossy codec drops in one round is added back into the next round's
/// buffer before compressing, so gradient mass is delayed, never lost
/// (the residual stays bounded; see the `error_feedback_*` tests).
pub struct Compressor {
    codec: Codec,
    residual: Vec<f32>,
    /// Partition the fp16 quantize+residual loop over this pool. Every
    /// element's op sequence is unchanged, so packed bytes and residual
    /// are bitwise-identical at any thread count. Top-k stays serial —
    /// its global magnitude selection is one reduction.
    pool: Option<Arc<ThreadPool>>,
}

impl Compressor {
    pub fn new(codec: Codec) -> Self {
        Self { codec, residual: Vec::new(), pool: None }
    }

    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Run the fp16 pack loop on `pool` (see the field docs).
    pub fn set_pool(&mut self, pool: Arc<ThreadPool>) {
        self.pool = Some(pool);
    }

    /// Compress a whole buffer with error feedback. `None` means "send
    /// raw" (identity codec; no residual is kept — nothing is lost).
    pub fn compress(&mut self, data: &[f32]) -> Option<PackedF32> {
        self.compress_window(data, 0, data.len(), 0)
    }

    /// Compress `chunk`, a window of a logical buffer of `total`
    /// elements starting at `offset` — the ring collective compresses
    /// per-chunk but keeps ONE residual per element index. The last
    /// `protect` elements of the chunk are exempt from lossy dropping
    /// (see [`Codec::pack_protect`]).
    pub fn compress_window(&mut self, chunk: &[f32], offset: usize,
                           total: usize, protect: usize)
        -> Option<PackedF32> {
        if self.codec.is_identity() {
            return None;
        }
        if self.residual.len() != total {
            // first use (or a shape change): start from a zero residual
            self.residual = vec![0.0; total];
        }
        let res = &mut self.residual[offset..offset + chunk.len()];
        if let (Codec::Fp16, Some(pool)) = (self.codec, &self.pool) {
            // Fused pooled fp16 path: per element, acc = chunk + res,
            // quantize, carry the error — the exact op sequence of the
            // generic path below, just partitioned into disjoint
            // blocks.
            let mut bits = vec![0u16; chunk.len()];
            let bv = SharedMut::new(&mut bits);
            let rv = SharedMut::new(res);
            par_blocks(pool, chunk.len(), |r| {
                let bs = unsafe { bv.range(r.clone()) };
                let rs = unsafe { rv.range(r.clone()) };
                for ((b, rr), &c) in
                    bs.iter_mut().zip(rs.iter_mut()).zip(&chunk[r])
                {
                    let a = c + *rr;
                    *b = f32_to_f16_bits(a);
                    *rr = a - f16_bits_to_f32(*b);
                }
            });
            return Some(PackedF32::F16(bits));
        }
        let acc: Vec<f32> =
            chunk.iter().zip(res.iter()).map(|(c, r)| c + r).collect();
        let packed = self
            .codec
            .pack_protect(&acc, protect)
            .expect("non-identity codec packs");
        match &packed {
            PackedF32::F16(bits) => {
                for ((r, &a), &b) in
                    res.iter_mut().zip(&acc).zip(bits.iter())
                {
                    *r = a - f16_bits_to_f32(b);
                }
            }
            PackedF32::Sparse { idx, .. } => {
                // kept values are exact: residual = acc with kept
                // positions zeroed
                res.copy_from_slice(&acc);
                for &i in idx {
                    res[i as usize] = 0.0;
                }
            }
        }
        Some(packed)
    }

    /// Largest dropped-mass magnitude currently carried (diagnostics).
    pub fn max_residual(&self) -> f32 {
        self.residual.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }
}

/// Build a gradient-like payload: compressed (with feedback) when the
/// compressor's codec is lossy, raw otherwise.
pub fn grad_payload(comp: &mut Compressor, step: u64, loss: f32,
                    grads: Vec<f32>) -> Payload {
    match comp.compress(&grads) {
        Some(p) => Payload::packed(step, loss, p),
        None => Payload::grad(step, loss, grads),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_roundtrip_every_non_nan_pattern() {
        for h in 0..=u16::MAX {
            let exp = (h >> 10) & 0x1F;
            let man = h & 0x3FF;
            if exp == 0x1F && man != 0 {
                continue; // NaNs canonicalize; checked separately
            }
            let f = f16_bits_to_f32(h);
            assert_eq!(f32_to_f16_bits(f), h,
                       "pattern {h:#06x} -> {f} did not round-trip");
        }
    }

    #[test]
    fn f16_round_to_nearest_even_ties() {
        // 1 + 2^-11 is exactly halfway between f16(1.0) and the next
        // representable (1 + 2^-10): RNE picks the even mantissa (1.0)
        assert_eq!(f32_to_f16_bits(1.0 + 2f32.powi(-11)), 0x3C00);
        // 1 + 2^-10 + 2^-11 is halfway with an ODD lower mantissa:
        // RNE rounds up to mantissa 2
        assert_eq!(f32_to_f16_bits(1.0 + 2f32.powi(-10) + 2f32.powi(-11)),
                   0x3C02);
        // just above the tie rounds up
        assert_eq!(f32_to_f16_bits(1.0 + 2f32.powi(-11) + 2f32.powi(-18)),
                   0x3C01);
    }

    #[test]
    fn f16_saturation_and_specials() {
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(65504.0)), 65504.0);
        // 65520 is the tie to the first unrepresentable binade: -> Inf
        assert_eq!(f32_to_f16_bits(65520.0), 0x7C00);
        assert_eq!(f32_to_f16_bits(1e9), 0x7C00);
        assert_eq!(f32_to_f16_bits(-1e9), 0xFC00);
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xFC00);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // signed zero survives
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f16_bits_to_f32(0x8000).to_bits(), (-0.0f32).to_bits());
        // subnormal range: 2^-24 is the smallest half subnormal
        assert_eq!(f32_to_f16_bits(2f32.powi(-24)), 0x0001);
        assert_eq!(f32_to_f16_bits(2f32.powi(-25)), 0x0000); // tie-even
        assert_eq!(f32_to_f16_bits(2f32.powi(-25) * 1.5), 0x0001);
    }

    #[test]
    fn parse_spellings() {
        assert_eq!(Codec::parse("fp32").unwrap(), Codec::Fp32);
        assert_eq!(Codec::parse("none").unwrap(), Codec::Fp32);
        assert_eq!(Codec::parse("fp16").unwrap(), Codec::Fp16);
        assert_eq!(Codec::parse("topk").unwrap(),
                   Codec::TopK { k: 0.1 });
        assert_eq!(Codec::parse("topk:0.25").unwrap(),
                   Codec::TopK { k: 0.25 });
        assert_eq!(Codec::parse("topk:10%").unwrap(),
                   Codec::TopK { k: 0.1 });
        assert!(Codec::parse("topk:0").is_err());
        assert!(Codec::parse("topk:1.5").is_err());
        assert!(Codec::parse("topk:abc").is_err());
        assert!(Codec::parse("gzip").is_err());
        // canonical names parse back
        for c in [Codec::Fp32, Codec::Fp16, Codec::TopK { k: 0.25 }] {
            assert_eq!(Codec::parse(&c.name()).unwrap(), c);
        }
    }

    #[test]
    fn wire_ratios() {
        assert_eq!(Codec::Fp32.wire_ratio(), 1.0);
        assert_eq!(Codec::Fp16.wire_ratio(), 0.5);
        assert!((Codec::TopK { k: 0.1 }.wire_ratio() - 0.2).abs() < 1e-9);
    }

    #[test]
    fn topk_keeps_largest_magnitudes() {
        let data = [0.1f32, -5.0, 0.0, 2.0, -0.5, 3.0];
        let p = Codec::TopK { k: 0.5 }.pack(&data).unwrap();
        match &p {
            PackedF32::Sparse { n, idx, val } => {
                assert_eq!(*n, 6);
                assert_eq!(idx, &[1, 3, 5]);
                assert_eq!(val, &[-5.0, 2.0, 3.0]);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(p.unpack(), vec![0.0, -5.0, 0.0, 2.0, 0.0, 3.0]);
    }

    #[test]
    fn topk_ties_break_by_lower_index() {
        let data = [1.0f32, -1.0, 1.0, 1.0];
        let p = Codec::TopK { k: 0.5 }.pack(&data).unwrap();
        match p {
            PackedF32::Sparse { idx, .. } => assert_eq!(idx, vec![0, 1]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn topk_protects_trailing_elements() {
        // 8 body elements + a loss + a 0/1 stop flag: the tiny flag
        // must survive even though its magnitude never competes
        let mut data = vec![10.0f32; 8];
        data.push(0.7); // loss
        data.push(1.0); // stop flag
        let p = Codec::TopK { k: 0.125 }.pack_protect(&data, 2).unwrap();
        let dec = p.unpack();
        assert_eq!(dec[8], 0.7);
        assert_eq!(dec[9], 1.0);
        match &p {
            PackedF32::Sparse { idx, .. } => {
                assert_eq!(idx.len(), 3); // 1 body + 2 protected
                assert_eq!(&idx[1..], &[8, 9]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn topk_edge_lengths() {
        let c = Codec::TopK { k: 0.1 };
        assert_eq!(c.pack(&[]).unwrap().unpack(), Vec::<f32>::new());
        assert_eq!(c.pack(&[3.5]).unwrap().unpack(), vec![3.5]);
        // k = 1 keeps everything
        let data = [1.0f32, -2.0, 0.5];
        assert_eq!(Codec::TopK { k: 1.0 }.pack(&data).unwrap().unpack(),
                   data.to_vec());
        // all-protected buffer round-trips exactly
        assert_eq!(c.pack_protect(&data, 3).unwrap().unpack(),
                   data.to_vec());
    }

    #[test]
    fn topk_is_idempotent_on_its_own_output() {
        let data: Vec<f32> = (0..40)
            .map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.3)
            .collect();
        let c = Codec::TopK { k: 0.2 };
        let once = c.pack(&data).unwrap().unpack();
        let twice = c.pack(&once).unwrap().unpack();
        assert_eq!(once, twice);
    }

    #[test]
    fn fp16_pack_unpack_dense() {
        let data = [0.5f32, -1.25, 3.0e-5, 70000.0, 0.0];
        let p = Codec::Fp16.pack(&data).unwrap();
        let dec = p.unpack();
        assert_eq!(dec[0], 0.5);
        assert_eq!(dec[1], -1.25);
        assert!((dec[2] - 3.0e-5).abs() / 3.0e-5 < 1e-3);
        assert_eq!(dec[3], f32::INFINITY); // saturation
        assert_eq!(dec[4], 0.0);
        assert_eq!(p.wire_nbytes(), 8 + 10);
    }

    #[test]
    fn identity_codec_packs_nothing() {
        assert!(Codec::Fp32.pack(&[1.0, 2.0]).is_none());
        assert!(Compressor::new(Codec::Fp32)
            .compress(&[1.0, 2.0])
            .is_none());
        assert!(Codec::Fp32.is_identity());
        assert!(!Codec::Fp16.is_identity());
    }

    #[test]
    fn replica_packing_is_fp16_only() {
        let w = [0.5f32, -0.25];
        assert!(Codec::Fp32.pack_replica(&w).is_none());
        assert!(Codec::TopK { k: 0.1 }.pack_replica(&w).is_none());
        let p = Codec::Fp16.pack_replica(&w).unwrap();
        assert_eq!(p.unpack(), w.to_vec());
    }

    #[test]
    fn error_feedback_reinjects_dropped_mass() {
        // k keeps 1 of 4: the small element is dropped on round 1 but
        // its residual joins round 2, where a zero gradient lets it win
        let mut comp = Compressor::new(Codec::TopK { k: 0.25 });
        let p1 = comp.compress(&[4.0, 0.5, 0.0, 0.0]).unwrap();
        assert_eq!(p1.unpack(), vec![4.0, 0.0, 0.0, 0.0]);
        assert_eq!(comp.max_residual(), 0.5);
        let p2 = comp.compress(&[0.0, 0.0, 0.0, 0.0]).unwrap();
        assert_eq!(p2.unpack(), vec![0.0, 0.5, 0.0, 0.0]);
        assert_eq!(comp.max_residual(), 0.0);
    }

    #[test]
    fn error_feedback_fp16_carries_quantization_error() {
        let mut comp = Compressor::new(Codec::Fp16);
        let v = 1.0 + 2f32.powi(-13); // rounds to 1.0 in fp16
        let p = comp.compress(&[v]).unwrap();
        assert_eq!(p.unpack(), vec![1.0]);
        assert!(comp.max_residual() > 0.0);
        // the carried error eventually pushes past the quantum
        let mut total = p.unpack()[0];
        for _ in 0..20 {
            total += comp.compress(&[v]).unwrap().unpack()[0];
        }
        assert!((total - 21.0 * v).abs() < 2f32.powi(-10),
                "cumulative delivery drifted: {total} vs {}", 21.0 * v);
    }

    #[test]
    fn compress_window_keeps_one_residual_per_index() {
        let mut comp = Compressor::new(Codec::TopK { k: 0.5 });
        // two windows of a logical 4-element buffer
        let a = comp.compress_window(&[3.0, 0.1], 0, 4, 0).unwrap();
        let b = comp.compress_window(&[0.2, 5.0], 2, 4, 0).unwrap();
        assert_eq!(a.unpack(), vec![3.0, 0.0]);
        assert_eq!(b.unpack(), vec![0.0, 5.0]);
        // residuals live at global indices 1 and 2
        let c = comp.compress_window(&[0.0, 0.0], 0, 4, 0).unwrap();
        assert_eq!(c.unpack(), vec![0.0, 0.1]);
        let d = comp.compress_window(&[0.0, 0.0], 2, 4, 0).unwrap();
        assert_eq!(d.unpack(), vec![0.2, 0.0]);
    }

    /// The pooled fp16 pack/unpack paths must be bitwise-identical to
    /// the serial ones — packed bits, residual, and decoded floats.
    #[test]
    fn pooled_fp16_paths_are_bitwise_identical() {
        let n = 9_137usize;
        let data: Vec<f32> = (0..n)
            .map(|i| ((i % 251) as f32 - 125.0) * 1.7e-3
                 + ((i % 7) as f32) * 1e-7)
            .collect();
        for threads in [2usize, 4] {
            let pool = Arc::new(ThreadPool::new(threads));
            let mut serial = Compressor::new(Codec::Fp16);
            let mut pooled = Compressor::new(Codec::Fp16);
            pooled.set_pool(Arc::clone(&pool));
            for round in 0..3 {
                let ps = serial.compress(&data).unwrap();
                let pp = pooled.compress(&data).unwrap();
                assert_eq!(ps, pp, "round {round} at {threads} threads");
                let mut outs = vec![0.0f32; n];
                let mut outp = vec![0.0f32; n];
                ps.unpack_into(&mut outs);
                pp.unpack_into_pooled(&mut outp, Some(&pool));
                assert!(outs.iter().zip(&outp)
                    .all(|(a, b)| a.to_bits() == b.to_bits()));
                let mut adds = outs.clone();
                let mut addp = outs.clone();
                ps.add_into(&mut adds);
                pp.add_into_pooled(&mut addp, Some(&pool));
                assert!(adds.iter().zip(&addp)
                    .all(|(a, b)| a.to_bits() == b.to_bits()));
            }
            assert_eq!(serial.max_residual(), pooled.max_residual());
        }
    }

    #[test]
    fn weights_payload_variants() {
        let w = [0.5f32, -1.5];
        match Codec::Fp32.weights_payload(7, &w) {
            Payload::Floats { step, data } => {
                assert_eq!(step, 7);
                assert_eq!(*data, w.to_vec());
            }
            other => panic!("{other:?}"),
        }
        match Codec::Fp16.weights_payload(7, &w) {
            Payload::Packed { step, data, .. } => {
                assert_eq!(step, 7);
                assert_eq!(data.unpack(), w.to_vec());
            }
            other => panic!("{other:?}"),
        }
    }
}
