//! Communicator: the MPI-world abstraction over pluggable transports.
//!
//! Semantics mirror what `mpi_learn` uses from mpi4py:
//! - a world of `size` ranks,
//! - tagged point-to-point `send` (non-blocking, buffered — MPI_Isend
//!   flavor),
//! - blocking `recv` from ANY_SOURCE, plus `try_recv` / `recv_timeout`,
//! - in-order delivery per (sender, receiver) pair.
//!
//! Two transports implement the same interface: [`super::transport::inproc`]
//! (threads + channels: the shared-memory single-node case of the paper's
//! Supermicro server) and [`super::transport::tcp`] (socket mesh: the
//! Cooley-cluster case).

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::Duration;

use super::message::{Envelope, Payload, Rank, Tag};

#[derive(Debug, thiserror::Error)]
pub enum CommError {
    #[error("send to rank {0} failed: peer disconnected")]
    SendFailed(Rank),
    #[error("recv failed: all peers disconnected")]
    Disconnected,
    #[error("recv timed out after {0:?}")]
    Timeout(Duration),
    #[error("invalid rank {rank} (world size {size})")]
    InvalidRank { rank: Rank, size: usize },
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

/// Sending half — transport-specific.
pub(super) enum Sender {
    Inproc(Vec<Option<std::sync::mpsc::Sender<Envelope>>>),
    Tcp(super::transport::tcp::TcpSenders),
}

/// One rank's endpoint in the world.
pub struct Comm {
    rank: Rank,
    size: usize,
    pub(super) tx: Sender,
    pub(super) rx: Receiver<Envelope>,
    /// Bytes sent/received — exposed for the comm microbench + simulator
    /// calibration.
    pub(super) bytes_sent: std::cell::Cell<u64>,
    pub(super) bytes_recv: std::cell::Cell<u64>,
}

impl Comm {
    pub(super) fn new(rank: Rank, size: usize, tx: Sender,
                      rx: Receiver<Envelope>) -> Self {
        Self {
            rank,
            size,
            tx,
            rx,
            bytes_sent: std::cell::Cell::new(0),
            bytes_recv: std::cell::Cell::new(0),
        }
    }

    pub fn rank(&self) -> Rank {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent.get()
    }

    pub fn bytes_recv(&self) -> u64 {
        self.bytes_recv.get()
    }

    /// Buffered non-blocking send (MPI_Isend flavor).
    pub fn send(&self, to: Rank, tag: Tag, payload: Payload)
        -> Result<(), CommError> {
        if to >= self.size {
            return Err(CommError::InvalidRank { rank: to, size: self.size });
        }
        self.bytes_sent.set(self.bytes_sent.get() + payload.nbytes() as u64);
        match &self.tx {
            Sender::Inproc(peers) => {
                let ch = peers[to]
                    .as_ref()
                    .expect("send to self not supported");
                ch.send(Envelope { src: self.rank, tag, payload })
                    .map_err(|_| CommError::SendFailed(to))
            }
            Sender::Tcp(senders) => senders.send(self.rank, to, tag,
                                                 &payload),
        }
    }

    /// Blocking receive from ANY_SOURCE.
    pub fn recv(&self) -> Result<Envelope, CommError> {
        let env = self.rx.recv().map_err(|_| CommError::Disconnected)?;
        self.bytes_recv
            .set(self.bytes_recv.get() + env.payload.nbytes() as u64);
        Ok(env)
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Result<Option<Envelope>, CommError> {
        match self.rx.try_recv() {
            Ok(env) => {
                self.bytes_recv
                    .set(self.bytes_recv.get() + env.payload.nbytes() as u64);
                Ok(Some(env))
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(CommError::Disconnected),
        }
    }

    pub fn recv_timeout(&self, dur: Duration) -> Result<Envelope, CommError> {
        match self.rx.recv_timeout(dur) {
            Ok(env) => {
                self.bytes_recv
                    .set(self.bytes_recv.get() + env.payload.nbytes() as u64);
                Ok(env)
            }
            Err(RecvTimeoutError::Timeout) => Err(CommError::Timeout(dur)),
            Err(RecvTimeoutError::Disconnected) => {
                Err(CommError::Disconnected)
            }
        }
    }

    /// Blocking receive of a specific tag; other tags are delivered later
    /// (simple out-of-band queue, like MPI tag matching).
    ///
    /// NOTE: only used in tests/benches — the training protocol is designed
    /// so each role's state machine consumes every tag it can receive.
    pub fn recv_tag(&self, want: Tag, stash: &mut Vec<Envelope>)
        -> Result<Envelope, CommError> {
        if let Some(i) = stash.iter().position(|e| e.tag == want) {
            return Ok(stash.remove(i));
        }
        loop {
            let env = self.recv()?;
            if env.tag == want {
                return Ok(env);
            }
            stash.push(env);
        }
    }
}
