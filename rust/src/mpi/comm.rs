//! Communicator: the MPI-world abstraction over pluggable transports.
//!
//! Semantics mirror what `mpi_learn` uses from mpi4py:
//! - a world of `size` ranks,
//! - tagged point-to-point `send` (non-blocking, buffered — MPI_Isend
//!   flavor),
//! - blocking `recv` from ANY_SOURCE, plus `try_recv` / `recv_timeout`,
//! - in-order delivery per (sender, receiver) pair.
//!
//! Two transports implement the same interface: [`super::transport::inproc`]
//! (threads + channels: the shared-memory single-node case of the paper's
//! Supermicro server) and [`super::transport::tcp`] (socket mesh: the
//! Cooley-cluster case).

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::Duration;

use super::message::{Envelope, Payload, Rank, Tag};

#[derive(Debug)]
pub enum CommError {
    SendFailed(Rank),
    Disconnected,
    Timeout(Duration),
    InvalidRank { rank: Rank, size: usize },
    /// Peer violated a protocol invariant (e.g. a collective received a
    /// chunk from a non-neighbor rank or with the wrong length).
    Protocol(String),
    /// An elastic-membership control message arrived mid-collective:
    /// the caller must abort the in-flight round and run the
    /// membership-agreement barrier (DESIGN.md §Elasticity). Not a
    /// transport failure — the world is being re-formed.
    Interrupted(String),
    Io(std::io::Error),
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::SendFailed(r) => {
                write!(f, "send to rank {r} failed: peer disconnected")
            }
            CommError::Disconnected => {
                write!(f, "recv failed: all peers disconnected")
            }
            CommError::Timeout(d) => write!(f, "recv timed out after {d:?}"),
            CommError::InvalidRank { rank, size } => {
                write!(f, "invalid rank {rank} (world size {size})")
            }
            CommError::Protocol(msg) => write!(f, "protocol: {msg}"),
            CommError::Interrupted(msg) => {
                write!(f, "collective interrupted: {msg}")
            }
            CommError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for CommError {}

impl From<std::io::Error> for CommError {
    fn from(e: std::io::Error) -> Self {
        CommError::Io(e)
    }
}

/// Sending half — transport-specific. Peer maps sit behind `RefCell`
/// so a departed peer's endpoint can be dropped (`Comm::close_peer`)
/// without `&mut self` — `Comm` is already `!Sync` (Cell counters), so
/// single-threaded interior mutability is safe here.
pub(super) enum Sender {
    Inproc(
        std::cell::RefCell<Vec<Option<std::sync::mpsc::Sender<Envelope>>>>,
    ),
    Tcp(super::transport::tcp::TcpSenders),
}

/// One rank's endpoint in the world.
pub struct Comm {
    rank: Rank,
    size: usize,
    pub(super) tx: Sender,
    pub(super) rx: Receiver<Envelope>,
    /// Bytes sent/received — exposed for the comm microbench + simulator
    /// calibration.
    pub(super) bytes_sent: std::cell::Cell<u64>,
    pub(super) bytes_recv: std::cell::Cell<u64>,
}

impl Comm {
    pub(super) fn new(rank: Rank, size: usize, tx: Sender,
                      rx: Receiver<Envelope>) -> Self {
        Self {
            rank,
            size,
            tx,
            rx,
            bytes_sent: std::cell::Cell::new(0),
            bytes_recv: std::cell::Cell::new(0),
        }
    }

    pub fn rank(&self) -> Rank {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent.get()
    }

    pub fn bytes_recv(&self) -> u64 {
        self.bytes_recv.get()
    }

    /// Buffered non-blocking send (MPI_Isend flavor).
    ///
    /// Sending to your own rank is reported as `InvalidRank` rather than
    /// panicking: ring collectives make self-adjacent worlds (size 1–2)
    /// easy to construct, and their algorithms degrade to zero steps
    /// instead of self-sends — so a self-send is always a caller bug,
    /// surfaced as an error the caller can attribute.
    pub fn send(&self, to: Rank, tag: Tag, payload: Payload)
        -> Result<(), CommError> {
        if to >= self.size || to == self.rank {
            return Err(CommError::InvalidRank { rank: to, size: self.size });
        }
        self.bytes_sent.set(self.bytes_sent.get() + payload.nbytes() as u64);
        match &self.tx {
            Sender::Inproc(peers) => {
                // Clone the channel handle out of the borrow before
                // sending so a reentrant close cannot observe a held
                // borrow. A `None` slot for a non-self rank means the
                // peer departed (`close_peer`): report SendFailed, the
                // same error a dead TCP peer produces.
                let ch = peers.borrow()[to].clone();
                match ch {
                    Some(ch) => ch
                        .send(Envelope { src: self.rank, tag, payload })
                        .map_err(|_| CommError::SendFailed(to)),
                    None => Err(CommError::SendFailed(to)),
                }
            }
            Sender::Tcp(senders) => senders.send(self.rank, to, tag,
                                                 &payload),
        }
    }

    /// Drop the sending endpoint for a departed peer. Subsequent sends
    /// to it fail fast with `SendFailed` instead of writing into a dead
    /// channel/socket; the TCP transport also shuts the socket down so
    /// the survivor does not hold the dead peer's half-open connection.
    /// Idempotent; out-of-range ranks are ignored.
    pub fn close_peer(&self, peer: Rank) {
        if peer >= self.size || peer == self.rank {
            return;
        }
        match &self.tx {
            Sender::Inproc(peers) => {
                peers.borrow_mut()[peer] = None;
            }
            Sender::Tcp(senders) => senders.close_peer(peer),
        }
    }

    /// Whether this rank still holds a live sending endpoint for `peer`.
    pub fn has_peer(&self, peer: Rank) -> bool {
        if peer >= self.size || peer == self.rank {
            return false;
        }
        match &self.tx {
            Sender::Inproc(peers) => peers.borrow()[peer].is_some(),
            Sender::Tcp(senders) => senders.has_peer(peer),
        }
    }

    /// Blocking receive from ANY_SOURCE.
    pub fn recv(&self) -> Result<Envelope, CommError> {
        let env = self.rx.recv().map_err(|_| CommError::Disconnected)?;
        self.bytes_recv
            .set(self.bytes_recv.get() + env.payload.nbytes() as u64);
        Ok(env)
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Result<Option<Envelope>, CommError> {
        match self.rx.try_recv() {
            Ok(env) => {
                self.bytes_recv
                    .set(self.bytes_recv.get() + env.payload.nbytes() as u64);
                Ok(Some(env))
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(CommError::Disconnected),
        }
    }

    pub fn recv_timeout(&self, dur: Duration) -> Result<Envelope, CommError> {
        match self.rx.recv_timeout(dur) {
            Ok(env) => {
                self.bytes_recv
                    .set(self.bytes_recv.get() + env.payload.nbytes() as u64);
                Ok(env)
            }
            Err(RecvTimeoutError::Timeout) => Err(CommError::Timeout(dur)),
            Err(RecvTimeoutError::Disconnected) => {
                Err(CommError::Disconnected)
            }
        }
    }

    /// Blocking receive of a specific tag; other tags are stashed and
    /// delivered later (simple out-of-band queue, like MPI tag
    /// matching). Same-tag messages keep their arrival order: the stash
    /// is scanned front-to-back, so per-(sender, tag) FIFO survives a
    /// detour through it.
    ///
    /// Used by the all-reduce wind-down (rank 0 collects `TrainStats`
    /// that may have been stashed during the final collectives) and by
    /// tests/benches.
    pub fn recv_tag(&self, want: Tag, stash: &mut Vec<Envelope>)
        -> Result<Envelope, CommError> {
        if let Some(i) = stash.iter().position(|e| e.tag == want) {
            return Ok(stash.remove(i));
        }
        loop {
            let env = self.recv()?;
            if env.tag == want {
                return Ok(env);
            }
            stash.push(env);
        }
    }
}
