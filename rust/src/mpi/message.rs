//! Message types and wire encoding for the MPI-style substrate.
//!
//! `mpi_learn` drives its whole protocol with tagged point-to-point
//! messages (mpi4py tags like `gradients`, `weights`, `train`, `exit`).
//! We mirror that: an [`Envelope`] is (source rank, [`Tag`], [`Payload`]).
//!
//! Payloads have a compact binary wire format (used verbatim by the TCP
//! transport; the in-process transport passes the enum directly):
//!
//! ```text
//! [u32 tag] [u32 kind] [u64 nbytes] [payload bytes...]
//! ```
//! Float payloads are little-endian f32; the `Stats` payload is a small
//! fixed struct. CRC is delegated to TCP's checksum; the frame length is
//! validated on decode.
//!
//! Codec-compressed float buffers ([`crate::mpi::codec`]) travel as the
//! self-describing `Packed` kind:
//!
//! ```text
//! [u64 step] [f32 loss] [u32 enc] [u32 n] [encoding-specific bytes]
//!   enc 1 (fp16):  n * u16 binary16 bit patterns
//!   enc 2 (top-k): [u32 nnz] [nnz * u32 idx] [nnz * f32 val]
//! ```

pub type Rank = usize;

/// Phase of a per-bucket collective, encoded into the bucket tag block.
/// Each phase mirrors one of the fixed collective tags (`RingChunk`,
/// `GroupGather`, `TreeReduce`, `TreeBcast`, `GroupBcast`) so a bucketed
/// all-reduce runs the exact same schedule as the monolithic one, just
/// on a tag lane of its own per bucket.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(u32)]
pub enum BucketPhase {
    /// ring reduce-scatter / all-gather chunk (flat ring or intra-group)
    Chunk = 0,
    /// member -> group leader gather (hierarchical intra-group)
    Gather = 1,
    /// child -> parent inter-group tree partial sum (hierarchical)
    TreeReduce = 2,
    /// parent -> child canonical payload (hierarchical)
    TreeBcast = 3,
    /// leader -> group ring canonical payload (hierarchical)
    Bcast = 4,
}

impl BucketPhase {
    pub fn from_u32(v: u32) -> Option<BucketPhase> {
        Some(match v {
            0 => BucketPhase::Chunk,
            1 => BucketPhase::Gather,
            2 => BucketPhase::TreeReduce,
            3 => BucketPhase::TreeBcast,
            4 => BucketPhase::Bcast,
            _ => return None,
        })
    }
}

/// Protocol tags (superset of mpi_learn's).
///
/// Every fixed tag's wire value is pinned by the central registry in
/// [`crate::mpi::tags`] (compile-time-checked unique and ordered); the
/// data-carrying `Bucket` variant owns the contiguous block above the
/// fixed tags, one lane per (bucket, phase). Wire values come from
/// [`Tag::to_u32`] — there is deliberately no `as u32` cast.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Tag {
    /// worker -> master: ready to train, send me initial weights
    Ready,
    /// worker -> master: gradient payload (Downpour)
    Gradients,
    /// master -> worker: full weight payload
    Weights,
    /// worker -> master: EASGD weight exchange request (payload = worker weights)
    ExchangeWeights,
    /// master -> worker: EASGD center variable
    Center,
    /// master -> worker: stop training
    Exit,
    /// worker -> master: per-epoch timing/progress stats
    TrainStats,
    /// master -> parent master: hierarchical aggregated gradient
    AggGradients,
    /// any -> any: liveness probe (comm microbench)
    Ping,
    /// neighbor -> neighbor: ring all-reduce chunk (collective layer)
    RingChunk,
    /// neighbor -> neighbor: ring broadcast payload (collective layer)
    Bcast,
    /// child -> parent: binary-tree reduce partial sum (collective layer,
    /// hierarchical all-reduce's inter-group phase)
    TreeReduce,
    /// parent -> child: binary-tree broadcast payload (collective layer)
    TreeBcast,
    /// member -> group leader: reduce-scattered chunk gather (collective
    /// layer, hierarchical all-reduce's intra-group phase)
    GroupGather,
    /// group-ring neighbor -> neighbor: intra-group reduce-scatter
    /// chunk. Distinct from `RingChunk` so grouped traffic can never be
    /// mistaken for a flat collective's (their source ranks differ, and
    /// a fast rank's first grouped chunk may arrive while its neighbor
    /// is still inside a flat collective's strict receive).
    GroupChunk,
    /// group-ring neighbor -> neighbor: the canonical result payload
    /// chained through the group (distinct from `Bcast` for the same
    /// reason as `GroupChunk`).
    GroupBcast,
    /// member -> coordinator (rank 0): my collective timed out — a
    /// neighbor is suspected dead. Generation-stamped with the sender's
    /// world epoch so stale suspicions from an already-replaced world
    /// are discarded (see DESIGN.md §Elasticity).
    ElasticSuspect,
    /// coordinator -> members: liveness probe at a membership-agreement
    /// barrier; answer with `ElasticAlive` or be declared departed.
    ElasticProbe,
    /// member -> coordinator: probe answer. Payload carries the member's
    /// completed-update count so the coordinator can pick the
    /// most-advanced survivor as the weight re-sync root.
    ElasticAlive,
    /// coordinator -> members: the agreed next world
    /// (epoch, member list, sync root, resume update count) encoded by
    /// [`crate::coordinator::elastic`].
    ElasticPlan,
    /// joiner -> coordinator: request admission at the next membership
    /// barrier. Deliberately exempt from generation screening — a joiner
    /// cannot know the current epoch.
    ElasticJoin,
    /// Per-bucket collective traffic for the compute-overlapped
    /// (bucketed) all-reduce: one tag lane per (bucket, phase) so
    /// multiple outstanding collectives can be in flight without
    /// cross-talk — the wrong-source hazard that forced `GroupChunk`
    /// away from `RingChunk` applies between buckets too.
    Bucket { bucket: u16, phase: BucketPhase },
    /// serve frontend -> replica: one micro-batch of inference inputs
    /// (`Floats { step: batch id, data: rows * seq_len * features }`).
    ServeRequest,
    /// serve replica -> frontend: the logits for one micro-batch
    /// (`Floats { step: batch id, data: rows * classes }`).
    ServeReply,
    /// planner rank 0 -> peer: topology-probe ping. `Payload::Empty`
    /// measures pure link latency; a `Floats` payload of ramped size
    /// measures bandwidth (the peer echoes it back verbatim). `step`
    /// sequences the probe so a straggling echo can never be matched
    /// to a later exchange.
    ProbePing,
    /// peer -> planner rank 0: the probe echo (same payload shape as
    /// the ping it answers).
    ProbePong,
}

impl Tag {
    /// Wire value. Fixed tags are the registry's pinned values; bucket
    /// tags map into the block at
    /// `BUCKET_TAG_BASE + bucket * BUCKET_PHASES + phase`.
    pub fn to_u32(self) -> u32 {
        use crate::mpi::tags::{BUCKET_PHASES, BUCKET_TAG_BASE,
                               PROBE_TAG_BASE, SERVE_TAG_BASE};
        match self {
            Tag::Ready => 0,
            Tag::Gradients => 1,
            Tag::Weights => 2,
            Tag::ExchangeWeights => 3,
            Tag::Center => 4,
            Tag::Exit => 5,
            Tag::TrainStats => 6,
            Tag::AggGradients => 7,
            Tag::Ping => 8,
            Tag::RingChunk => 9,
            Tag::Bcast => 10,
            Tag::TreeReduce => 11,
            Tag::TreeBcast => 12,
            Tag::GroupGather => 13,
            Tag::GroupChunk => 14,
            Tag::GroupBcast => 15,
            Tag::ElasticSuspect => 16,
            Tag::ElasticProbe => 17,
            Tag::ElasticAlive => 18,
            Tag::ElasticPlan => 19,
            Tag::ElasticJoin => 20,
            Tag::Bucket { bucket, phase } => {
                BUCKET_TAG_BASE
                    + bucket as u32 * BUCKET_PHASES
                    + phase as u32
            }
            Tag::ServeRequest => SERVE_TAG_BASE,
            Tag::ServeReply => SERVE_TAG_BASE + 1,
            Tag::ProbePing => PROBE_TAG_BASE,
            Tag::ProbePong => PROBE_TAG_BASE + 1,
        }
    }

    pub fn from_u32(v: u32) -> Option<Tag> {
        use crate::mpi::tags::{BUCKET_PHASES, BUCKET_TAG_BASE,
                               MAX_BUCKETS, PROBE_TAG_BASE,
                               SERVE_TAG_BASE};
        Some(match v {
            0 => Tag::Ready,
            1 => Tag::Gradients,
            2 => Tag::Weights,
            3 => Tag::ExchangeWeights,
            4 => Tag::Center,
            5 => Tag::Exit,
            6 => Tag::TrainStats,
            7 => Tag::AggGradients,
            8 => Tag::Ping,
            9 => Tag::RingChunk,
            10 => Tag::Bcast,
            11 => Tag::TreeReduce,
            12 => Tag::TreeBcast,
            13 => Tag::GroupGather,
            14 => Tag::GroupChunk,
            15 => Tag::GroupBcast,
            16 => Tag::ElasticSuspect,
            17 => Tag::ElasticProbe,
            18 => Tag::ElasticAlive,
            19 => Tag::ElasticPlan,
            20 => Tag::ElasticJoin,
            v if (BUCKET_TAG_BASE
                ..BUCKET_TAG_BASE + MAX_BUCKETS * BUCKET_PHASES)
                .contains(&v) =>
            {
                let rel = v - BUCKET_TAG_BASE;
                Tag::Bucket {
                    bucket: (rel / BUCKET_PHASES) as u16,
                    phase: BucketPhase::from_u32(rel % BUCKET_PHASES)?,
                }
            }
            v if v == SERVE_TAG_BASE => Tag::ServeRequest,
            v if v == SERVE_TAG_BASE + 1 => Tag::ServeReply,
            v if v == PROBE_TAG_BASE => Tag::ProbePing,
            v if v == PROBE_TAG_BASE + 1 => Tag::ProbePong,
            _ => return None,
        })
    }
}

/// Worker progress statistics piggybacked to the master.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WorkerStats {
    pub epoch: u32,
    pub batches_done: u64,
    pub samples_done: u64,
    pub train_loss: f32,
    pub grad_time_s: f64,
    pub comm_wait_s: f64,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    Empty,
    /// Flat f32 buffer (weights or center) + the sender's model step.
    /// `Arc` so the master can snapshot once and fan out to many workers
    /// (sync barrier, handshakes) without re-copying megabyte payloads —
    /// the in-process transport then moves only the refcount
    /// (perf pass iter 2, EXPERIMENTS.md §Perf).
    Floats { step: u64, data: std::sync::Arc<Vec<f32>> },
    Stats(WorkerStats),
    /// A gradient: the worker's base weight step (for staleness
    /// accounting) + the batch training loss + the flat gradient.
    Grad { step: u64, loss: f32, data: Vec<f32> },
    /// A codec-compressed float buffer standing in for `Floats` or
    /// `Grad` (weight replicas carry `loss = 0.0`). `Arc` so ring
    /// all-gather hops forward one owner-compressed payload verbatim.
    Packed {
        step: u64,
        loss: f32,
        data: std::sync::Arc<crate::mpi::codec::PackedF32>,
    },
}

impl Payload {
    pub fn floats(step: u64, data: Vec<f32>) -> Self {
        Payload::Floats { step, data: std::sync::Arc::new(data) }
    }

    /// Fan-out constructor: share an existing snapshot.
    pub fn floats_shared(step: u64, data: std::sync::Arc<Vec<f32>>)
        -> Self {
        Payload::Floats { step, data }
    }

    pub fn grad(step: u64, loss: f32, data: Vec<f32>) -> Self {
        Payload::Grad { step, loss, data }
    }

    pub fn packed(step: u64, loss: f32,
                  data: crate::mpi::codec::PackedF32) -> Self {
        Payload::Packed { step, loss, data: std::sync::Arc::new(data) }
    }

    /// View a weight-like payload (`Floats` or `Packed`) as
    /// (step, dense data), decoding the compressed form if needed.
    /// `None` for payloads that carry no float buffer.
    pub fn weights_like(self)
        -> Option<(u64, std::sync::Arc<Vec<f32>>)> {
        match self {
            Payload::Floats { step, data } => Some((step, data)),
            Payload::Packed { step, data, .. } => {
                Some((step, std::sync::Arc::new(data.unpack())))
            }
            _ => None,
        }
    }

    /// View a gradient-like payload (`Grad` or `Packed`) as
    /// (step, loss, dense gradient), decoding if needed.
    pub fn grad_like(self) -> Option<(u64, f32, Vec<f32>)> {
        match self {
            Payload::Grad { step, loss, data } => {
                Some((step, loss, data))
            }
            Payload::Packed { step, loss, data } => {
                Some((step, loss, data.unpack()))
            }
            _ => None,
        }
    }

    fn kind(&self) -> u32 {
        match self {
            Payload::Empty => 0,
            Payload::Floats { .. } => 1,
            Payload::Stats(_) => 2,
            Payload::Grad { .. } => 3,
            Payload::Packed { .. } => 4,
        }
    }

    /// Exact wire size (used by the simulator's cost model, the comm
    /// byte counters, and the bench-smoke CI gate).
    pub fn nbytes(&self) -> usize {
        16 + match self {
            Payload::Empty => 0,
            Payload::Floats { data, .. } => 8 + data.len() * 4,
            Payload::Stats(_) => 40,
            Payload::Grad { data, .. } => 12 + data.len() * 4,
            Payload::Packed { data, .. } => 12 + data.wire_nbytes(),
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct Envelope {
    pub src: Rank,
    pub tag: Tag,
    pub payload: Payload,
}

// ---------------------------------------------------------------------------
// wire encoding
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub enum WireError {
    Truncated { need: usize, have: usize },
    UnknownTag(u32),
    UnknownKind(u32),
    /// Unknown codec encoding id in a `Packed` payload.
    UnknownEnc(u32),
    /// Structurally invalid `Packed` body (e.g. sparse index >= n).
    Corrupt(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(f, "frame truncated: need {need} bytes, have {have}")
            }
            WireError::UnknownTag(t) => write!(f, "unknown tag {t}"),
            WireError::UnknownKind(k) => {
                write!(f, "unknown payload kind {k}")
            }
            WireError::UnknownEnc(e) => {
                write!(f, "unknown packed encoding {e}")
            }
            WireError::Corrupt(msg) => {
                write!(f, "corrupt packed payload: {msg}")
            }
        }
    }
}

impl std::error::Error for WireError {}

/// Append a slice of plain-old-data values as little-endian bytes.
/// On little-endian hosts this is one bulk copy (the gradient hot
/// path); big-endian hosts fall back to per-element conversion.
macro_rules! le_slice_io {
    ($write:ident, $read:ident, $ty:ty, $size:expr) => {
        pub(crate) fn $write(out: &mut Vec<u8>, data: &[$ty]) {
            #[cfg(target_endian = "little")]
            {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(
                        data.as_ptr() as *const u8, data.len() * $size)
                };
                out.extend_from_slice(bytes);
            }
            #[cfg(not(target_endian = "little"))]
            {
                for v in data {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }

        /// Decode the whole body as little-endian values (length must
        /// be a multiple of the element size; a ragged tail is
        /// dropped, which the callers' length checks rule out).
        pub(crate) fn $read(body: &[u8]) -> Vec<$ty> {
            body.chunks_exact($size)
                .map(|c| <$ty>::from_le_bytes(c.try_into().unwrap()))
                .collect()
        }
    };
}

le_slice_io!(write_f32_slice, read_f32_slice, f32, 4);
le_slice_io!(write_u16_slice, read_u16_slice, u16, 2);
le_slice_io!(write_u32_slice, read_u32_slice, u32, 4);

/// Encode (tag, payload) into a frame body (the TCP transport adds the
/// outer [u32 src][u64 len] header).
pub fn encode(tag: Tag, payload: &Payload) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.nbytes());
    encode_into(&mut out, tag, payload);
    out
}

/// [`encode`] into a caller-owned buffer: clears `out`, reserves the
/// exact frame size ([`Payload::nbytes`] counts the 16-byte header
/// too), then appends the frame. A pooled send buffer therefore
/// reallocates only when a payload outgrows every previous one —
/// steady-state training rounds encode with zero allocations (see
/// `transport::tcp`'s frame-buffer pool).
pub fn encode_into(out: &mut Vec<u8>, tag: Tag, payload: &Payload) {
    out.clear();
    out.reserve(payload.nbytes());
    encode_append(out, tag, payload);
}

/// Append the frame body to `out` without clearing — the TCP transport
/// prefixes its own `[u32 src][u64 body_len]` header in the same
/// buffer, so one pooled `Vec` holds the whole wire frame.
pub(crate) fn encode_append(out: &mut Vec<u8>, tag: Tag,
                            payload: &Payload) {
    use crate::mpi::codec::PackedF32;
    out.extend_from_slice(&tag.to_u32().to_le_bytes());
    out.extend_from_slice(&payload.kind().to_le_bytes());
    match payload {
        Payload::Empty => {
            out.extend_from_slice(&0u64.to_le_bytes());
        }
        Payload::Floats { step, data } => {
            out.extend_from_slice(&((8 + data.len() * 4) as u64)
                .to_le_bytes());
            out.extend_from_slice(&step.to_le_bytes());
            write_f32_slice(&mut out, data);
        }
        Payload::Stats(s) => {
            out.extend_from_slice(&40u64.to_le_bytes());
            out.extend_from_slice(&s.epoch.to_le_bytes());
            out.extend_from_slice(&s.train_loss.to_le_bytes());
            out.extend_from_slice(&s.batches_done.to_le_bytes());
            out.extend_from_slice(&s.samples_done.to_le_bytes());
            out.extend_from_slice(&s.grad_time_s.to_le_bytes());
            out.extend_from_slice(&s.comm_wait_s.to_le_bytes());
        }
        Payload::Grad { step, loss, data } => {
            out.extend_from_slice(&((12 + data.len() * 4) as u64)
                .to_le_bytes());
            out.extend_from_slice(&step.to_le_bytes());
            out.extend_from_slice(&loss.to_le_bytes());
            write_f32_slice(&mut out, data);
        }
        Payload::Packed { step, loss, data } => {
            out.extend_from_slice(
                &((12 + data.wire_nbytes()) as u64).to_le_bytes());
            out.extend_from_slice(&step.to_le_bytes());
            out.extend_from_slice(&loss.to_le_bytes());
            match data.as_ref() {
                PackedF32::F16(bits) => {
                    out.extend_from_slice(&1u32.to_le_bytes());
                    out.extend_from_slice(
                        &(bits.len() as u32).to_le_bytes());
                    write_u16_slice(&mut out, bits);
                }
                PackedF32::Sparse { n, idx, val } => {
                    out.extend_from_slice(&2u32.to_le_bytes());
                    out.extend_from_slice(&n.to_le_bytes());
                    out.extend_from_slice(
                        &(idx.len() as u32).to_le_bytes());
                    write_u32_slice(&mut out, idx);
                    write_f32_slice(&mut out, val);
                }
            }
        }
    }
}

/// Decode the `Packed` kind's body (after step + loss).
fn decode_packed(body: &[u8])
    -> Result<crate::mpi::codec::PackedF32, WireError> {
    use crate::mpi::codec::PackedF32;
    if body.len() < 8 {
        return Err(WireError::Truncated { need: 8, have: body.len() });
    }
    let enc = u32::from_le_bytes(body[0..4].try_into().unwrap());
    let n = u32::from_le_bytes(body[4..8].try_into().unwrap()) as usize;
    let rest = &body[8..];
    match enc {
        1 => {
            if rest.len() != 2 * n {
                return Err(WireError::Truncated { need: 8 + 2 * n,
                                                  have: body.len() });
            }
            Ok(PackedF32::F16(read_u16_slice(rest)))
        }
        2 => {
            if rest.len() < 4 {
                return Err(WireError::Truncated { need: 12,
                                                  have: body.len() });
            }
            let nnz =
                u32::from_le_bytes(rest[0..4].try_into().unwrap())
                    as usize;
            if nnz > n {
                return Err(WireError::Corrupt("sparse nnz > n"));
            }
            if rest.len() != 4 + 8 * nnz {
                return Err(WireError::Truncated {
                    need: 12 + 8 * nnz,
                    have: body.len(),
                });
            }
            let idx = read_u32_slice(&rest[4..4 + 4 * nnz]);
            if idx.iter().any(|&i| i as usize >= n) {
                return Err(WireError::Corrupt(
                    "sparse index out of range"));
            }
            let val = read_f32_slice(&rest[4 + 4 * nnz..]);
            Ok(PackedF32::Sparse { n: n as u32, idx, val })
        }
        e => Err(WireError::UnknownEnc(e)),
    }
}

pub fn decode(buf: &[u8]) -> Result<(Tag, Payload), WireError> {
    let need = 16usize;
    if buf.len() < need {
        return Err(WireError::Truncated { need, have: buf.len() });
    }
    let tag_v = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    let kind = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    let nbytes = u64::from_le_bytes(buf[8..16].try_into().unwrap()) as usize;
    if buf.len() < 16 + nbytes {
        return Err(WireError::Truncated { need: 16 + nbytes,
                                          have: buf.len() });
    }
    let tag = Tag::from_u32(tag_v).ok_or(WireError::UnknownTag(tag_v))?;
    let body = &buf[16..16 + nbytes];
    let payload = match kind {
        0 => Payload::Empty,
        1 => {
            if body.len() < 8 {
                return Err(WireError::Truncated { need: 8,
                                                  have: body.len() });
            }
            let step = u64::from_le_bytes(body[0..8].try_into().unwrap());
            let data = read_f32_slice(&body[8..]);
            Payload::Floats { step, data: std::sync::Arc::new(data) }
        }
        2 => {
            if body.len() < 40 {
                return Err(WireError::Truncated { need: 40,
                                                  have: body.len() });
            }
            Payload::Stats(WorkerStats {
                epoch: u32::from_le_bytes(body[0..4].try_into().unwrap()),
                train_loss: f32::from_le_bytes(body[4..8].try_into()
                    .unwrap()),
                batches_done: u64::from_le_bytes(body[8..16].try_into()
                    .unwrap()),
                samples_done: u64::from_le_bytes(body[16..24].try_into()
                    .unwrap()),
                grad_time_s: f64::from_le_bytes(body[24..32].try_into()
                    .unwrap()),
                comm_wait_s: f64::from_le_bytes(body[32..40].try_into()
                    .unwrap()),
            })
        }
        3 => {
            if body.len() < 12 {
                return Err(WireError::Truncated { need: 12,
                                                  have: body.len() });
            }
            let step = u64::from_le_bytes(body[0..8].try_into().unwrap());
            let loss = f32::from_le_bytes(body[8..12].try_into().unwrap());
            let data = read_f32_slice(&body[12..]);
            Payload::Grad { step, loss, data }
        }
        4 => {
            if body.len() < 12 {
                return Err(WireError::Truncated { need: 12,
                                                  have: body.len() });
            }
            let step = u64::from_le_bytes(body[0..8].try_into().unwrap());
            let loss = f32::from_le_bytes(body[8..12].try_into().unwrap());
            let data = decode_packed(&body[12..])?;
            Payload::Packed { step, loss,
                              data: std::sync::Arc::new(data) }
        }
        k => return Err(WireError::UnknownKind(k)),
    };
    Ok((tag, payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_empty() {
        let buf = encode(Tag::Exit, &Payload::Empty);
        let (tag, p) = decode(&buf).unwrap();
        assert_eq!(tag, Tag::Exit);
        assert_eq!(p, Payload::Empty);
    }

    #[test]
    fn roundtrip_floats() {
        let data: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5).collect();
        let p = Payload::floats(42, data.clone());
        let buf = encode(Tag::Gradients, &p);
        let (tag, q) = decode(&buf).unwrap();
        assert_eq!(tag, Tag::Gradients);
        assert_eq!(q, p);
        assert_eq!(buf.len(), 16 + 8 + 4000);
    }

    #[test]
    fn roundtrip_stats() {
        let s = WorkerStats {
            epoch: 3,
            batches_done: 950,
            samples_done: 95_000,
            train_loss: 0.72,
            grad_time_s: 12.5,
            comm_wait_s: 1.25,
        };
        let buf = encode(Tag::TrainStats, &Payload::Stats(s));
        let (tag, q) = decode(&buf).unwrap();
        assert_eq!(tag, Tag::TrainStats);
        assert_eq!(q, Payload::Stats(s));
    }

    #[test]
    fn truncation_detected() {
        let buf = encode(Tag::Gradients, &Payload::floats(0, vec![1.0; 8]));
        for cut in [0, 8, 15, 20, buf.len() - 1] {
            assert!(decode(&buf[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn unknown_tag_rejected() {
        let mut buf = encode(Tag::Ping, &Payload::Empty);
        buf[0] = 0xFF;
        assert!(matches!(decode(&buf), Err(WireError::UnknownTag(_))));
    }

    #[test]
    fn collective_tags_roundtrip() {
        for tag in [Tag::RingChunk, Tag::Bcast, Tag::TreeReduce,
                    Tag::TreeBcast, Tag::GroupGather, Tag::GroupChunk,
                    Tag::GroupBcast] {
            let p = Payload::floats(3, vec![0.5, 1.5]);
            let (t2, p2) = decode(&encode(tag, &p)).unwrap();
            assert_eq!(t2, tag);
            assert_eq!(p2, p);
            assert_eq!(Tag::from_u32(tag.to_u32()), Some(tag));
        }
    }

    #[test]
    fn bucket_tags_roundtrip() {
        use crate::mpi::tags::{BUCKET_PHASES, BUCKET_TAG_BASE,
                               MAX_BUCKETS};
        let phases = [BucketPhase::Chunk, BucketPhase::Gather,
                      BucketPhase::TreeReduce, BucketPhase::TreeBcast,
                      BucketPhase::Bcast];
        assert_eq!(phases.len() as u32, BUCKET_PHASES);
        let mut seen = std::collections::HashSet::new();
        for bucket in 0..MAX_BUCKETS as u16 {
            for phase in phases {
                let tag = Tag::Bucket { bucket, phase };
                let v = tag.to_u32();
                assert!(v >= BUCKET_TAG_BASE);
                assert!(seen.insert(v), "duplicate wire value {v}");
                assert_eq!(Tag::from_u32(v), Some(tag));
                let p = Payload::floats(7, vec![0.25, -1.0]);
                let (t2, p2) = decode(&encode(tag, &p)).unwrap();
                assert_eq!(t2, tag);
                assert_eq!(p2, p);
            }
        }
        // the lane just past the bucket block belongs to the serving
        // RPC pair, the pair past THAT to the planner's probe, and the
        // lane past the probe block is unassigned
        use crate::mpi::tags::{PROBE_TAGS, PROBE_TAG_BASE, SERVE_TAGS,
                               SERVE_TAG_BASE};
        assert_eq!(BUCKET_TAG_BASE + MAX_BUCKETS * BUCKET_PHASES,
                   SERVE_TAG_BASE);
        assert_eq!(Tag::from_u32(SERVE_TAG_BASE + SERVE_TAGS),
                   Some(Tag::ProbePing));
        assert_eq!(Tag::from_u32(PROBE_TAG_BASE + PROBE_TAGS), None);
    }

    #[test]
    fn serve_tags_roundtrip() {
        use crate::mpi::tags::{SERVE_TAGS, SERVE_TAG_BASE};
        let lanes = [Tag::ServeRequest, Tag::ServeReply];
        assert_eq!(lanes.len() as u32, SERVE_TAGS);
        for (i, tag) in lanes.into_iter().enumerate() {
            assert_eq!(tag.to_u32(), SERVE_TAG_BASE + i as u32);
            assert_eq!(Tag::from_u32(tag.to_u32()), Some(tag));
            let p = Payload::floats(11, vec![0.5, -0.25, 3.0]);
            let (t2, p2) = decode(&encode(tag, &p)).unwrap();
            assert_eq!(t2, tag);
            assert_eq!(p2, p);
        }
    }

    #[test]
    fn probe_tags_roundtrip() {
        use crate::mpi::tags::{PROBE_TAGS, PROBE_TAG_BASE};
        let lanes = [Tag::ProbePing, Tag::ProbePong];
        assert_eq!(lanes.len() as u32, PROBE_TAGS);
        for (i, tag) in lanes.into_iter().enumerate() {
            assert_eq!(tag.to_u32(), PROBE_TAG_BASE + i as u32);
            assert_eq!(Tag::from_u32(tag.to_u32()), Some(tag));
            let p = Payload::floats(13, vec![0.0; 64]);
            let (t2, p2) = decode(&encode(tag, &p)).unwrap();
            assert_eq!(t2, tag);
            assert_eq!(p2, p);
        }
    }

    #[test]
    fn elastic_tags_roundtrip() {
        let lanes = [Tag::ElasticSuspect, Tag::ElasticProbe,
                     Tag::ElasticAlive, Tag::ElasticPlan,
                     Tag::ElasticJoin];
        for (i, tag) in lanes.into_iter().enumerate() {
            assert_eq!(tag.to_u32(), 16 + i as u32);
            assert_eq!(Tag::from_u32(tag.to_u32()), Some(tag));
            let p = Payload::floats(1 << 32, vec![3.0, 7.0]);
            let (t2, p2) = decode(&encode(tag, &p)).unwrap();
            assert_eq!(t2, tag);
            assert_eq!(p2, p);
        }
        // the elastic block sits directly below the bucket block
        use crate::mpi::tags::BUCKET_TAG_BASE;
        assert_eq!(Tag::ElasticJoin.to_u32() + 1, BUCKET_TAG_BASE);
    }

    #[test]
    fn nbytes_matches_encoding() {
        use crate::mpi::codec::Codec;
        for p in [
            Payload::Empty,
            Payload::floats(1, vec![0.5; 123]),
            Payload::Stats(WorkerStats::default()),
            Payload::grad(2, 0.5, vec![1.0; 17]),
            Payload::packed(3, 0.25,
                            Codec::Fp16.pack(&[0.5; 9]).unwrap()),
            Payload::packed(4, 0.0,
                            Codec::TopK { k: 0.3 }
                                .pack(&[1.0, -2.0, 0.0, 4.0, 0.5])
                                .unwrap()),
        ] {
            assert_eq!(encode(Tag::Ping, &p).len(), p.nbytes());
        }
    }

    /// The encoder must size its buffer exactly up front: encoding may
    /// never outgrow the initial capacity (a growth realloc in the hot
    /// send path would defeat the transport's buffer pool), and
    /// `encode_into` must reuse a warm buffer without reallocating.
    #[test]
    fn encode_never_outgrows_initial_capacity() {
        use crate::mpi::codec::Codec;
        let payloads = [
            Payload::Empty,
            Payload::floats(1, (0..501).map(|i| i as f32).collect()),
            Payload::Stats(WorkerStats::default()),
            Payload::grad(2, 0.5, vec![1.0; 333]),
            Payload::packed(3, 0.25,
                            Codec::Fp16.pack(&[0.5; 77]).unwrap()),
            Payload::packed(4, 0.0,
                            Codec::TopK { k: 0.1 }
                                .pack(&vec![1.0; 90]).unwrap()),
        ];
        for p in &payloads {
            let buf = encode(Tag::Gradients, p);
            assert_eq!(buf.len(), p.nbytes());
            assert_eq!(buf.capacity(), p.nbytes(),
                       "encode grew past its initial capacity");
        }
        // warm reuse: once the buffer holds the largest frame's
        // capacity, every further encode_into leaves it untouched
        let max = payloads.iter().map(|p| p.nbytes()).max().unwrap();
        let mut buf = Vec::with_capacity(max);
        let cap0 = buf.capacity();
        for p in payloads.iter().chain(payloads.iter().rev()) {
            encode_into(&mut buf, Tag::Gradients, p);
            assert_eq!(buf.len(), p.nbytes());
            assert_eq!(buf, encode(Tag::Gradients, p));
            assert_eq!(buf.capacity(), cap0,
                       "warm encode_into reallocated");
        }
    }

    #[test]
    fn roundtrip_packed_fp16() {
        use crate::mpi::codec::Codec;
        let data: Vec<f32> = (0..33).map(|i| i as f32 * 0.25 - 4.0)
            .collect();
        let p = Payload::packed(9, 1.5, Codec::Fp16.pack(&data).unwrap());
        let buf = encode(Tag::Gradients, &p);
        let (tag, q) = decode(&buf).unwrap();
        assert_eq!(tag, Tag::Gradients);
        assert_eq!(q, p);
        // fp16 wire: outer 16 + step 8 + loss 4 + enc/n header 8 + 2/elem
        assert_eq!(buf.len(), 16 + 12 + 8 + 2 * 33);
        match q.weights_like() {
            Some((step, dense)) => {
                assert_eq!(step, 9);
                assert_eq!(*dense, data); // quarter-steps are f16-exact
            }
            None => panic!("packed must decode as weights-like"),
        }
    }

    #[test]
    fn roundtrip_packed_sparse() {
        use crate::mpi::codec::Codec;
        let data = [0.0f32, -7.0, 0.0, 0.0, 2.5, 0.0];
        let p = Payload::packed(
            5, 0.75, Codec::TopK { k: 0.34 }.pack(&data).unwrap());
        let buf = encode(Tag::Gradients, &p);
        let (tag, q) = decode(&buf).unwrap();
        assert_eq!(tag, Tag::Gradients);
        assert_eq!(q, p);
        match q.grad_like() {
            Some((step, loss, dense)) => {
                assert_eq!(step, 5);
                assert_eq!(loss, 0.75);
                assert_eq!(dense, data.to_vec());
            }
            None => panic!("packed must decode as grad-like"),
        }
        // truncation anywhere must error, never panic
        for cut in 0..buf.len() {
            assert!(decode(&buf[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn corrupt_packed_rejected() {
        use crate::mpi::codec::Codec;
        let p = Payload::packed(
            1, 0.0, Codec::TopK { k: 0.5 }.pack(&[1.0, 2.0]).unwrap());
        let buf = encode(Tag::Gradients, &p);
        // unknown encoding id
        let mut bad = buf.clone();
        bad[16 + 12] = 0x7F;
        assert!(matches!(decode(&bad), Err(WireError::UnknownEnc(_))));
        // sparse index out of range (idx array starts after
        // 16 outer + 12 step/loss + 8 enc/n + 4 nnz)
        let mut bad = buf.clone();
        bad[16 + 12 + 8 + 4] = 0xEE;
        assert!(matches!(decode(&bad), Err(WireError::Corrupt(_))));
    }

    #[test]
    fn f32_slice_helpers_roundtrip() {
        let data = [1.5f32, -0.25, f32::MIN_POSITIVE, 3.4e38];
        let mut buf = Vec::new();
        write_f32_slice(&mut buf, &data);
        assert_eq!(buf.len(), 16);
        assert_eq!(read_f32_slice(&buf), data.to_vec());
        // explicit little-endian byte order
        assert_eq!(&buf[0..4], &1.5f32.to_le_bytes());
    }

    #[test]
    fn roundtrip_grad() {
        let p = Payload::grad(99, 1.25, vec![0.5, -0.5, 2.0]);
        let buf = encode(Tag::Gradients, &p);
        let (tag, q) = decode(&buf).unwrap();
        assert_eq!(tag, Tag::Gradients);
        assert_eq!(q, p);
    }
}
