//! Message types and wire encoding for the MPI-style substrate.
//!
//! `mpi_learn` drives its whole protocol with tagged point-to-point
//! messages (mpi4py tags like `gradients`, `weights`, `train`, `exit`).
//! We mirror that: an [`Envelope`] is (source rank, [`Tag`], [`Payload`]).
//!
//! Payloads have a compact binary wire format (used verbatim by the TCP
//! transport; the in-process transport passes the enum directly):
//!
//! ```text
//! [u32 tag] [u32 kind] [u64 nbytes] [payload bytes...]
//! ```
//! Float payloads are little-endian f32; the `Stats` payload is a small
//! fixed struct. CRC is delegated to TCP's checksum; the frame length is
//! validated on decode.

pub type Rank = usize;

/// Protocol tags (superset of mpi_learn's).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(u32)]
pub enum Tag {
    /// worker -> master: ready to train, send me initial weights
    Ready = 0,
    /// worker -> master: gradient payload (Downpour)
    Gradients = 1,
    /// master -> worker: full weight payload
    Weights = 2,
    /// worker -> master: EASGD weight exchange request (payload = worker weights)
    ExchangeWeights = 3,
    /// master -> worker: EASGD center variable
    Center = 4,
    /// master -> worker: stop training
    Exit = 5,
    /// worker -> master: per-epoch timing/progress stats
    TrainStats = 6,
    /// master -> parent master: hierarchical aggregated gradient
    AggGradients = 7,
    /// any -> any: liveness probe (comm microbench)
    Ping = 8,
    /// neighbor -> neighbor: ring all-reduce chunk (collective layer)
    RingChunk = 9,
    /// neighbor -> neighbor: ring broadcast payload (collective layer)
    Bcast = 10,
}

impl Tag {
    pub fn from_u32(v: u32) -> Option<Tag> {
        Some(match v {
            0 => Tag::Ready,
            1 => Tag::Gradients,
            2 => Tag::Weights,
            3 => Tag::ExchangeWeights,
            4 => Tag::Center,
            5 => Tag::Exit,
            6 => Tag::TrainStats,
            7 => Tag::AggGradients,
            8 => Tag::Ping,
            9 => Tag::RingChunk,
            10 => Tag::Bcast,
            _ => return None,
        })
    }
}

/// Worker progress statistics piggybacked to the master.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WorkerStats {
    pub epoch: u32,
    pub batches_done: u64,
    pub samples_done: u64,
    pub train_loss: f32,
    pub grad_time_s: f64,
    pub comm_wait_s: f64,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    Empty,
    /// Flat f32 buffer (weights or center) + the sender's model step.
    /// `Arc` so the master can snapshot once and fan out to many workers
    /// (sync barrier, handshakes) without re-copying megabyte payloads —
    /// the in-process transport then moves only the refcount
    /// (perf pass iter 2, EXPERIMENTS.md §Perf).
    Floats { step: u64, data: std::sync::Arc<Vec<f32>> },
    Stats(WorkerStats),
    /// A gradient: the worker's base weight step (for staleness
    /// accounting) + the batch training loss + the flat gradient.
    Grad { step: u64, loss: f32, data: Vec<f32> },
}

impl Payload {
    pub fn floats(step: u64, data: Vec<f32>) -> Self {
        Payload::Floats { step, data: std::sync::Arc::new(data) }
    }

    /// Fan-out constructor: share an existing snapshot.
    pub fn floats_shared(step: u64, data: std::sync::Arc<Vec<f32>>)
        -> Self {
        Payload::Floats { step, data }
    }

    pub fn grad(step: u64, loss: f32, data: Vec<f32>) -> Self {
        Payload::Grad { step, loss, data }
    }

    fn kind(&self) -> u32 {
        match self {
            Payload::Empty => 0,
            Payload::Floats { .. } => 1,
            Payload::Stats(_) => 2,
            Payload::Grad { .. } => 3,
        }
    }

    /// Approximate wire size (used by the simulator's cost model and the
    /// comm microbench).
    pub fn nbytes(&self) -> usize {
        16 + match self {
            Payload::Empty => 0,
            Payload::Floats { data, .. } => 8 + data.len() * 4,
            Payload::Stats(_) => 40,
            Payload::Grad { data, .. } => 12 + data.len() * 4,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct Envelope {
    pub src: Rank,
    pub tag: Tag,
    pub payload: Payload,
}

// ---------------------------------------------------------------------------
// wire encoding
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub enum WireError {
    Truncated { need: usize, have: usize },
    UnknownTag(u32),
    UnknownKind(u32),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(f, "frame truncated: need {need} bytes, have {have}")
            }
            WireError::UnknownTag(t) => write!(f, "unknown tag {t}"),
            WireError::UnknownKind(k) => {
                write!(f, "unknown payload kind {k}")
            }
        }
    }
}

impl std::error::Error for WireError {}

/// Encode (tag, payload) into a frame body (the TCP transport adds the
/// outer [u32 src][u64 len] header).
pub fn encode(tag: Tag, payload: &Payload) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.nbytes());
    out.extend_from_slice(&(tag as u32).to_le_bytes());
    out.extend_from_slice(&payload.kind().to_le_bytes());
    match payload {
        Payload::Empty => {
            out.extend_from_slice(&0u64.to_le_bytes());
        }
        Payload::Floats { step, data } => {
            out.extend_from_slice(&((8 + data.len() * 4) as u64)
                .to_le_bytes());
            out.extend_from_slice(&step.to_le_bytes());
            // bulk little-endian f32 copy
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(
                    data.as_ptr() as *const u8, data.len() * 4)
            };
            out.extend_from_slice(bytes);
        }
        Payload::Stats(s) => {
            out.extend_from_slice(&40u64.to_le_bytes());
            out.extend_from_slice(&s.epoch.to_le_bytes());
            out.extend_from_slice(&s.train_loss.to_le_bytes());
            out.extend_from_slice(&s.batches_done.to_le_bytes());
            out.extend_from_slice(&s.samples_done.to_le_bytes());
            out.extend_from_slice(&s.grad_time_s.to_le_bytes());
            out.extend_from_slice(&s.comm_wait_s.to_le_bytes());
        }
        Payload::Grad { step, loss, data } => {
            out.extend_from_slice(&((12 + data.len() * 4) as u64)
                .to_le_bytes());
            out.extend_from_slice(&step.to_le_bytes());
            out.extend_from_slice(&loss.to_le_bytes());
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(
                    data.as_ptr() as *const u8, data.len() * 4)
            };
            out.extend_from_slice(bytes);
        }
    }
    out
}

pub fn decode(buf: &[u8]) -> Result<(Tag, Payload), WireError> {
    let need = 16usize;
    if buf.len() < need {
        return Err(WireError::Truncated { need, have: buf.len() });
    }
    let tag_v = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    let kind = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    let nbytes = u64::from_le_bytes(buf[8..16].try_into().unwrap()) as usize;
    if buf.len() < 16 + nbytes {
        return Err(WireError::Truncated { need: 16 + nbytes,
                                          have: buf.len() });
    }
    let tag = Tag::from_u32(tag_v).ok_or(WireError::UnknownTag(tag_v))?;
    let body = &buf[16..16 + nbytes];
    let payload = match kind {
        0 => Payload::Empty,
        1 => {
            if body.len() < 8 {
                return Err(WireError::Truncated { need: 8,
                                                  have: body.len() });
            }
            let step = u64::from_le_bytes(body[0..8].try_into().unwrap());
            let data: Vec<f32> = body[8..]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Payload::Floats { step, data: std::sync::Arc::new(data) }
        }
        2 => {
            if body.len() < 40 {
                return Err(WireError::Truncated { need: 40,
                                                  have: body.len() });
            }
            Payload::Stats(WorkerStats {
                epoch: u32::from_le_bytes(body[0..4].try_into().unwrap()),
                train_loss: f32::from_le_bytes(body[4..8].try_into()
                    .unwrap()),
                batches_done: u64::from_le_bytes(body[8..16].try_into()
                    .unwrap()),
                samples_done: u64::from_le_bytes(body[16..24].try_into()
                    .unwrap()),
                grad_time_s: f64::from_le_bytes(body[24..32].try_into()
                    .unwrap()),
                comm_wait_s: f64::from_le_bytes(body[32..40].try_into()
                    .unwrap()),
            })
        }
        3 => {
            if body.len() < 12 {
                return Err(WireError::Truncated { need: 12,
                                                  have: body.len() });
            }
            let step = u64::from_le_bytes(body[0..8].try_into().unwrap());
            let loss = f32::from_le_bytes(body[8..12].try_into().unwrap());
            let data = body[12..]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Payload::Grad { step, loss, data }
        }
        k => return Err(WireError::UnknownKind(k)),
    };
    Ok((tag, payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_empty() {
        let buf = encode(Tag::Exit, &Payload::Empty);
        let (tag, p) = decode(&buf).unwrap();
        assert_eq!(tag, Tag::Exit);
        assert_eq!(p, Payload::Empty);
    }

    #[test]
    fn roundtrip_floats() {
        let data: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5).collect();
        let p = Payload::floats(42, data.clone());
        let buf = encode(Tag::Gradients, &p);
        let (tag, q) = decode(&buf).unwrap();
        assert_eq!(tag, Tag::Gradients);
        assert_eq!(q, p);
        assert_eq!(buf.len(), 16 + 8 + 4000);
    }

    #[test]
    fn roundtrip_stats() {
        let s = WorkerStats {
            epoch: 3,
            batches_done: 950,
            samples_done: 95_000,
            train_loss: 0.72,
            grad_time_s: 12.5,
            comm_wait_s: 1.25,
        };
        let buf = encode(Tag::TrainStats, &Payload::Stats(s));
        let (tag, q) = decode(&buf).unwrap();
        assert_eq!(tag, Tag::TrainStats);
        assert_eq!(q, Payload::Stats(s));
    }

    #[test]
    fn truncation_detected() {
        let buf = encode(Tag::Gradients, &Payload::floats(0, vec![1.0; 8]));
        for cut in [0, 8, 15, 20, buf.len() - 1] {
            assert!(decode(&buf[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn unknown_tag_rejected() {
        let mut buf = encode(Tag::Ping, &Payload::Empty);
        buf[0] = 0xFF;
        assert!(matches!(decode(&buf), Err(WireError::UnknownTag(_))));
    }

    #[test]
    fn collective_tags_roundtrip() {
        for tag in [Tag::RingChunk, Tag::Bcast] {
            let p = Payload::floats(3, vec![0.5, 1.5]);
            let (t2, p2) = decode(&encode(tag, &p)).unwrap();
            assert_eq!(t2, tag);
            assert_eq!(p2, p);
            assert_eq!(Tag::from_u32(tag as u32), Some(tag));
        }
    }

    #[test]
    fn nbytes_matches_encoding() {
        for p in [
            Payload::Empty,
            Payload::floats(1, vec![0.5; 123]),
            Payload::Stats(WorkerStats::default()),
            Payload::grad(2, 0.5, vec![1.0; 17]),
        ] {
            assert_eq!(encode(Tag::Ping, &p).len(), p.nbytes());
        }
    }

    #[test]
    fn roundtrip_grad() {
        let p = Payload::grad(99, 1.25, vec![0.5, -0.5, 2.0]);
        let buf = encode(Tag::Gradients, &p);
        let (tag, q) = decode(&buf).unwrap();
        assert_eq!(tag, Tag::Gradients);
        assert_eq!(q, p);
    }
}
