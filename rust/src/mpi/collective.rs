//! Collective communication over the tagged point-to-point substrate.
//!
//! The parameter-server master is the scalability wall the paper itself
//! measures (Figs 3/4): every gradient serializes through one rank. The
//! standard way past it (Vishnu et al., *Distributed TensorFlow with
//! MPI*; Awan et al., *HyPar-Flow*) is masterless collectives. This
//! module implements the classic **chunked ring all-reduce**
//! (reduce-scatter + all-gather, bandwidth-optimal `2(n-1)/n` payload
//! volume per rank) and a ring **broadcast**, built purely from `Comm`'s
//! tagged sends — so they run unchanged on both the inproc and TCP
//! transports.
//!
//! Determinism: each vector element's reduction is computed exactly once,
//! on a single rank, in a schedule-independent order fixed by the ring
//! topology, then replicated byte-for-byte by the all-gather. All ranks
//! therefore finish with **bitwise identical** buffers regardless of
//! thread/network timing — the property the all-reduce training mode's
//! replicated optimizer relies on.
//!
//! Collectives compose with ordinary protocol traffic: an envelope that
//! is not the expected chunk (e.g. a `TrainStats` racing into rank 0
//! while it is inside an all-reduce) is stashed and re-delivered to the
//! caller afterwards ([`Collective::into_stash`]).
//!
//! **Compression** ([`Collective::set_codec`]): with a lossy codec, sum
//! all-reduces compress every wire hop while keeping the determinism
//! guarantee. The reduce-scatter reduces *decoded* f32 along the ring's
//! fixed chain (each hop compresses its partial sums with an
//! error-feedback residual, so dropped mass re-enters the next round);
//! the all-gather compresses each completed chunk ONCE on its owner —
//! which adopts the decoded form itself — and forwards that payload
//! verbatim, so every rank decodes identical bytes. Min/Max reductions,
//! scalar agreements, and broadcasts always go raw, and the last
//! [`Collective::set_exact_tail`] elements are exempt from top-k
//! dropping (piggybacked control flags must never vanish).

use std::time::Duration;

use crate::mpi::codec::{Codec, Compressor};
use crate::mpi::comm::{Comm, CommError};
use crate::mpi::message::{Envelope, Payload, Rank, Tag};

/// Default bound on waiting for a ring neighbor. A peer that dies
/// mid-collective can never be detected by disconnect alone (other
/// ranks keep the receive channel alive), so without a bound one failed
/// rank would hang the whole world forever; with it, the survivors
/// surface `CommError::Timeout` and the driver reports the failure.
/// Generous enough that validation pauses and big payloads never trip it.
const DEFAULT_RECV_TIMEOUT: Duration = Duration::from_secs(300);

/// Element-wise reduction operator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Min,
    Max,
}

impl ReduceOp {
    fn apply(self, dst: &mut f32, src: f32) {
        match self {
            ReduceOp::Sum => *dst += src,
            ReduceOp::Min => *dst = dst.min(src),
            ReduceOp::Max => *dst = dst.max(src),
        }
    }
}

/// Per-rank collective endpoint: wraps a [`Comm`] with the stash needed
/// to keep ring traffic and unrelated protocol messages untangled.
pub struct Collective<'a> {
    comm: &'a Comm,
    stash: Vec<Envelope>,
    seq: u64,
    recv_timeout: Duration,
    codec: Codec,
    /// Error-feedback state for compressed hops (one residual slot per
    /// element index; see the module docs).
    compressor: Compressor,
    /// Trailing elements exempt from lossy dropping (stop flags, loss).
    exact_tail: usize,
}

impl<'a> Collective<'a> {
    pub fn new(comm: &'a Comm) -> Self {
        Self {
            comm,
            stash: Vec::new(),
            seq: 0,
            recv_timeout: DEFAULT_RECV_TIMEOUT,
            codec: Codec::Fp32,
            compressor: Compressor::new(Codec::Fp32),
            exact_tail: 0,
        }
    }

    /// Override the neighbor-wait bound (see [`DEFAULT_RECV_TIMEOUT`]).
    pub fn set_recv_timeout(&mut self, timeout: Duration) {
        self.recv_timeout = timeout;
    }

    /// Compress sum all-reduce wire hops with `codec` (resets the
    /// error-feedback residual). All ranks of a world must configure
    /// the same codec — chunks are decoded by shape, not negotiated.
    pub fn set_codec(&mut self, codec: Codec) {
        self.codec = codec;
        self.compressor = Compressor::new(codec);
    }

    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Exempt the last `n` elements of every compressed all-reduce
    /// from lossy dropping (piggybacked control values).
    pub fn set_exact_tail(&mut self, n: usize) {
        self.exact_tail = n;
    }

    pub fn comm(&self) -> &Comm {
        self.comm
    }

    /// Non-collective envelopes observed mid-collective, in arrival
    /// order. The owner should drain these (e.g. via
    /// [`Comm::recv_tag`]'s stash argument) after the last collective.
    pub fn into_stash(self) -> Vec<Envelope> {
        self.stash
    }

    fn next_rank(&self) -> Rank {
        (self.comm.rank() + 1) % self.comm.size()
    }

    fn prev_rank(&self) -> Rank {
        (self.comm.rank() + self.comm.size() - 1) % self.comm.size()
    }

    /// Bounds of balanced chunk `i` when a length-`len` vector is split
    /// `n` ways: the first `len % n` chunks get one extra element, so
    /// non-divisible lengths (and `len < n`, where trailing chunks are
    /// empty) need no padding.
    pub fn chunk_bounds(len: usize, n: usize, i: usize) -> (usize, usize) {
        let base = len / n;
        let rem = len % n;
        let start = i * base + i.min(rem);
        let end = start + base + usize::from(i < rem);
        (start, end)
    }

    fn send_chunk(&mut self, to: Rank, tag: Tag, data: &[f32])
        -> Result<(), CommError> {
        self.seq += 1;
        self.comm.send(to, tag, Payload::floats(self.seq, data.to_vec()))
    }

    /// Receive the next `tag` envelope from `from`, stashing any
    /// unrelated traffic (ring lockstep: wrong-source chunks are a
    /// protocol violation).
    fn recv_from(&mut self, tag: Tag, from: Rank)
        -> Result<Envelope, CommError> {
        loop {
            if let Some(i) = self
                .stash
                .iter()
                .position(|e| e.tag == tag && e.src == from)
            {
                return Ok(self.stash.remove(i));
            }
            let env = self.comm.recv_timeout(self.recv_timeout)?;
            if env.tag == tag {
                if env.src != from {
                    return Err(CommError::Protocol(format!(
                        "collective: {tag:?} from rank {} (expected \
                         ring neighbor {from})",
                        env.src
                    )));
                }
                return Ok(env);
            }
            self.stash.push(env);
        }
    }

    /// Receive the next `tag` float payload from `from`. `expect_len`
    /// of `Some(k)` validates the chunk length (ring lockstep
    /// invariant).
    fn recv_floats(&mut self, tag: Tag, from: Rank,
                   expect_len: Option<usize>)
        -> Result<std::sync::Arc<Vec<f32>>, CommError> {
        let env = self.recv_from(tag, from)?;
        Self::unwrap_floats(env, expect_len)
    }

    /// Receive a raw-or-compressed chunk of exactly `expect_len`
    /// logical elements.
    fn recv_chunk(&mut self, tag: Tag, from: Rank, expect_len: usize)
        -> Result<Payload, CommError> {
        let env = self.recv_from(tag, from)?;
        let got = match &env.payload {
            Payload::Floats { data, .. } => data.len(),
            Payload::Packed { data, .. } => data.len(),
            other => {
                return Err(CommError::Protocol(format!(
                    "collective: non-float payload {other:?} from \
                     rank {}",
                    env.src
                )))
            }
        };
        if got != expect_len {
            return Err(CommError::Protocol(format!(
                "collective: chunk length {got} from rank {} \
                 (expected {expect_len})",
                env.src
            )));
        }
        Ok(env.payload)
    }

    fn unwrap_floats(env: Envelope, expect_len: Option<usize>)
        -> Result<std::sync::Arc<Vec<f32>>, CommError> {
        match env.payload {
            Payload::Floats { data, .. } => {
                if let Some(want) = expect_len {
                    if data.len() != want {
                        return Err(CommError::Protocol(format!(
                            "collective: chunk length {} from rank {} \
                             (expected {want})",
                            data.len(),
                            env.src
                        )));
                    }
                }
                Ok(data)
            }
            other => Err(CommError::Protocol(format!(
                "collective: non-float payload {other:?} from rank {}",
                env.src
            ))),
        }
    }

    /// In-place chunked ring all-reduce: on return, `data` holds the
    /// element-wise `op`-reduction over every rank's input, identical
    /// (bitwise) on all ranks. Works for any `data.len()`, including
    /// lengths not divisible by — or smaller than — the world size.
    ///
    /// With a lossy codec configured ([`Collective::set_codec`]), sum
    /// reductions compress every wire hop (see the module docs); the
    /// bitwise-identical guarantee still holds. Min/Max always go raw
    /// (error feedback is a sum-space concept).
    ///
    /// All ranks must call this the same number of times with
    /// equal-length buffers (lockstep SPMD, like `MPI_Allreduce`).
    pub fn allreduce(&mut self, data: &mut [f32], op: ReduceOp)
        -> Result<(), CommError> {
        if self.comm.size() <= 1 {
            return Ok(());
        }
        if self.codec.is_identity() || op != ReduceOp::Sum {
            self.allreduce_raw(data, op)
        } else {
            self.allreduce_compressed(data)
        }
    }

    fn allreduce_raw(&mut self, data: &mut [f32], op: ReduceOp)
        -> Result<(), CommError> {
        let n = self.comm.size();
        let rank = self.comm.rank();
        let len = data.len();
        let next = self.next_rank();
        let prev = self.prev_rank();

        // Phase 1 — reduce-scatter: after step s, a rank holds the
        // partial reduction of s+1 ranks for chunk (rank - s) mod n;
        // after n-1 steps it owns the complete chunk (rank + 1) mod n.
        for step in 0..n - 1 {
            let send_idx = (rank + n - step) % n;
            let recv_idx = (rank + 2 * n - step - 1) % n;
            let (s0, s1) = Self::chunk_bounds(len, n, send_idx);
            self.send_chunk(next, Tag::RingChunk, &data[s0..s1])?;
            let (r0, r1) = Self::chunk_bounds(len, n, recv_idx);
            let chunk =
                self.recv_floats(Tag::RingChunk, prev, Some(r1 - r0))?;
            for (dst, &src) in data[r0..r1].iter_mut().zip(chunk.iter()) {
                op.apply(dst, src);
            }
        }

        // Phase 2 — all-gather: circulate the completed chunks.
        for step in 0..n - 1 {
            let send_idx = (rank + 1 + 2 * n - step) % n;
            let recv_idx = (rank + 2 * n - step) % n;
            let (s0, s1) = Self::chunk_bounds(len, n, send_idx);
            self.send_chunk(next, Tag::RingChunk, &data[s0..s1])?;
            let (r0, r1) = Self::chunk_bounds(len, n, recv_idx);
            let chunk =
                self.recv_floats(Tag::RingChunk, prev, Some(r1 - r0))?;
            data[r0..r1].copy_from_slice(&chunk);
        }
        Ok(())
    }

    /// How many trailing elements of chunk `[s0, s1)` fall inside the
    /// exact tail `[len - exact_tail, len)` (always a chunk suffix).
    fn protect_len(&self, len: usize, s0: usize, s1: usize) -> usize {
        let tail_start = len - self.exact_tail.min(len);
        s1.saturating_sub(s0.max(tail_start))
    }

    /// Sum all-reduce with compressed wire hops (see the module docs
    /// for why every rank still finishes bitwise identical).
    fn allreduce_compressed(&mut self, data: &mut [f32])
        -> Result<(), CommError> {
        let n = self.comm.size();
        let rank = self.comm.rank();
        let len = data.len();
        let next = self.next_rank();
        let prev = self.prev_rank();

        // Phase 1 — reduce-scatter over decoded f32: each hop
        // compresses its outgoing partial sums with error feedback
        // (what this round drops rides along next round).
        for step in 0..n - 1 {
            let send_idx = (rank + n - step) % n;
            let recv_idx = (rank + 2 * n - step - 1) % n;
            let (s0, s1) = Self::chunk_bounds(len, n, send_idx);
            let protect = self.protect_len(len, s0, s1);
            let packed = self
                .compressor
                .compress_window(&data[s0..s1], s0, len, protect)
                .expect("lossy codec packs");
            self.seq += 1;
            self.comm.send(next, Tag::RingChunk,
                           Payload::packed(self.seq, 0.0, packed))?;
            let (r0, r1) = Self::chunk_bounds(len, n, recv_idx);
            match self.recv_chunk(Tag::RingChunk, prev, r1 - r0)? {
                Payload::Packed { data: packed, .. } => {
                    packed.add_into(&mut data[r0..r1]);
                }
                Payload::Floats { data: chunk, .. } => {
                    for (dst, &src) in
                        data[r0..r1].iter_mut().zip(chunk.iter())
                    {
                        *dst += src;
                    }
                }
                _ => unreachable!("recv_chunk validates the kind"),
            }
        }

        // Phase 2 — all-gather: the chunk owner compresses its
        // completed chunk ONCE (adopting the decoded form itself, so
        // its replica matches everyone else's) and the payload is then
        // forwarded verbatim around the ring.
        let mut carry: Option<Payload> = None;
        for step in 0..n - 1 {
            let send_idx = (rank + 1 + 2 * n - step) % n;
            let recv_idx = (rank + 2 * n - step) % n;
            let payload = match carry.take() {
                Some(p) => p,
                None => {
                    // step 0: our own completed chunk
                    let (s0, s1) = Self::chunk_bounds(len, n, send_idx);
                    let protect = self.protect_len(len, s0, s1);
                    let packed = self
                        .compressor
                        .compress_window(&data[s0..s1], s0, len, protect)
                        .expect("lossy codec packs");
                    packed.unpack_into(&mut data[s0..s1]);
                    self.seq += 1;
                    Payload::packed(self.seq, 0.0, packed)
                }
            };
            self.comm.send(next, Tag::RingChunk, payload)?;
            let (r0, r1) = Self::chunk_bounds(len, n, recv_idx);
            let payload =
                self.recv_chunk(Tag::RingChunk, prev, r1 - r0)?;
            match &payload {
                Payload::Packed { data: packed, .. } => {
                    packed.unpack_into(&mut data[r0..r1]);
                }
                Payload::Floats { data: chunk, .. } => {
                    data[r0..r1].copy_from_slice(chunk);
                }
                _ => unreachable!("recv_chunk validates the kind"),
            }
            carry = Some(payload);
        }
        Ok(())
    }

    /// Single-value all-reduce convenience (e.g. agreeing on the common
    /// per-epoch round count via `ReduceOp::Min`). Exact for integral
    /// values below 2^24: scalar agreements are control-plane values,
    /// so they always travel raw regardless of the configured codec.
    pub fn allreduce_scalar(&mut self, value: f32, op: ReduceOp)
        -> Result<f32, CommError> {
        let mut buf = [value];
        if self.comm.size() > 1 {
            self.allreduce_raw(&mut buf, op)?;
        }
        Ok(buf[0])
    }

    /// Ring broadcast from `root`: each rank adopts the root's buffer.
    /// The payload travels the ring once as a shared `Arc`, so the
    /// inproc transport forwards it without re-copying.
    pub fn broadcast(&mut self, root: Rank, data: &mut Vec<f32>)
        -> Result<(), CommError> {
        let n = self.comm.size();
        if root >= n {
            return Err(CommError::InvalidRank { rank: root, size: n });
        }
        if n <= 1 {
            return Ok(());
        }
        let rank = self.comm.rank();
        let next = self.next_rank();
        self.seq += 1;
        if rank == root {
            self.comm.send(next, Tag::Bcast,
                           Payload::floats(self.seq, data.clone()))?;
        } else {
            let prev = self.prev_rank();
            let payload = self.recv_floats(Tag::Bcast, prev, None)?;
            data.clear();
            data.extend_from_slice(&payload);
            if next != root {
                self.comm.send(next, Tag::Bcast,
                               Payload::floats_shared(self.seq, payload))?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::inproc_world;
    use crate::mpi::message::WorkerStats;

    /// Reference reduction matching the ring's deterministic order:
    /// chunk `c` is accumulated starting at rank `c`, then ranks
    /// c+1, …, c+n-1 (mod n) — so results must match *bitwise*.
    fn ring_order_reference(inputs: &[Vec<f32>], op: ReduceOp) -> Vec<f32> {
        let n = inputs.len();
        let len = inputs[0].len();
        let mut out = vec![0.0f32; len];
        for c in 0..n {
            let (lo, hi) = Collective::chunk_bounds(len, n, c);
            for j in lo..hi {
                let mut acc = inputs[c][j];
                for k in 1..n {
                    op.apply(&mut acc, inputs[(c + k) % n][j]);
                }
                out[j] = acc;
            }
        }
        out
    }

    fn run_allreduce(n: usize, len: usize, op: ReduceOp)
        -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut rng = crate::util::rng::Rng::new(n as u64 * 31 + len as u64);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.normal_f32(0.0, 2.0)).collect())
            .collect();
        let reference = ring_order_reference(&inputs, op);
        let world = inproc_world(n);
        let results: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = world
                .into_iter()
                .zip(inputs.iter())
                .map(|(comm, input)| {
                    let mut buf = input.clone();
                    s.spawn(move || {
                        let mut col = Collective::new(&comm);
                        col.allreduce(&mut buf, op).unwrap();
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        (results, reference)
    }

    #[test]
    fn chunk_bounds_partition_any_length() {
        for n in 1..9usize {
            for len in [0usize, 1, 2, 3, 7, 8, 100, 101] {
                let mut covered = 0usize;
                for i in 0..n {
                    let (lo, hi) = Collective::chunk_bounds(len, n, i);
                    assert_eq!(lo, covered, "len={len} n={n} i={i}");
                    assert!(hi >= lo);
                    covered = hi;
                }
                assert_eq!(covered, len);
            }
        }
    }

    #[test]
    fn allreduce_sum_matches_serial_and_is_identical_across_ranks() {
        for n in [2usize, 3, 4, 5] {
            for len in [1usize, 3, 7, 64, 65] {
                let (results, reference) = run_allreduce(n, len,
                                                         ReduceOp::Sum);
                for (r, got) in results.iter().enumerate() {
                    assert_eq!(got, &reference, "rank {r}, n={n} len={len}");
                }
            }
        }
    }

    #[test]
    fn allreduce_min_and_max() {
        let (res_min, ref_min) = run_allreduce(4, 13, ReduceOp::Min);
        for got in &res_min {
            assert_eq!(got, &ref_min);
        }
        let (res_max, ref_max) = run_allreduce(3, 5, ReduceOp::Max);
        for got in &res_max {
            assert_eq!(got, &ref_max);
        }
    }

    #[test]
    fn allreduce_single_rank_is_identity() {
        let world = inproc_world(1);
        let mut col = Collective::new(&world[0]);
        let mut data = vec![1.0f32, -2.0, 3.5];
        col.allreduce(&mut data, ReduceOp::Sum).unwrap();
        assert_eq!(data, vec![1.0, -2.0, 3.5]);
        assert_eq!(col.allreduce_scalar(9.0, ReduceOp::Min).unwrap(), 9.0);
    }

    #[test]
    fn scalar_min_agrees_on_smallest() {
        let n = 5;
        let world = inproc_world(n);
        let results: Vec<f32> = std::thread::scope(|s| {
            let handles: Vec<_> = world
                .into_iter()
                .enumerate()
                .map(|(r, comm)| {
                    s.spawn(move || {
                        let mut col = Collective::new(&comm);
                        col.allreduce_scalar(10.0 + r as f32,
                                             ReduceOp::Min)
                            .unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(results.iter().all(|&v| v == 10.0), "{results:?}");
    }

    #[test]
    fn broadcast_replicates_root_buffer() {
        for root in [0usize, 2] {
            let n = 4;
            let world = inproc_world(n);
            let payload: Vec<f32> = (0..33).map(|i| i as f32 * 0.25).collect();
            let results: Vec<Vec<f32>> = std::thread::scope(|s| {
                let handles: Vec<_> = world
                    .into_iter()
                    .enumerate()
                    .map(|(r, comm)| {
                        let mut buf = if r == root {
                            payload.clone()
                        } else {
                            Vec::new()
                        };
                        s.spawn(move || {
                            let mut col = Collective::new(&comm);
                            col.broadcast(root, &mut buf).unwrap();
                            buf
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for got in &results {
                assert_eq!(got, &payload, "root={root}");
            }
        }
    }

    #[test]
    fn unrelated_traffic_is_stashed_not_lost() {
        // Rank 1 fires a TrainStats at rank 0 *before* the collective;
        // the all-reduce must still complete and the stats must come
        // back out of the stash.
        let mut world = inproc_world(2);
        let c1 = world.pop().unwrap();
        let c0 = world.pop().unwrap();
        let stats = WorkerStats { epoch: 3, ..Default::default() };
        let handle = std::thread::spawn(move || {
            c1.send(0, Tag::TrainStats, Payload::Stats(stats)).unwrap();
            let mut col = Collective::new(&c1);
            let mut buf = vec![1.0f32; 10];
            col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
            buf
        });
        let mut col = Collective::new(&c0);
        let mut buf = vec![2.0f32; 10];
        col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
        assert!(buf.iter().all(|&v| v == 3.0));
        let stash = col.into_stash();
        assert_eq!(stash.len(), 1);
        assert_eq!(stash[0].tag, Tag::TrainStats);
        assert_eq!(stash[0].payload, Payload::Stats(stats));
        let other = handle.join().unwrap();
        assert!(other.iter().all(|&v| v == 3.0));
    }

    #[test]
    fn broadcast_bad_root_rejected() {
        let world = inproc_world(2);
        let mut col = Collective::new(&world[0]);
        let mut buf = vec![0.0f32];
        assert!(matches!(col.broadcast(7, &mut buf),
                         Err(CommError::InvalidRank { .. })));
    }

    // --- compressed collectives -----------------------------------

    use crate::mpi::codec::Codec;

    /// Run one compressed all-reduce; returns (per-rank results,
    /// per-rank wire bytes sent during it).
    fn run_compressed(n: usize, inputs: &[Vec<f32>], codec: Codec,
                      tail: usize, rounds: usize)
        -> (Vec<Vec<f32>>, Vec<u64>) {
        let world = inproc_world(n);
        std::thread::scope(|s| {
            let handles: Vec<_> = world
                .into_iter()
                .zip(inputs.iter())
                .map(|(comm, input)| {
                    s.spawn(move || {
                        let mut col = Collective::new(&comm);
                        col.set_codec(codec);
                        col.set_exact_tail(tail);
                        let mut buf = input.clone();
                        let before = comm.bytes_sent();
                        for r in 0..rounds {
                            if r > 0 {
                                buf.copy_from_slice(input);
                            }
                            col.allreduce(&mut buf, ReduceOp::Sum)
                                .unwrap();
                        }
                        (buf, comm.bytes_sent() - before)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).unzip()
        })
    }

    fn random_inputs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..n)
            .map(|_| (0..len).map(|_| rng.normal_f32(0.0, 2.0)).collect())
            .collect()
    }

    #[test]
    fn compressed_allreduce_is_bitwise_identical_across_ranks() {
        for codec in [Codec::Fp16, Codec::TopK { k: 0.25 }] {
            for n in [2usize, 3, 4, 5] {
                for len in [1usize, 3, 7, 64, 65] {
                    let inputs = random_inputs(
                        n, len, n as u64 * 131 + len as u64);
                    let (results, _) =
                        run_compressed(n, &inputs, codec, 0, 1);
                    let reference = &results[0];
                    for (r, got) in results.iter().enumerate() {
                        assert!(
                            got.iter().zip(reference.iter()).all(
                                |(a, b)| a.to_bits() == b.to_bits()),
                            "rank {r} diverged ({codec:?}, n={n}, \
                             len={len})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fp16_allreduce_tracks_exact_sum() {
        let n = 4;
        let len = 64;
        let inputs = random_inputs(n, len, 99);
        let reference = ring_order_reference(&inputs, ReduceOp::Sum);
        let (results, _) =
            run_compressed(n, &inputs, Codec::Fp16, 0, 1);
        for (got, want) in results[0].iter().zip(&reference) {
            // fp16 has ~2^-11 relative precision per hop; a 4-rank
            // chain stays well inside 1%
            assert!((got - want).abs() <= 0.01 * want.abs() + 0.01,
                    "fp16 sum {got} too far from {want}");
        }
    }

    #[test]
    fn exact_tail_survives_topk() {
        // body elements are huge, tail elements tiny: without
        // protection top-k would drop the tail every time
        let n = 4;
        let len = 34; // 32 body + loss + stop flag
        let mut inputs = random_inputs(n, len, 7);
        for (r, input) in inputs.iter_mut().enumerate() {
            for v in input.iter_mut() {
                *v *= 100.0;
            }
            input[len - 2] = 0.25 + r as f32; // loss-like, f32-exact
            input[len - 1] = if r == 2 { 1.0 } else { 0.0 }; // flag
        }
        let reference = ring_order_reference(&inputs, ReduceOp::Sum);
        let (results, _) = run_compressed(
            n, &inputs, Codec::TopK { k: 0.1 }, 2, 1);
        for got in &results {
            assert_eq!(got[len - 2], reference[len - 2],
                       "protected loss must be the exact f32 chain sum");
            assert_eq!(got[len - 1], 1.0, "stop flag must survive");
        }
    }

    #[test]
    fn min_max_and_scalar_ignore_the_codec() {
        // Min/Max reductions and scalar agreements must stay exact
        // even when a lossy codec is configured (raw fallback) —
        // including SUM scalars whose values fp16 cannot represent.
        let n = 3;
        let world = inproc_world(n);
        let results: Vec<(f32, f32, Vec<f32>)> =
            std::thread::scope(|s| {
                let handles: Vec<_> = world
                    .into_iter()
                    .enumerate()
                    .map(|(r, comm)| {
                        s.spawn(move || {
                            let mut col = Collective::new(&comm);
                            col.set_codec(Codec::Fp16);
                            let min = col
                                .allreduce_scalar(10.0 + r as f32,
                                                  ReduceOp::Min)
                                .unwrap();
                            // 70001+70002+70003: each addend already
                            // overflows fp16 — must stay exact
                            let sum = col
                                .allreduce_scalar(
                                    70001.0 + r as f32,
                                    ReduceOp::Sum)
                                .unwrap();
                            col.set_codec(Codec::TopK { k: 0.1 });
                            let mut buf = vec![r as f32 + 0.125; 8];
                            col.allreduce(&mut buf, ReduceOp::Max)
                                .unwrap();
                            (min, sum, buf)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
        for (min, sum, maxes) in &results {
            assert_eq!(*min, 10.0);
            assert_eq!(*sum, 210_006.0);
            assert!(maxes.iter().all(|&v| v == 2.125));
        }
    }

    #[test]
    fn compression_cuts_wire_bytes_per_round() {
        let n = 4;
        let len = 4098; // gradient-sized, non-divisible by n
        let inputs = random_inputs(n, len, 5);
        let rounds = 3;
        let bytes = |codec| {
            let (_, b) = run_compressed(n, &inputs, codec, 2, rounds);
            b.iter().sum::<u64>() as f64 / rounds as f64
        };
        let raw = bytes(Codec::Fp32);
        let fp16 = bytes(Codec::Fp16);
        let topk = bytes(Codec::TopK { k: 0.1 });
        assert!(fp16 < 0.6 * raw,
                "fp16 {fp16} should be < 60% of fp32 {raw}");
        assert!(topk < 0.25 * raw,
                "topk:0.1 {topk} should be < 25% of fp32 {raw}");
    }

    #[test]
    fn error_feedback_delivers_dropped_mass_over_rounds() {
        // Repeatedly all-reduce the SAME gradients under heavy top-k:
        // cumulative delivered mass must track rounds * true sum
        // (residuals bounded), the property that keeps top-k training
        // convergent.
        let n = 4;
        let len = 40;
        let inputs = random_inputs(n, len, 11);
        let true_sum = ring_order_reference(&inputs, ReduceOp::Sum);
        let rounds = 300;
        let world = inproc_world(n);
        let applied: Vec<Vec<f64>> = std::thread::scope(|s| {
            let handles: Vec<_> = world
                .into_iter()
                .zip(inputs.iter())
                .map(|(comm, input)| {
                    s.spawn(move || {
                        let mut col = Collective::new(&comm);
                        col.set_codec(Codec::TopK { k: 0.1 });
                        let mut total = vec![0.0f64; input.len()];
                        let mut buf = input.clone();
                        for r in 0..rounds {
                            if r > 0 {
                                buf.copy_from_slice(input);
                            }
                            col.allreduce(&mut buf, ReduceOp::Sum)
                                .unwrap();
                            for (t, &v) in total.iter_mut().zip(&buf) {
                                *t += v as f64;
                            }
                        }
                        total
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut err2 = 0.0f64;
        let mut ref2 = 0.0f64;
        for (i, &want) in true_sum.iter().enumerate() {
            let target = rounds as f64 * want as f64;
            err2 += (applied[0][i] - target).powi(2);
            ref2 += target.powi(2);
        }
        let rel = (err2 / ref2).sqrt();
        assert!(rel < 0.05,
                "cumulative delivery drifted: rel err {rel:.4}");
    }
}
