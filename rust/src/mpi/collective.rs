//! Collective communication over the tagged point-to-point substrate.
//!
//! The parameter-server master is the scalability wall the paper itself
//! measures (Figs 3/4): every gradient serializes through one rank. The
//! standard way past it (Vishnu et al., *Distributed TensorFlow with
//! MPI*; Awan et al., *HyPar-Flow*) is masterless collectives. This
//! module implements the classic **chunked ring all-reduce**
//! (reduce-scatter + all-gather, bandwidth-optimal `2(n-1)/n` payload
//! volume per rank) and a ring **broadcast**, built purely from `Comm`'s
//! tagged sends — so they run unchanged on both the inproc and TCP
//! transports.
//!
//! Determinism: each vector element's reduction is computed exactly once,
//! on a single rank, in a schedule-independent order fixed by the ring
//! topology, then replicated byte-for-byte by the all-gather. All ranks
//! therefore finish with **bitwise identical** buffers regardless of
//! thread/network timing — the property the all-reduce training mode's
//! replicated optimizer relies on.
//!
//! Collectives compose with ordinary protocol traffic: an envelope that
//! is not the expected chunk (e.g. a `TrainStats` racing into rank 0
//! while it is inside an all-reduce) is stashed and re-delivered to the
//! caller afterwards ([`Collective::into_stash`]).

use std::time::Duration;

use crate::mpi::comm::{Comm, CommError};
use crate::mpi::message::{Envelope, Payload, Rank, Tag};

/// Default bound on waiting for a ring neighbor. A peer that dies
/// mid-collective can never be detected by disconnect alone (other
/// ranks keep the receive channel alive), so without a bound one failed
/// rank would hang the whole world forever; with it, the survivors
/// surface `CommError::Timeout` and the driver reports the failure.
/// Generous enough that validation pauses and big payloads never trip it.
const DEFAULT_RECV_TIMEOUT: Duration = Duration::from_secs(300);

/// Element-wise reduction operator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Min,
    Max,
}

impl ReduceOp {
    fn apply(self, dst: &mut f32, src: f32) {
        match self {
            ReduceOp::Sum => *dst += src,
            ReduceOp::Min => *dst = dst.min(src),
            ReduceOp::Max => *dst = dst.max(src),
        }
    }
}

/// Per-rank collective endpoint: wraps a [`Comm`] with the stash needed
/// to keep ring traffic and unrelated protocol messages untangled.
pub struct Collective<'a> {
    comm: &'a Comm,
    stash: Vec<Envelope>,
    seq: u64,
    recv_timeout: Duration,
}

impl<'a> Collective<'a> {
    pub fn new(comm: &'a Comm) -> Self {
        Self {
            comm,
            stash: Vec::new(),
            seq: 0,
            recv_timeout: DEFAULT_RECV_TIMEOUT,
        }
    }

    /// Override the neighbor-wait bound (see [`DEFAULT_RECV_TIMEOUT`]).
    pub fn set_recv_timeout(&mut self, timeout: Duration) {
        self.recv_timeout = timeout;
    }

    pub fn comm(&self) -> &Comm {
        self.comm
    }

    /// Non-collective envelopes observed mid-collective, in arrival
    /// order. The owner should drain these (e.g. via
    /// [`Comm::recv_tag`]'s stash argument) after the last collective.
    pub fn into_stash(self) -> Vec<Envelope> {
        self.stash
    }

    fn next_rank(&self) -> Rank {
        (self.comm.rank() + 1) % self.comm.size()
    }

    fn prev_rank(&self) -> Rank {
        (self.comm.rank() + self.comm.size() - 1) % self.comm.size()
    }

    /// Bounds of balanced chunk `i` when a length-`len` vector is split
    /// `n` ways: the first `len % n` chunks get one extra element, so
    /// non-divisible lengths (and `len < n`, where trailing chunks are
    /// empty) need no padding.
    pub fn chunk_bounds(len: usize, n: usize, i: usize) -> (usize, usize) {
        let base = len / n;
        let rem = len % n;
        let start = i * base + i.min(rem);
        let end = start + base + usize::from(i < rem);
        (start, end)
    }

    fn send_chunk(&mut self, to: Rank, tag: Tag, data: &[f32])
        -> Result<(), CommError> {
        self.seq += 1;
        self.comm.send(to, tag, Payload::floats(self.seq, data.to_vec()))
    }

    /// Receive the next `tag` float payload from `from`, stashing any
    /// unrelated traffic. `expect_len` of `Some(k)` validates the chunk
    /// length (ring lockstep invariant).
    fn recv_floats(&mut self, tag: Tag, from: Rank,
                   expect_len: Option<usize>)
        -> Result<std::sync::Arc<Vec<f32>>, CommError> {
        loop {
            if let Some(i) = self
                .stash
                .iter()
                .position(|e| e.tag == tag && e.src == from)
            {
                let env = self.stash.remove(i);
                return Self::unwrap_floats(env, expect_len);
            }
            let env = self.comm.recv_timeout(self.recv_timeout)?;
            if env.tag == tag {
                if env.src != from {
                    return Err(CommError::Protocol(format!(
                        "collective: {tag:?} from rank {} (expected \
                         ring neighbor {from})",
                        env.src
                    )));
                }
                return Self::unwrap_floats(env, expect_len);
            }
            self.stash.push(env);
        }
    }

    fn unwrap_floats(env: Envelope, expect_len: Option<usize>)
        -> Result<std::sync::Arc<Vec<f32>>, CommError> {
        match env.payload {
            Payload::Floats { data, .. } => {
                if let Some(want) = expect_len {
                    if data.len() != want {
                        return Err(CommError::Protocol(format!(
                            "collective: chunk length {} from rank {} \
                             (expected {want})",
                            data.len(),
                            env.src
                        )));
                    }
                }
                Ok(data)
            }
            other => Err(CommError::Protocol(format!(
                "collective: non-float payload {other:?} from rank {}",
                env.src
            ))),
        }
    }

    /// In-place chunked ring all-reduce: on return, `data` holds the
    /// element-wise `op`-reduction over every rank's input, identical
    /// (bitwise) on all ranks. Works for any `data.len()`, including
    /// lengths not divisible by — or smaller than — the world size.
    ///
    /// All ranks must call this the same number of times with
    /// equal-length buffers (lockstep SPMD, like `MPI_Allreduce`).
    pub fn allreduce(&mut self, data: &mut [f32], op: ReduceOp)
        -> Result<(), CommError> {
        let n = self.comm.size();
        if n <= 1 {
            return Ok(());
        }
        let rank = self.comm.rank();
        let len = data.len();
        let next = self.next_rank();
        let prev = self.prev_rank();

        // Phase 1 — reduce-scatter: after step s, a rank holds the
        // partial reduction of s+1 ranks for chunk (rank - s) mod n;
        // after n-1 steps it owns the complete chunk (rank + 1) mod n.
        for step in 0..n - 1 {
            let send_idx = (rank + n - step) % n;
            let recv_idx = (rank + 2 * n - step - 1) % n;
            let (s0, s1) = Self::chunk_bounds(len, n, send_idx);
            self.send_chunk(next, Tag::RingChunk, &data[s0..s1])?;
            let (r0, r1) = Self::chunk_bounds(len, n, recv_idx);
            let chunk =
                self.recv_floats(Tag::RingChunk, prev, Some(r1 - r0))?;
            for (dst, &src) in data[r0..r1].iter_mut().zip(chunk.iter()) {
                op.apply(dst, src);
            }
        }

        // Phase 2 — all-gather: circulate the completed chunks.
        for step in 0..n - 1 {
            let send_idx = (rank + 1 + 2 * n - step) % n;
            let recv_idx = (rank + 2 * n - step) % n;
            let (s0, s1) = Self::chunk_bounds(len, n, send_idx);
            self.send_chunk(next, Tag::RingChunk, &data[s0..s1])?;
            let (r0, r1) = Self::chunk_bounds(len, n, recv_idx);
            let chunk =
                self.recv_floats(Tag::RingChunk, prev, Some(r1 - r0))?;
            data[r0..r1].copy_from_slice(&chunk);
        }
        Ok(())
    }

    /// Single-value all-reduce convenience (e.g. agreeing on the common
    /// per-epoch round count via `ReduceOp::Min`). Exact for integral
    /// values below 2^24.
    pub fn allreduce_scalar(&mut self, value: f32, op: ReduceOp)
        -> Result<f32, CommError> {
        let mut buf = [value];
        self.allreduce(&mut buf, op)?;
        Ok(buf[0])
    }

    /// Ring broadcast from `root`: each rank adopts the root's buffer.
    /// The payload travels the ring once as a shared `Arc`, so the
    /// inproc transport forwards it without re-copying.
    pub fn broadcast(&mut self, root: Rank, data: &mut Vec<f32>)
        -> Result<(), CommError> {
        let n = self.comm.size();
        if root >= n {
            return Err(CommError::InvalidRank { rank: root, size: n });
        }
        if n <= 1 {
            return Ok(());
        }
        let rank = self.comm.rank();
        let next = self.next_rank();
        self.seq += 1;
        if rank == root {
            self.comm.send(next, Tag::Bcast,
                           Payload::floats(self.seq, data.clone()))?;
        } else {
            let prev = self.prev_rank();
            let payload = self.recv_floats(Tag::Bcast, prev, None)?;
            data.clear();
            data.extend_from_slice(&payload);
            if next != root {
                self.comm.send(next, Tag::Bcast,
                               Payload::floats_shared(self.seq, payload))?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::inproc_world;
    use crate::mpi::message::WorkerStats;

    /// Reference reduction matching the ring's deterministic order:
    /// chunk `c` is accumulated starting at rank `c`, then ranks
    /// c+1, …, c+n-1 (mod n) — so results must match *bitwise*.
    fn ring_order_reference(inputs: &[Vec<f32>], op: ReduceOp) -> Vec<f32> {
        let n = inputs.len();
        let len = inputs[0].len();
        let mut out = vec![0.0f32; len];
        for c in 0..n {
            let (lo, hi) = Collective::chunk_bounds(len, n, c);
            for j in lo..hi {
                let mut acc = inputs[c][j];
                for k in 1..n {
                    op.apply(&mut acc, inputs[(c + k) % n][j]);
                }
                out[j] = acc;
            }
        }
        out
    }

    fn run_allreduce(n: usize, len: usize, op: ReduceOp)
        -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut rng = crate::util::rng::Rng::new(n as u64 * 31 + len as u64);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.normal_f32(0.0, 2.0)).collect())
            .collect();
        let reference = ring_order_reference(&inputs, op);
        let world = inproc_world(n);
        let results: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = world
                .into_iter()
                .zip(inputs.iter())
                .map(|(comm, input)| {
                    let mut buf = input.clone();
                    s.spawn(move || {
                        let mut col = Collective::new(&comm);
                        col.allreduce(&mut buf, op).unwrap();
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        (results, reference)
    }

    #[test]
    fn chunk_bounds_partition_any_length() {
        for n in 1..9usize {
            for len in [0usize, 1, 2, 3, 7, 8, 100, 101] {
                let mut covered = 0usize;
                for i in 0..n {
                    let (lo, hi) = Collective::chunk_bounds(len, n, i);
                    assert_eq!(lo, covered, "len={len} n={n} i={i}");
                    assert!(hi >= lo);
                    covered = hi;
                }
                assert_eq!(covered, len);
            }
        }
    }

    #[test]
    fn allreduce_sum_matches_serial_and_is_identical_across_ranks() {
        for n in [2usize, 3, 4, 5] {
            for len in [1usize, 3, 7, 64, 65] {
                let (results, reference) = run_allreduce(n, len,
                                                         ReduceOp::Sum);
                for (r, got) in results.iter().enumerate() {
                    assert_eq!(got, &reference, "rank {r}, n={n} len={len}");
                }
            }
        }
    }

    #[test]
    fn allreduce_min_and_max() {
        let (res_min, ref_min) = run_allreduce(4, 13, ReduceOp::Min);
        for got in &res_min {
            assert_eq!(got, &ref_min);
        }
        let (res_max, ref_max) = run_allreduce(3, 5, ReduceOp::Max);
        for got in &res_max {
            assert_eq!(got, &ref_max);
        }
    }

    #[test]
    fn allreduce_single_rank_is_identity() {
        let world = inproc_world(1);
        let mut col = Collective::new(&world[0]);
        let mut data = vec![1.0f32, -2.0, 3.5];
        col.allreduce(&mut data, ReduceOp::Sum).unwrap();
        assert_eq!(data, vec![1.0, -2.0, 3.5]);
        assert_eq!(col.allreduce_scalar(9.0, ReduceOp::Min).unwrap(), 9.0);
    }

    #[test]
    fn scalar_min_agrees_on_smallest() {
        let n = 5;
        let world = inproc_world(n);
        let results: Vec<f32> = std::thread::scope(|s| {
            let handles: Vec<_> = world
                .into_iter()
                .enumerate()
                .map(|(r, comm)| {
                    s.spawn(move || {
                        let mut col = Collective::new(&comm);
                        col.allreduce_scalar(10.0 + r as f32,
                                             ReduceOp::Min)
                            .unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(results.iter().all(|&v| v == 10.0), "{results:?}");
    }

    #[test]
    fn broadcast_replicates_root_buffer() {
        for root in [0usize, 2] {
            let n = 4;
            let world = inproc_world(n);
            let payload: Vec<f32> = (0..33).map(|i| i as f32 * 0.25).collect();
            let results: Vec<Vec<f32>> = std::thread::scope(|s| {
                let handles: Vec<_> = world
                    .into_iter()
                    .enumerate()
                    .map(|(r, comm)| {
                        let mut buf = if r == root {
                            payload.clone()
                        } else {
                            Vec::new()
                        };
                        s.spawn(move || {
                            let mut col = Collective::new(&comm);
                            col.broadcast(root, &mut buf).unwrap();
                            buf
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for got in &results {
                assert_eq!(got, &payload, "root={root}");
            }
        }
    }

    #[test]
    fn unrelated_traffic_is_stashed_not_lost() {
        // Rank 1 fires a TrainStats at rank 0 *before* the collective;
        // the all-reduce must still complete and the stats must come
        // back out of the stash.
        let mut world = inproc_world(2);
        let c1 = world.pop().unwrap();
        let c0 = world.pop().unwrap();
        let stats = WorkerStats { epoch: 3, ..Default::default() };
        let handle = std::thread::spawn(move || {
            c1.send(0, Tag::TrainStats, Payload::Stats(stats)).unwrap();
            let mut col = Collective::new(&c1);
            let mut buf = vec![1.0f32; 10];
            col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
            buf
        });
        let mut col = Collective::new(&c0);
        let mut buf = vec![2.0f32; 10];
        col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
        assert!(buf.iter().all(|&v| v == 3.0));
        let stash = col.into_stash();
        assert_eq!(stash.len(), 1);
        assert_eq!(stash[0].tag, Tag::TrainStats);
        assert_eq!(stash[0].payload, Payload::Stats(stats));
        let other = handle.join().unwrap();
        assert!(other.iter().all(|&v| v == 3.0));
    }

    #[test]
    fn broadcast_bad_root_rejected() {
        let world = inproc_world(2);
        let mut col = Collective::new(&world[0]);
        let mut buf = vec![0.0f32];
        assert!(matches!(col.broadcast(7, &mut buf),
                         Err(CommError::InvalidRank { .. })));
    }
}
