//! Collective communication over the tagged point-to-point substrate.
//!
//! The parameter-server master is the scalability wall the paper itself
//! measures (Figs 3/4): every gradient serializes through one rank. The
//! standard way past it (Vishnu et al., *Distributed TensorFlow with
//! MPI*; Awan et al., *HyPar-Flow*) is masterless collectives. This
//! module implements the classic **chunked ring all-reduce**
//! (reduce-scatter + all-gather, bandwidth-optimal `2(n-1)/n` payload
//! volume per rank) and a ring **broadcast**, built purely from `Comm`'s
//! tagged sends — so they run unchanged on both the inproc and TCP
//! transports.
//!
//! Determinism: each vector element's reduction is computed exactly once,
//! on a single rank, in a schedule-independent order fixed by the ring
//! topology, then replicated byte-for-byte by the all-gather. All ranks
//! therefore finish with **bitwise identical** buffers regardless of
//! thread/network timing — the property the all-reduce training mode's
//! replicated optimizer relies on.
//!
//! Collectives compose with ordinary protocol traffic: an envelope that
//! is not the expected chunk (e.g. a `TrainStats` racing into rank 0
//! while it is inside an all-reduce) is stashed and re-delivered to the
//! caller afterwards ([`Collective::into_stash`]).
//!
//! **Compression** ([`Collective::set_codec`]): with a lossy codec, sum
//! all-reduces compress every wire hop while keeping the determinism
//! guarantee. The reduce-scatter reduces *decoded* f32 along the ring's
//! fixed chain (each hop compresses its partial sums with an
//! error-feedback residual, so dropped mass re-enters the next round);
//! the all-gather compresses each completed chunk ONCE on its owner —
//! which adopts the decoded form itself — and forwards that payload
//! verbatim, so every rank decodes identical bytes. Min/Max reductions,
//! scalar agreements, and broadcasts always go raw, and the last
//! [`Collective::set_exact_tail`] elements are exempt from top-k
//! dropping (piggybacked control flags must never vanish).
//!
//! **Hierarchical all-reduce** ([`Collective::set_groups`]): a flat ring
//! pays a `2(n-1)` lockstep-latency term per round — the curve-flattener
//! HyPar-Flow attributes the PS-free scaling wall to. With a
//! [`GroupLayout`] configured, sum all-reduces instead run
//! ring → tree → ring:
//!
//! 1. each group runs the chunked ring **reduce-scatter** over its own
//!    members (`Tag::GroupChunk`, cheap intra-node hops),
//! 2. members gather their completed chunks onto the group **leader**
//!    (`Tag::GroupGather`), so each leader holds its group's full sum,
//! 3. leaders combine partial sums up a **binary tree**
//!    (`Tag::TreeReduce`) — `ceil(log2 G)` expensive inter-node hops
//!    instead of `G` ring steps,
//! 4. the tree root builds the **canonical payload** (compressing it
//!    ONCE under a lossy codec, adopting the decoded form itself) and it
//!    travels back down the leader tree (`Tag::TreeBcast`) and around
//!    each group's ring (`Tag::GroupBcast`) *verbatim* — every rank
//!    decodes identical bytes, so the bitwise-identical guarantee holds
//!    exactly as in the flat ring.
//!
//! Min/Max reductions, scalar agreements, and `broadcast` ignore the
//! layout (control-plane traffic stays on the flat raw ring).
//!
//! **Bucketed overlap** ([`Collective::bucket_begin`] /
//! [`Collective::bucket_finish_sum`]): a sum all-reduce can be split
//! into per-layer *buckets* — contiguous windows of the round buffer —
//! each launched as soon as its layer's backward pass completes, so the
//! wire works while upstream layers still compute. Every bucket runs
//! the same flat-ring or hierarchical schedule on its own
//! `(bucket, phase)` tag lanes (see [`crate::mpi::tags`]), and windows
//! are chunked on the GLOBAL grid, so fp32/fp16 bucketed results are
//! bitwise identical to the monolithic all-reduce over the same buffer
//! (top-k re-selects per packed slice, so it stays bitwise identical
//! *across ranks* but not to the monolith). See DESIGN.md §Layer DAG &
//! bucketed overlap.

use std::sync::Arc;
use std::time::Duration;

use crate::mpi::codec::{Codec, Compressor};
use crate::mpi::comm::{Comm, CommError};
use crate::mpi::message::{BucketPhase, Envelope, Payload, Rank, Tag};
use crate::mpi::tags;
use crate::util::threadpool::ThreadPool;

/// Default bound on waiting for a ring neighbor. A peer that dies
/// mid-collective can never be detected by disconnect alone (other
/// ranks keep the receive channel alive), so without a bound one failed
/// rank would hang the whole world forever; with it, the survivors
/// surface `CommError::Timeout` and the driver reports the failure.
/// Generous enough that validation pauses and big payloads never trip it.
pub const DEFAULT_RECV_TIMEOUT: Duration = Duration::from_secs(300);

/// Element-wise reduction operator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Min,
    Max,
}

impl ReduceOp {
    fn apply(self, dst: &mut f32, src: f32) {
        match self {
            ReduceOp::Sum => *dst += src,
            ReduceOp::Min => *dst = dst.min(src),
            ReduceOp::Max => *dst = dst.max(src),
        }
    }
}

/// Disjoint rank groups covering a masterless world — the topology input
/// of the hierarchical all-reduce. The first member of each group is its
/// *leader* (the rank that joins the inter-group binary tree); the
/// leader of group 0 is the tree root.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GroupLayout {
    groups: Vec<Vec<Rank>>,
}

impl GroupLayout {
    /// Build a layout from explicit member lists. Groups must be
    /// non-empty and disjoint (every rank in at most one group).
    pub fn new(groups: Vec<Vec<Rank>>) -> Result<GroupLayout, String> {
        if groups.is_empty() {
            return Err("group layout needs at least one group".into());
        }
        let mut seen = std::collections::BTreeSet::new();
        for (g, members) in groups.iter().enumerate() {
            if members.is_empty() {
                return Err(format!("group {g} is empty"));
            }
            for &r in members {
                if !seen.insert(r) {
                    return Err(format!(
                        "rank {r} appears in more than one group"));
                }
            }
        }
        Ok(GroupLayout { groups })
    }

    /// Split ranks `0..world` into `n_groups` contiguous blocks (the
    /// canonical layout: ranks of one group are co-located "node"
    /// neighbors). `world` must divide evenly.
    pub fn contiguous(world: usize, n_groups: usize)
        -> Result<GroupLayout, String> {
        if n_groups == 0 || world == 0 || world % n_groups != 0 {
            return Err(format!(
                "cannot split {world} ranks into {n_groups} equal \
                 groups"));
        }
        let per = world / n_groups;
        Self::new((0..n_groups)
            .map(|g| (g * per..(g + 1) * per).collect())
            .collect())
    }

    pub fn groups(&self) -> &[Vec<Rank>] {
        &self.groups
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// Group index of `rank`, if it belongs to the layout.
    pub fn group_of(&self, rank: Rank) -> Option<usize> {
        self.groups.iter().position(|g| g.contains(&rank))
    }

    /// One leader per group: the group's first member.
    pub fn leaders(&self) -> Vec<Rank> {
        self.groups.iter().map(|g| g[0]).collect()
    }

    /// Total ranks covered by the layout.
    pub fn world_size(&self) -> usize {
        self.groups.iter().map(|g| g.len()).sum()
    }
}

/// Position of `rank` in `members`, or a protocol error (collectives
/// over a subset require the caller to be part of it).
fn member_pos(members: &[Rank], rank: Rank) -> Result<usize, CommError> {
    members.iter().position(|&r| r == rank).ok_or_else(|| {
        CommError::Protocol(format!(
            "collective: rank {rank} is not a member of {members:?}"))
    })
}

/// Per-rank collective endpoint: wraps a [`Comm`] with the stash needed
/// to keep ring traffic and unrelated protocol messages untangled.
pub struct Collective<'a> {
    comm: &'a Comm,
    stash: Vec<Envelope>,
    seq: u64,
    recv_timeout: Duration,
    codec: Codec,
    /// Error-feedback state for compressed hops (one residual slot per
    /// element index; see the module docs).
    compressor: Compressor,
    /// Compute pool for the fp16 pack/unpack hot loops (None = serial).
    pool: Option<Arc<ThreadPool>>,
    /// Trailing elements exempt from lossy dropping (stop flags, loss).
    exact_tail: usize,
    /// Grouped topology for sum all-reduces (None = flat ring).
    groups: Option<GroupLayout>,
    /// Buckets launched by [`Collective::bucket_begin`] and not yet
    /// completed by [`Collective::bucket_finish_sum`], in launch order.
    pending: Vec<PendingBucket>,
    /// World generation: stamped into the high 32 bits of every
    /// collective payload's `step` so traffic from an already-replaced
    /// world is rejected (the wrong-source race class the tag registry
    /// exists for, extended across replans). 0 until the first replan.
    epoch: u64,
    /// Current membership over the ORIGINAL Comm rank space (`None` =
    /// every rank). Replans shrink/grow this list; the `Comm` world
    /// itself never changes size after launch.
    members: Option<Vec<Rank>>,
    /// Elastic mode: membership-control envelopes (`ElasticSuspect` /
    /// `ElasticProbe` / `ElasticPlan`) interrupt in-flight collectives
    /// with [`CommError::Interrupted`] instead of being stashed.
    elastic: bool,
}

/// One outstanding bucketed sum all-reduce: the window `[w0, w1)` of a
/// logical `total`-element round buffer, running on its own
/// `(bucket, phase)` tag lanes.
struct PendingBucket {
    bucket: usize,
    w0: usize,
    w1: usize,
    total: usize,
    /// The schedule's first wire send already happened in
    /// `bucket_begin` (false on 1-rank worlds and 1-member groups).
    first_sent: bool,
}

/// The tag lane set one hierarchical sum all-reduce runs on — the fixed
/// monolithic tags, or a bucket's five dedicated lanes.
struct HierTags {
    chunk: Tag,
    gather: Tag,
    tree_reduce: Tag,
    tree_bcast: Tag,
    bcast: Tag,
}

const MONOLITH_HIER_TAGS: HierTags = HierTags {
    chunk: Tag::GroupChunk,
    gather: Tag::GroupGather,
    tree_reduce: Tag::TreeReduce,
    tree_bcast: Tag::TreeBcast,
    bcast: Tag::GroupBcast,
};

fn bucket_hier_tags(bucket: usize) -> HierTags {
    HierTags {
        chunk: tags::bucket_tag(bucket, BucketPhase::Chunk),
        gather: tags::bucket_tag(bucket, BucketPhase::Gather),
        tree_reduce: tags::bucket_tag(bucket, BucketPhase::TreeReduce),
        tree_bcast: tags::bucket_tag(bucket, BucketPhase::TreeBcast),
        bcast: tags::bucket_tag(bucket, BucketPhase::Bcast),
    }
}

impl<'a> Collective<'a> {
    pub fn new(comm: &'a Comm) -> Self {
        Self {
            comm,
            stash: Vec::new(),
            seq: 0,
            recv_timeout: DEFAULT_RECV_TIMEOUT,
            codec: Codec::Fp32,
            compressor: Compressor::new(Codec::Fp32),
            pool: None,
            exact_tail: 0,
            groups: None,
            pending: Vec::new(),
            epoch: 0,
            members: None,
            elastic: false,
        }
    }

    /// Override the neighbor-wait bound (see [`DEFAULT_RECV_TIMEOUT`]).
    pub fn set_recv_timeout(&mut self, timeout: Duration) {
        self.recv_timeout = timeout;
    }

    /// Compress sum all-reduce wire hops with `codec` (resets the
    /// error-feedback residual). All ranks of a world must configure
    /// the same codec — chunks are decoded by shape, not negotiated.
    pub fn set_codec(&mut self, codec: Codec) {
        self.codec = codec;
        self.compressor = Compressor::new(codec);
        if let Some(pool) = &self.pool {
            self.compressor.set_pool(Arc::clone(pool));
        }
    }

    /// Run the fp16 pack/unpack hot loops on the rank's compute pool
    /// (bitwise-identical at any thread count; see
    /// [`Compressor::set_pool`]).
    pub fn set_pool(&mut self, pool: Arc<ThreadPool>) {
        self.compressor.set_pool(Arc::clone(&pool));
        self.pool = Some(pool);
    }

    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Exempt the last `n` elements of every compressed all-reduce
    /// from lossy dropping (piggybacked control values).
    pub fn set_exact_tail(&mut self, n: usize) {
        self.exact_tail = n;
    }

    /// Route sum all-reduces through the hierarchical
    /// ring → tree → ring schedule over `layout` (see the module docs);
    /// `None` restores the flat ring. All ranks of a world must
    /// configure the identical layout — the schedule is positional, not
    /// negotiated. Min/Max reductions, scalar agreements, and
    /// broadcasts are unaffected.
    pub fn set_groups(&mut self, layout: Option<GroupLayout>) {
        self.groups = layout;
    }

    pub fn groups_layout(&self) -> Option<&GroupLayout> {
        self.groups.as_ref()
    }

    pub fn comm(&self) -> &Comm {
        self.comm
    }

    /// Non-collective envelopes observed mid-collective, in arrival
    /// order. The owner should drain these (e.g. via
    /// [`Comm::recv_tag`]'s stash argument) after the last collective.
    pub fn into_stash(self) -> Vec<Envelope> {
        self.stash
    }

    /// Direct mutable access to the stash — the elastic membership
    /// protocol ([`crate::coordinator::elastic`]) shares it so control
    /// envelopes stashed mid-collective are found by its receives.
    pub fn stash_mut(&mut self) -> &mut Vec<Envelope> {
        &mut self.stash
    }

    /// Enable elastic membership handling: `ElasticSuspect` /
    /// `ElasticProbe` / `ElasticPlan` envelopes observed inside a
    /// collective abort it with [`CommError::Interrupted`] so the
    /// caller can run the membership-agreement barrier.
    pub fn set_elastic(&mut self, on: bool) {
        self.elastic = on;
    }

    /// Current world generation (0 until the first replan).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Current member list (`None` = the full Comm world).
    pub fn members(&self) -> Option<&[Rank]> {
        self.members.as_deref()
    }

    /// Ranks participating in collectives under the current plan.
    pub fn n_ranks(&self) -> usize {
        self.members.as_ref().map_or(self.comm.size(), |m| m.len())
    }

    /// Adopt a replanned world: bump the generation, install the member
    /// list, and deterministically reset in-flight state — pending
    /// buckets are dropped, the error-feedback residual is DISCARDED
    /// (not flushed: survivors abort at different points of the round,
    /// so only a reset keeps the compressor state replica-identical;
    /// DESIGN.md §Elasticity), and stale stash entries from older
    /// generations are purged. Stashed future-generation traffic (sent
    /// by members that adopted before us) is kept: it is this world's.
    pub fn adopt_world(&mut self, epoch: u64,
                       members: Option<Vec<Rank>>) {
        self.epoch = epoch;
        self.members = members;
        self.pending.clear();
        self.compressor = Compressor::new(self.codec);
        if let Some(pool) = &self.pool {
            self.compressor.set_pool(Arc::clone(pool));
        }
        self.stash.retain(|e| {
            let stale_gen = Self::gen_of(&e.payload)
                .map_or(false, |g| g < epoch);
            let screened = Self::is_collective_tag(e.tag)
                || matches!(e.tag, Tag::ElasticSuspect
                            | Tag::ElasticProbe | Tag::ElasticAlive
                            | Tag::ElasticPlan);
            !(screened && stale_gen)
        });
    }

    /// Drain every `ElasticJoin` request observed so far (stashed
    /// mid-collective or still sitting in the receive queue), deduped
    /// and sorted.
    pub fn pending_joiners(&mut self) -> Vec<Rank> {
        let mut joiners: Vec<Rank> = Vec::new();
        self.stash.retain(|e| {
            if e.tag == Tag::ElasticJoin {
                joiners.push(e.src);
                false
            } else {
                true
            }
        });
        loop {
            match self.comm.try_recv() {
                Ok(Some(env)) => {
                    if env.tag == Tag::ElasticJoin {
                        joiners.push(env.src);
                    } else {
                        self.stash.push(env);
                    }
                }
                Ok(None) | Err(_) => break,
            }
        }
        joiners.sort_unstable();
        joiners.dedup();
        joiners
    }

    /// The world generation stamped into a payload's `step` high bits
    /// (None for payloads that carry no step).
    fn gen_of(payload: &Payload) -> Option<u64> {
        match payload {
            Payload::Floats { step, .. }
            | Payload::Packed { step, .. }
            | Payload::Grad { step, .. } => Some(step >> 32),
            _ => None,
        }
    }

    /// Tags whose envelopes carry generation-screened collective data.
    fn is_collective_tag(tag: Tag) -> bool {
        matches!(tag,
                 Tag::RingChunk | Tag::Bcast | Tag::TreeReduce
                 | Tag::TreeBcast | Tag::GroupGather | Tag::GroupChunk
                 | Tag::GroupBcast | Tag::Bucket { .. })
    }

    /// Whether a stashed/received envelope may satisfy the current
    /// collective receive: collective data must carry the current
    /// generation (stale worlds' chunks are never deliverable).
    fn current_gen(&self, e: &Envelope) -> bool {
        !Self::is_collective_tag(e.tag)
            || Self::gen_of(&e.payload).map_or(true, |g| g == self.epoch)
    }

    /// Gate one envelope observed inside a collective receive loop.
    /// `Ok(Some(env))` = deliverable; `Ok(None)` = swallowed (stale
    /// generation) or parked in the stash (future generation, elastic
    /// control); `Err(Interrupted)` = membership control demands the
    /// caller abort the in-flight round (elastic mode only).
    fn screen(&mut self, env: Envelope)
        -> Result<Option<Envelope>, CommError> {
        if Self::is_collective_tag(env.tag) {
            return Ok(match Self::gen_of(&env.payload) {
                Some(g) if g < self.epoch => None, // stale world: drop
                Some(g) if g > self.epoch => {
                    // a member that already adopted the next plan is
                    // ahead of us — keep its traffic for after adoption
                    self.stash.push(env);
                    None
                }
                _ => Some(env),
            });
        }
        match env.tag {
            Tag::ElasticSuspect | Tag::ElasticProbe
            | Tag::ElasticPlan => {
                if Self::gen_of(&env.payload)
                    .map_or(false, |g| g < self.epoch)
                {
                    return Ok(None); // stale control: drop
                }
                let what =
                    format!("{:?} from rank {}", env.tag, env.src);
                self.stash.push(env);
                if self.elastic {
                    Err(CommError::Interrupted(what))
                } else {
                    Ok(None)
                }
            }
            Tag::ElasticAlive | Tag::ElasticJoin => {
                // consumed out-of-band by the membership protocol
                self.stash.push(env);
                Ok(None)
            }
            _ => Ok(Some(env)),
        }
    }

    /// Stamp the next collective payload: world generation in the high
    /// 32 bits, the monotone send sequence in the low 32.
    fn next_step(&mut self) -> u64 {
        self.seq += 1;
        (self.epoch << 32) | (self.seq & 0xFFFF_FFFF)
    }

    /// The current world's ring: (member count, own position, next
    /// rank, prev rank). With no member list this is the full Comm
    /// world's rank order.
    fn ring(&self) -> Result<(usize, usize, Rank, Rank), CommError> {
        match &self.members {
            None => {
                let n = self.comm.size();
                let rank = self.comm.rank();
                Ok((n, rank, (rank + 1) % n, (rank + n - 1) % n))
            }
            Some(members) => {
                let m = members.len();
                let pos = member_pos(members, self.comm.rank())?;
                Ok((m, pos, members[(pos + 1) % m],
                    members[(pos + m - 1) % m]))
            }
        }
    }

    /// Bounds of balanced chunk `i` when a length-`len` vector is split
    /// `n` ways: the first `len % n` chunks get one extra element, so
    /// non-divisible lengths (and `len < n`, where trailing chunks are
    /// empty) need no padding.
    pub fn chunk_bounds(len: usize, n: usize, i: usize) -> (usize, usize) {
        let base = len / n;
        let rem = len % n;
        let start = i * base + i.min(rem);
        let end = start + base + usize::from(i < rem);
        (start, end)
    }

    /// Intersection of GLOBAL chunk `i` (the [`Collective::chunk_bounds`]
    /// grid over the whole `total`-element buffer) with the window
    /// `[w0, w1)`. Bucketed collectives chunk on the global grid — not
    /// per-window — so every element keeps the exact reduction start
    /// rank and accumulation order it has in the monolithic all-reduce,
    /// which is what makes fp32 and fp16 bucketed results bitwise
    /// identical to the monolith. The intersection may be empty (windows
    /// smaller than the grid); empty slices still travel the ring so
    /// the lockstep schedule stays uniform.
    pub fn window_chunk(total: usize, n: usize, i: usize, w0: usize,
                        w1: usize) -> (usize, usize) {
        let (c0, c1) = Self::chunk_bounds(total, n, i);
        let lo = c0.max(w0).min(w1);
        let hi = c1.min(w1).max(lo);
        (lo, hi)
    }

    fn send_chunk(&mut self, to: Rank, tag: Tag, data: &[f32])
        -> Result<(), CommError> {
        let step = self.next_step();
        self.comm.send(to, tag, Payload::floats(step, data.to_vec()))
    }

    /// Like [`Collective::recv_from`], but same-tag traffic from other
    /// sources is stashed instead of treated as a protocol violation —
    /// needed wherever a rank legitimately hears the same tag from
    /// several peers in arbitrary order (a tree parent's two children,
    /// a leader gathering its whole group).
    fn recv_from_stashing(&mut self, tag: Tag, from: Rank)
        -> Result<Envelope, CommError> {
        loop {
            if let Some(i) = self.stash.iter().position(|e| {
                e.tag == tag && e.src == from && self.current_gen(e)
            }) {
                return Ok(self.stash.remove(i));
            }
            let env = self.comm.recv_timeout(self.recv_timeout)?;
            let env = match self.screen(env)? {
                Some(env) => env,
                None => continue,
            };
            if env.tag == tag && env.src == from {
                return Ok(env);
            }
            self.stash.push(env);
        }
    }

    /// Receive the next `tag` envelope from `from`, stashing any
    /// unrelated traffic (ring lockstep: wrong-source chunks are a
    /// protocol violation).
    fn recv_from(&mut self, tag: Tag, from: Rank)
        -> Result<Envelope, CommError> {
        loop {
            if let Some(i) = self.stash.iter().position(|e| {
                e.tag == tag && e.src == from && self.current_gen(e)
            }) {
                return Ok(self.stash.remove(i));
            }
            let env = self.comm.recv_timeout(self.recv_timeout)?;
            let env = match self.screen(env)? {
                Some(env) => env,
                None => continue,
            };
            if env.tag == tag {
                if env.src != from {
                    return Err(CommError::Protocol(format!(
                        "collective: {tag:?} from rank {} (expected \
                         ring neighbor {from})",
                        env.src
                    )));
                }
                return Ok(env);
            }
            self.stash.push(env);
        }
    }

    /// Receive the next `tag` float payload from `from`. `expect_len`
    /// of `Some(k)` validates the chunk length (ring lockstep
    /// invariant).
    fn recv_floats(&mut self, tag: Tag, from: Rank,
                   expect_len: Option<usize>)
        -> Result<std::sync::Arc<Vec<f32>>, CommError> {
        let env = self.recv_from(tag, from)?;
        Self::unwrap_floats(env, expect_len)
    }

    /// Receive a raw-or-compressed chunk of exactly `expect_len`
    /// logical elements.
    fn recv_chunk(&mut self, tag: Tag, from: Rank, expect_len: usize)
        -> Result<Payload, CommError> {
        let env = self.recv_from(tag, from)?;
        Self::check_chunk(env, expect_len)
    }

    /// Validate a chunk envelope's payload kind and logical length.
    fn check_chunk(env: Envelope, expect_len: usize)
        -> Result<Payload, CommError> {
        let got = match &env.payload {
            Payload::Floats { data, .. } => data.len(),
            Payload::Packed { data, .. } => data.len(),
            other => {
                return Err(CommError::Protocol(format!(
                    "collective: non-float payload {other:?} from \
                     rank {}",
                    env.src
                )))
            }
        };
        if got != expect_len {
            return Err(CommError::Protocol(format!(
                "collective: chunk length {got} from rank {} \
                 (expected {expect_len})",
                env.src
            )));
        }
        Ok(env.payload)
    }

    fn unwrap_floats(env: Envelope, expect_len: Option<usize>)
        -> Result<std::sync::Arc<Vec<f32>>, CommError> {
        match env.payload {
            Payload::Floats { data, .. } => {
                if let Some(want) = expect_len {
                    if data.len() != want {
                        return Err(CommError::Protocol(format!(
                            "collective: chunk length {} from rank {} \
                             (expected {want})",
                            data.len(),
                            env.src
                        )));
                    }
                }
                Ok(data)
            }
            other => Err(CommError::Protocol(format!(
                "collective: non-float payload {other:?} from rank {}",
                env.src
            ))),
        }
    }

    /// In-place chunked ring all-reduce: on return, `data` holds the
    /// element-wise `op`-reduction over every rank's input, identical
    /// (bitwise) on all ranks. Works for any `data.len()`, including
    /// lengths not divisible by — or smaller than — the world size.
    ///
    /// With a lossy codec configured ([`Collective::set_codec`]), sum
    /// reductions compress every wire hop (see the module docs); the
    /// bitwise-identical guarantee still holds. Min/Max always go raw
    /// (error feedback is a sum-space concept).
    ///
    /// All ranks must call this the same number of times with
    /// equal-length buffers (lockstep SPMD, like `MPI_Allreduce`).
    /// With a [`GroupLayout`] configured ([`Collective::set_groups`]),
    /// sum reductions run the hierarchical ring → tree → ring schedule
    /// instead of the flat ring; Min/Max still use the flat raw ring
    /// (they are rare control-plane reductions).
    pub fn allreduce(&mut self, data: &mut [f32], op: ReduceOp)
        -> Result<(), CommError> {
        if self.n_ranks() <= 1 {
            return Ok(());
        }
        if op != ReduceOp::Sum {
            return self.allreduce_raw(data, op);
        }
        if self.groups.is_some() {
            return self.allreduce_hier(data);
        }
        // The monolithic flat sum is the windowed ring over the full
        // window — raw and compressed hops share one schedule.
        let len = data.len();
        self.ring_sum_window(data, 0, len, len, Tag::RingChunk, false)
    }

    fn allreduce_raw(&mut self, data: &mut [f32], op: ReduceOp)
        -> Result<(), CommError> {
        // Positional over the current member list, so the same schedule
        // runs on the full world and on any replanned survivor subset.
        let (n, pos, next, prev) = self.ring()?;
        if n <= 1 {
            return Ok(());
        }
        let len = data.len();

        // Phase 1 — reduce-scatter: after step s, a rank holds the
        // partial reduction of s+1 ranks for chunk (pos - s) mod n;
        // after n-1 steps it owns the complete chunk (pos + 1) mod n.
        for step in 0..n - 1 {
            let send_idx = (pos + n - step) % n;
            let recv_idx = (pos + 2 * n - step - 1) % n;
            let (s0, s1) = Self::chunk_bounds(len, n, send_idx);
            self.send_chunk(next, Tag::RingChunk, &data[s0..s1])?;
            let (r0, r1) = Self::chunk_bounds(len, n, recv_idx);
            let chunk =
                self.recv_floats(Tag::RingChunk, prev, Some(r1 - r0))?;
            for (dst, &src) in data[r0..r1].iter_mut().zip(chunk.iter()) {
                op.apply(dst, src);
            }
        }

        // Phase 2 — all-gather: circulate the completed chunks.
        for step in 0..n - 1 {
            let send_idx = (pos + 1 + 2 * n - step) % n;
            let recv_idx = (pos + 2 * n - step) % n;
            let (s0, s1) = Self::chunk_bounds(len, n, send_idx);
            self.send_chunk(next, Tag::RingChunk, &data[s0..s1])?;
            let (r0, r1) = Self::chunk_bounds(len, n, recv_idx);
            let chunk =
                self.recv_floats(Tag::RingChunk, prev, Some(r1 - r0))?;
            data[r0..r1].copy_from_slice(&chunk);
        }
        Ok(())
    }

    /// How many trailing elements of chunk `[s0, s1)` fall inside the
    /// exact tail `[len - exact_tail, len)` (always a chunk suffix).
    fn protect_len(&self, len: usize, s0: usize, s1: usize) -> usize {
        let tail_start = len - self.exact_tail.min(len);
        s1.saturating_sub(s0.max(tail_start))
    }

    /// The payload for a chunk this rank OWNS (its reduction is
    /// complete): raw floats under the identity codec; compressed ONCE
    /// with error feedback otherwise, adopting the decoded form locally
    /// so the owner's replica matches every receiver's bytes. `[s0, s1)`
    /// is a window of the logical `total`-element buffer.
    fn owned_chunk_payload(&mut self, data: &mut [f32], s0: usize,
                           s1: usize, total: usize) -> Payload {
        let step = self.next_step();
        if self.codec.is_identity() {
            Payload::floats(step, data[s0..s1].to_vec())
        } else {
            let protect = self.protect_len(total, s0, s1);
            let packed = self
                .compressor
                .compress_window(&data[s0..s1], s0, total, protect)
                .expect("lossy codec packs");
            packed.unpack_into(&mut data[s0..s1]);
            Payload::packed(step, 0.0, packed)
        }
    }

    /// Windowed flat-ring sum all-reduce over `data[w0..w1)`, chunked
    /// on the GLOBAL `total`-element grid (see
    /// [`Collective::window_chunk`]), running on `tag`. The full window
    /// `0..len` on `Tag::RingChunk` IS the monolithic all-reduce; a
    /// bucket's window on its own tag lane is one overlapped bucket.
    /// With `skip_first_send` the reduce-scatter's step-0 send is
    /// assumed already on the wire ([`Collective::bucket_begin`]).
    fn ring_sum_window(&mut self, data: &mut [f32], w0: usize,
                       w1: usize, total: usize, tag: Tag,
                       skip_first_send: bool) -> Result<(), CommError> {
        let (n, pos, next, prev) = self.ring()?;

        // Phase 1 — reduce-scatter over decoded f32: each hop carries
        // partial sums (compressed with error feedback under a lossy
        // codec — what this round drops rides along next round).
        for step in 0..n - 1 {
            let send_idx = (pos + n - step) % n;
            let recv_idx = (pos + 2 * n - step - 1) % n;
            if step > 0 || !skip_first_send {
                let (s0, s1) =
                    Self::window_chunk(total, n, send_idx, w0, w1);
                self.send_sum_chunk(next, tag, data, s0, s1, total)?;
            }
            let (r0, r1) = Self::window_chunk(total, n, recv_idx, w0, w1);
            let payload = self.recv_chunk(tag, prev, r1 - r0)?;
            Self::add_payload(&payload, &mut data[r0..r1],
                              self.pool.as_deref());
        }

        // Phase 2 — all-gather: the chunk owner builds its payload ONCE
        // and it is then forwarded verbatim around the ring, so every
        // rank adopts identical bytes.
        let mut carry: Option<Payload> = None;
        for step in 0..n - 1 {
            let send_idx = (pos + 1 + 2 * n - step) % n;
            let recv_idx = (pos + 2 * n - step) % n;
            let payload = match carry.take() {
                Some(p) => p,
                None => {
                    // step 0: our own completed chunk
                    let (s0, s1) =
                        Self::window_chunk(total, n, send_idx, w0, w1);
                    self.owned_chunk_payload(data, s0, s1, total)
                }
            };
            self.comm.send(next, tag, payload)?;
            let (r0, r1) = Self::window_chunk(total, n, recv_idx, w0, w1);
            let payload = self.recv_chunk(tag, prev, r1 - r0)?;
            Self::set_payload(&payload, &mut data[r0..r1],
                                  self.pool.as_deref());
            carry = Some(payload);
        }
        Ok(())
    }

    // --- hierarchical all-reduce (ring → tree → ring) ---------------

    /// Send the partial sums `data[s0..s1)` (a window of the logical
    /// `len`-element buffer) to `to`: raw under the identity codec,
    /// error-feedback-compressed otherwise (exact tail protected).
    fn send_sum_chunk(&mut self, to: Rank, tag: Tag, data: &[f32],
                      s0: usize, s1: usize, len: usize)
        -> Result<(), CommError> {
        if self.codec.is_identity() {
            let step = self.next_step();
            self.comm.send(to, tag,
                           Payload::floats(step,
                                           data[s0..s1].to_vec()))
        } else {
            let protect = self.protect_len(len, s0, s1);
            let packed = self
                .compressor
                .compress_window(&data[s0..s1], s0, len, protect)
                .expect("lossy codec packs");
            let step = self.next_step();
            self.comm.send(to, tag, Payload::packed(step, 0.0,
                                                    packed))
        }
    }

    /// Sum-accumulate a received raw-or-packed chunk into `dst` (the
    /// fp16 decode loop runs on `pool` when present).
    fn add_payload(payload: &Payload, dst: &mut [f32],
                   pool: Option<&ThreadPool>) {
        match payload {
            Payload::Packed { data, .. } => {
                data.add_into_pooled(dst, pool)
            }
            Payload::Floats { data, .. } => {
                for (d, &s) in dst.iter_mut().zip(data.iter()) {
                    *d += s;
                }
            }
            _ => unreachable!("recv_chunk validates the kind"),
        }
    }

    /// Overwrite `dst` with a received raw-or-packed chunk's decoded
    /// values (adoption hops: gather, broadcasts).
    fn set_payload(payload: &Payload, dst: &mut [f32],
                   pool: Option<&ThreadPool>) {
        match payload {
            Payload::Packed { data, .. } => {
                data.unpack_into_pooled(dst, pool)
            }
            Payload::Floats { data, .. } => dst.copy_from_slice(data),
            _ => unreachable!("recv_chunk validates the kind"),
        }
    }

    /// [`Collective::recv_chunk`] via the stashing receive — for hops
    /// where several peers legitimately send the same tag (tree
    /// children, group gathers).
    fn recv_chunk_stashing(&mut self, tag: Tag, from: Rank,
                           expect_len: usize)
        -> Result<Payload, CommError> {
        let env = self.recv_from_stashing(tag, from)?;
        Self::check_chunk(env, expect_len)
    }

    /// Binary-tree sum-reduce over `members` (position `p`'s parent is
    /// `(p-1)/2`): on return `members[0]` holds the element-wise sum of
    /// every member's input in a deterministic order (own subtree, then
    /// left child's, then right child's); other members hold partial
    /// sums that a following broadcast should overwrite. With a lossy
    /// codec, upward hops compress with error feedback. Must be called
    /// by every member with equal-length buffers.
    pub fn tree_reduce_sum(&mut self, members: &[Rank],
                           data: &mut [f32]) -> Result<(), CommError> {
        let len = data.len();
        self.tree_reduce_sum_window(members, data, 0, len, len,
                                    Tag::TreeReduce)
    }

    /// Windowed tree sum-reduce (see [`Collective::tree_reduce_sum`]):
    /// only `data[w0..w1)` of the logical `total`-element buffer is
    /// reduced, on `tag`.
    fn tree_reduce_sum_window(&mut self, members: &[Rank],
                              data: &mut [f32], w0: usize, w1: usize,
                              total: usize, tag: Tag)
        -> Result<(), CommError> {
        let pos = member_pos(members, self.comm.rank())?;
        for c in [2 * pos + 1, 2 * pos + 2] {
            if c < members.len() {
                let payload = self.recv_chunk_stashing(
                    tag, members[c], w1 - w0)?;
                Self::add_payload(&payload, &mut data[w0..w1],
                                  self.pool.as_deref());
            }
        }
        if pos > 0 {
            self.send_sum_chunk(members[(pos - 1) / 2], tag, data, w0,
                                w1, total)?;
        }
        Ok(())
    }

    /// Binary-tree broadcast from `members[0]`: every member adopts the
    /// root's window. The root builds the canonical payload ONCE via
    /// [`Collective::owned_chunk_payload`] (adopting the decoded form
    /// itself) and it is forwarded verbatim, so all members finish with
    /// identical bytes even under a lossy codec. Returns the payload so
    /// callers can keep forwarding it (the hierarchical all-reduce
    /// chains it into each group's ring).
    fn tree_bcast_window(&mut self, members: &[Rank], data: &mut [f32],
                         w0: usize, w1: usize, total: usize, tag: Tag)
        -> Result<Payload, CommError> {
        let pos = member_pos(members, self.comm.rank())?;
        let payload = if pos == 0 {
            self.owned_chunk_payload(data, w0, w1, total)
        } else {
            let parent = members[(pos - 1) / 2];
            let payload =
                self.recv_chunk_stashing(tag, parent, w1 - w0)?;
            Self::set_payload(&payload, &mut data[w0..w1],
                              self.pool.as_deref());
            payload
        };
        for c in [2 * pos + 1, 2 * pos + 2] {
            if c < members.len() {
                self.comm.send(members[c], tag, payload.clone())?;
            }
        }
        Ok(payload)
    }

    /// Public tree broadcast (reduce's companion): `members[0]`'s
    /// buffer replicated to every member in `ceil(log2 n)` hop levels.
    pub fn tree_broadcast(&mut self, members: &[Rank],
                          data: &mut [f32]) -> Result<(), CommError> {
        let len = data.len();
        self.tree_bcast_window(members, data, 0, len, len,
                               Tag::TreeBcast).map(|_| ())
    }

    /// This rank's group under the configured layout: (members, own
    /// position, leaders). Validates the layout against the world.
    fn hier_group(&self)
        -> Result<(Vec<Rank>, usize, Vec<Rank>), CommError> {
        let layout = self.groups.as_ref()
            .expect("hierarchical schedule requires a group layout");
        if layout.world_size() != self.n_ranks() {
            return Err(CommError::Protocol(format!(
                "collective: group layout covers {} ranks but the \
                 world has {}",
                layout.world_size(),
                self.n_ranks()
            )));
        }
        let rank = self.comm.rank();
        let gi = layout.group_of(rank).ok_or_else(|| {
            CommError::Protocol(format!(
                "collective: rank {rank} missing from the group layout"
            ))
        })?;
        let members = layout.groups()[gi].clone();
        let pos = member_pos(&members, rank)?;
        Ok((members, pos, layout.leaders()))
    }

    /// Hierarchical sum all-reduce (see the module docs): intra-group
    /// chunked ring reduce-scatter → gather onto the group leader →
    /// binary-tree reduce over leaders → the root's canonical payload
    /// travels back down the tree and around each group's ring
    /// verbatim. All ranks finish bitwise identical, raw or compressed.
    fn allreduce_hier(&mut self, data: &mut [f32])
        -> Result<(), CommError> {
        let len = data.len();
        self.hier_sum_window(data, 0, len, len, &MONOLITH_HIER_TAGS,
                             false)
    }

    /// Windowed hierarchical sum all-reduce over `data[w0..w1)` of the
    /// logical `total`-element buffer, on the tag lanes `tags`. The
    /// full window on [`MONOLITH_HIER_TAGS`] IS the monolithic
    /// hierarchical all-reduce; a bucket's window on its own lanes is
    /// one overlapped bucket. Intra-group chunks sit on the GLOBAL
    /// per-group grid (see [`Collective::window_chunk`]) so bucketing
    /// never changes any element's reduction order. `skip_first_send`:
    /// the intra-ring's step-0 send already happened in
    /// [`Collective::bucket_begin`].
    fn hier_sum_window(&mut self, data: &mut [f32], w0: usize,
                       w1: usize, total: usize, hier: &HierTags,
                       skip_first_send: bool) -> Result<(), CommError> {
        let (members, pos, leaders) = self.hier_group()?;
        let m = members.len();

        // Phase 1 — intra-group chunked ring reduce-scatter (the flat
        // ring's schedule over the group's members): after m-1 steps,
        // position p owns the complete group sum of chunk (p+1) mod m.
        // Dedicated tags (never RingChunk/Bcast): a rank's group-ring
        // neighbor differs from its flat-ring neighbor, and flat
        // collectives (the initial broadcast, scalar agreements)
        // interleave with grouped rounds — shared tags would make a
        // fast rank's grouped chunk look like a flat chunk from the
        // wrong source.
        if m > 1 {
            let next = members[(pos + 1) % m];
            let prev = members[(pos + m - 1) % m];
            for step in 0..m - 1 {
                let send_idx = (pos + m - step) % m;
                let recv_idx = (pos + 2 * m - step - 1) % m;
                if step > 0 || !skip_first_send {
                    let (s0, s1) =
                        Self::window_chunk(total, m, send_idx, w0, w1);
                    self.send_sum_chunk(next, hier.chunk, data, s0, s1,
                                        total)?;
                }
                let (r0, r1) =
                    Self::window_chunk(total, m, recv_idx, w0, w1);
                let payload =
                    self.recv_chunk(hier.chunk, prev, r1 - r0)?;
                Self::add_payload(&payload, &mut data[r0..r1],
                              self.pool.as_deref());
            }
            // Phase 2 — gather the scattered chunks onto the leader so
            // it holds the full group sum for the inter-group tree.
            // (These are adoption hops: each chunk's group sum exists
            // only on its owner.)
            if pos == 0 {
                for (p, &src) in members.iter().enumerate().skip(1) {
                    let (r0, r1) =
                        Self::window_chunk(total, m, (p + 1) % m, w0,
                                           w1);
                    let payload = self.recv_chunk_stashing(
                        hier.gather, src, r1 - r0)?;
                    Self::set_payload(&payload, &mut data[r0..r1],
                                  self.pool.as_deref());
                }
            } else {
                let (s0, s1) =
                    Self::window_chunk(total, m, (pos + 1) % m, w0, w1);
                self.send_sum_chunk(members[0], hier.gather, data, s0,
                                    s1, total)?;
            }
        }

        if pos == 0 {
            // Phases 3-4 — leaders only: combine group sums up the
            // binary tree, then carry the canonical result back down.
            self.tree_reduce_sum_window(&leaders, data, w0, w1, total,
                                        hier.tree_reduce)?;
            let payload = self.tree_bcast_window(&leaders, data, w0, w1,
                                                 total,
                                                 hier.tree_bcast)?;
            // Phase 5 — re-broadcast into the group's ring: the SAME
            // payload chains leader → members[1] → … → members[m-1].
            if m > 1 {
                self.comm.send(members[1], hier.bcast, payload)?;
            }
        } else {
            // Phase 5, member side: adopt the canonical payload from
            // the ring predecessor and forward it verbatim.
            let payload = self.recv_chunk(hier.bcast, members[pos - 1],
                                          w1 - w0)?;
            Self::set_payload(&payload, &mut data[w0..w1],
                              self.pool.as_deref());
            if pos + 1 < m {
                self.comm.send(members[pos + 1], hier.bcast, payload)?;
            }
        }
        Ok(())
    }

    // --- bucketed (compute-overlapped) sum all-reduce ---------------

    /// Launch the sum all-reduce of one bucket — the window `[w0, w1)`
    /// of the logical `total`-element round buffer — and return
    /// immediately: only the schedule's first wire send happens here;
    /// everything else (including every receive) is deferred to
    /// [`Collective::bucket_finish_sum`]. Launching each bucket as its
    /// layer's backward completes puts that chunk on the wire while
    /// upstream layers are still computing — the comm/compute overlap.
    /// `data` only needs `w1` elements (the round buffer's tail may not
    /// exist yet when early buckets launch).
    ///
    /// Buckets run on dedicated `(bucket, phase)` tag lanes
    /// ([`crate::mpi::tags`]), so up to `MAX_BUCKETS` may be
    /// outstanding without cross-talk, and windows chunk on the GLOBAL
    /// grid — so fp32/fp16 results stay bitwise identical to the
    /// monolithic all-reduce over the same buffer. All ranks must
    /// launch the same buckets in the same order (lockstep SPMD).
    pub fn bucket_begin(&mut self, bucket: usize, data: &[f32],
                        w0: usize, w1: usize, total: usize)
        -> Result<(), CommError> {
        assert!(w0 <= w1 && w1 <= total && w1 <= data.len(),
                "bucket window [{w0}, {w1}) out of bounds \
                 (total {total}, data {})", data.len());
        let mut first_sent = false;
        if self.n_ranks() > 1 {
            let tag = tags::bucket_tag(bucket, BucketPhase::Chunk);
            if self.groups.is_some() {
                // hierarchical: step 0 of the intra-group ring
                // reduce-scatter (send_idx at step 0 is own position)
                let (members, pos, _) = self.hier_group()?;
                let m = members.len();
                if m > 1 {
                    let next = members[(pos + 1) % m];
                    let (s0, s1) =
                        Self::window_chunk(total, m, pos, w0, w1);
                    self.send_sum_chunk(next, tag, data, s0, s1,
                                        total)?;
                    first_sent = true;
                }
            } else {
                // flat ring: step 0's send chunk is the rank's own
                let (n, pos, next, _) = self.ring()?;
                let (s0, s1) = Self::window_chunk(total, n, pos, w0, w1);
                self.send_sum_chunk(next, tag, data, s0, s1, total)?;
                first_sent = true;
            }
        }
        self.pending.push(PendingBucket {
            bucket, w0, w1, total, first_sent,
        });
        Ok(())
    }

    /// Complete every outstanding bucket, in launch order: the rest of
    /// each bucket's reduce-scatter plus the all-gather (or the
    /// hierarchical gather/tree/broadcast) that replicates its reduced
    /// window. `data` is the full `total`-element round buffer. On
    /// return the pending list is empty and every launched window of
    /// `data` holds the world sum, bitwise identical on all ranks.
    pub fn bucket_finish_sum(&mut self, data: &mut [f32])
        -> Result<(), CommError> {
        let pending = std::mem::take(&mut self.pending);
        if self.n_ranks() <= 1 {
            return Ok(());
        }
        for pb in pending {
            debug_assert_eq!(data.len(), pb.total,
                             "finish buffer must be the round's full \
                              logical buffer");
            if self.groups.is_some() {
                let hier = bucket_hier_tags(pb.bucket);
                self.hier_sum_window(data, pb.w0, pb.w1, pb.total,
                                     &hier, pb.first_sent)?;
            } else {
                let tag =
                    tags::bucket_tag(pb.bucket, BucketPhase::Chunk);
                self.ring_sum_window(data, pb.w0, pb.w1, pb.total, tag,
                                     pb.first_sent)?;
            }
        }
        Ok(())
    }

    /// Buckets launched and not yet finished.
    pub fn pending_buckets(&self) -> usize {
        self.pending.len()
    }

    /// Single-value all-reduce convenience (e.g. agreeing on the common
    /// per-epoch round count via `ReduceOp::Min`). Exact for integral
    /// values below 2^24: scalar agreements are control-plane values,
    /// so they always travel raw regardless of the configured codec.
    pub fn allreduce_scalar(&mut self, value: f32, op: ReduceOp)
        -> Result<f32, CommError> {
        let mut buf = [value];
        if self.n_ranks() > 1 {
            self.allreduce_raw(&mut buf, op)?;
        }
        Ok(buf[0])
    }

    /// Ring broadcast from `root`: each rank adopts the root's buffer.
    /// The payload travels the ring once as a shared `Arc`, so the
    /// inproc transport forwards it without re-copying.
    pub fn broadcast(&mut self, root: Rank, data: &mut Vec<f32>)
        -> Result<(), CommError> {
        if root >= self.comm.size() {
            return Err(CommError::InvalidRank { rank: root,
                                                size: self.comm.size() });
        }
        let (m, pos, next, prev) = self.ring()?;
        if m <= 1 {
            return Ok(());
        }
        // positional: the root may sit anywhere in a replanned
        // member list
        let root_pos = match &self.members {
            None => root,
            Some(members) => member_pos(members, root)?,
        };
        let step = self.next_step();
        if pos == root_pos {
            self.comm.send(next, Tag::Bcast,
                           Payload::floats(step, data.clone()))?;
        } else {
            let payload = self.recv_floats(Tag::Bcast, prev, None)?;
            data.clear();
            data.extend_from_slice(&payload);
            if (pos + 1) % m != root_pos {
                self.comm.send(next, Tag::Bcast,
                               Payload::floats_shared(step, payload))?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::inproc_world;
    use crate::mpi::message::WorkerStats;

    /// Reference reduction matching the ring's deterministic order:
    /// chunk `c` is accumulated starting at rank `c`, then ranks
    /// c+1, …, c+n-1 (mod n) — so results must match *bitwise*.
    fn ring_order_reference(inputs: &[Vec<f32>], op: ReduceOp) -> Vec<f32> {
        let n = inputs.len();
        let len = inputs[0].len();
        let mut out = vec![0.0f32; len];
        for c in 0..n {
            let (lo, hi) = Collective::chunk_bounds(len, n, c);
            for j in lo..hi {
                let mut acc = inputs[c][j];
                for k in 1..n {
                    op.apply(&mut acc, inputs[(c + k) % n][j]);
                }
                out[j] = acc;
            }
        }
        out
    }

    fn run_allreduce(n: usize, len: usize, op: ReduceOp)
        -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut rng = crate::util::rng::Rng::new(n as u64 * 31 + len as u64);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.normal_f32(0.0, 2.0)).collect())
            .collect();
        let reference = ring_order_reference(&inputs, op);
        let world = inproc_world(n);
        let results: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = world
                .into_iter()
                .zip(inputs.iter())
                .map(|(comm, input)| {
                    let mut buf = input.clone();
                    s.spawn(move || {
                        let mut col = Collective::new(&comm);
                        col.allreduce(&mut buf, op).unwrap();
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        (results, reference)
    }

    #[test]
    fn chunk_bounds_partition_any_length() {
        for n in 1..9usize {
            for len in [0usize, 1, 2, 3, 7, 8, 100, 101] {
                let mut covered = 0usize;
                for i in 0..n {
                    let (lo, hi) = Collective::chunk_bounds(len, n, i);
                    assert_eq!(lo, covered, "len={len} n={n} i={i}");
                    assert!(hi >= lo);
                    covered = hi;
                }
                assert_eq!(covered, len);
            }
        }
    }

    #[test]
    fn allreduce_sum_matches_serial_and_is_identical_across_ranks() {
        for n in [2usize, 3, 4, 5] {
            for len in [1usize, 3, 7, 64, 65] {
                let (results, reference) = run_allreduce(n, len,
                                                         ReduceOp::Sum);
                for (r, got) in results.iter().enumerate() {
                    assert_eq!(got, &reference, "rank {r}, n={n} len={len}");
                }
            }
        }
    }

    #[test]
    fn allreduce_min_and_max() {
        let (res_min, ref_min) = run_allreduce(4, 13, ReduceOp::Min);
        for got in &res_min {
            assert_eq!(got, &ref_min);
        }
        let (res_max, ref_max) = run_allreduce(3, 5, ReduceOp::Max);
        for got in &res_max {
            assert_eq!(got, &ref_max);
        }
    }

    #[test]
    fn allreduce_single_rank_is_identity() {
        let world = inproc_world(1);
        let mut col = Collective::new(&world[0]);
        let mut data = vec![1.0f32, -2.0, 3.5];
        col.allreduce(&mut data, ReduceOp::Sum).unwrap();
        assert_eq!(data, vec![1.0, -2.0, 3.5]);
        assert_eq!(col.allreduce_scalar(9.0, ReduceOp::Min).unwrap(), 9.0);
    }

    #[test]
    fn scalar_min_agrees_on_smallest() {
        let n = 5;
        let world = inproc_world(n);
        let results: Vec<f32> = std::thread::scope(|s| {
            let handles: Vec<_> = world
                .into_iter()
                .enumerate()
                .map(|(r, comm)| {
                    s.spawn(move || {
                        let mut col = Collective::new(&comm);
                        col.allreduce_scalar(10.0 + r as f32,
                                             ReduceOp::Min)
                            .unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(results.iter().all(|&v| v == 10.0), "{results:?}");
    }

    #[test]
    fn broadcast_replicates_root_buffer() {
        for root in [0usize, 2] {
            let n = 4;
            let world = inproc_world(n);
            let payload: Vec<f32> = (0..33).map(|i| i as f32 * 0.25).collect();
            let results: Vec<Vec<f32>> = std::thread::scope(|s| {
                let handles: Vec<_> = world
                    .into_iter()
                    .enumerate()
                    .map(|(r, comm)| {
                        let mut buf = if r == root {
                            payload.clone()
                        } else {
                            Vec::new()
                        };
                        s.spawn(move || {
                            let mut col = Collective::new(&comm);
                            col.broadcast(root, &mut buf).unwrap();
                            buf
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for got in &results {
                assert_eq!(got, &payload, "root={root}");
            }
        }
    }

    #[test]
    fn unrelated_traffic_is_stashed_not_lost() {
        // Rank 1 fires a TrainStats at rank 0 *before* the collective;
        // the all-reduce must still complete and the stats must come
        // back out of the stash.
        let mut world = inproc_world(2);
        let c1 = world.pop().unwrap();
        let c0 = world.pop().unwrap();
        let stats = WorkerStats { epoch: 3, ..Default::default() };
        let handle = std::thread::spawn(move || {
            c1.send(0, Tag::TrainStats, Payload::Stats(stats)).unwrap();
            let mut col = Collective::new(&c1);
            let mut buf = vec![1.0f32; 10];
            col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
            buf
        });
        let mut col = Collective::new(&c0);
        let mut buf = vec![2.0f32; 10];
        col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
        assert!(buf.iter().all(|&v| v == 3.0));
        let stash = col.into_stash();
        assert_eq!(stash.len(), 1);
        assert_eq!(stash[0].tag, Tag::TrainStats);
        assert_eq!(stash[0].payload, Payload::Stats(stats));
        let other = handle.join().unwrap();
        assert!(other.iter().all(|&v| v == 3.0));
    }

    #[test]
    fn broadcast_bad_root_rejected() {
        let world = inproc_world(2);
        let mut col = Collective::new(&world[0]);
        let mut buf = vec![0.0f32];
        assert!(matches!(col.broadcast(7, &mut buf),
                         Err(CommError::InvalidRank { .. })));
    }

    // --- compressed collectives -----------------------------------

    use crate::mpi::codec::Codec;

    /// Run one compressed all-reduce; returns (per-rank results,
    /// per-rank wire bytes sent during it).
    fn run_compressed(n: usize, inputs: &[Vec<f32>], codec: Codec,
                      tail: usize, rounds: usize)
        -> (Vec<Vec<f32>>, Vec<u64>) {
        let world = inproc_world(n);
        std::thread::scope(|s| {
            let handles: Vec<_> = world
                .into_iter()
                .zip(inputs.iter())
                .map(|(comm, input)| {
                    s.spawn(move || {
                        let mut col = Collective::new(&comm);
                        col.set_codec(codec);
                        col.set_exact_tail(tail);
                        let mut buf = input.clone();
                        let before = comm.bytes_sent();
                        for r in 0..rounds {
                            if r > 0 {
                                buf.copy_from_slice(input);
                            }
                            col.allreduce(&mut buf, ReduceOp::Sum)
                                .unwrap();
                        }
                        (buf, comm.bytes_sent() - before)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).unzip()
        })
    }

    fn random_inputs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..n)
            .map(|_| (0..len).map(|_| rng.normal_f32(0.0, 2.0)).collect())
            .collect()
    }

    #[test]
    fn compressed_allreduce_is_bitwise_identical_across_ranks() {
        for codec in [Codec::Fp16, Codec::TopK { k: 0.25 }] {
            for n in [2usize, 3, 4, 5] {
                for len in [1usize, 3, 7, 64, 65] {
                    let inputs = random_inputs(
                        n, len, n as u64 * 131 + len as u64);
                    let (results, _) =
                        run_compressed(n, &inputs, codec, 0, 1);
                    let reference = &results[0];
                    for (r, got) in results.iter().enumerate() {
                        assert!(
                            got.iter().zip(reference.iter()).all(
                                |(a, b)| a.to_bits() == b.to_bits()),
                            "rank {r} diverged ({codec:?}, n={n}, \
                             len={len})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fp16_allreduce_tracks_exact_sum() {
        let n = 4;
        let len = 64;
        let inputs = random_inputs(n, len, 99);
        let reference = ring_order_reference(&inputs, ReduceOp::Sum);
        let (results, _) =
            run_compressed(n, &inputs, Codec::Fp16, 0, 1);
        for (got, want) in results[0].iter().zip(&reference) {
            // fp16 has ~2^-11 relative precision per hop; a 4-rank
            // chain stays well inside 1%
            assert!((got - want).abs() <= 0.01 * want.abs() + 0.01,
                    "fp16 sum {got} too far from {want}");
        }
    }

    #[test]
    fn exact_tail_survives_topk() {
        // body elements are huge, tail elements tiny: without
        // protection top-k would drop the tail every time
        let n = 4;
        let len = 34; // 32 body + loss + stop flag
        let mut inputs = random_inputs(n, len, 7);
        for (r, input) in inputs.iter_mut().enumerate() {
            for v in input.iter_mut() {
                *v *= 100.0;
            }
            input[len - 2] = 0.25 + r as f32; // loss-like, f32-exact
            input[len - 1] = if r == 2 { 1.0 } else { 0.0 }; // flag
        }
        let reference = ring_order_reference(&inputs, ReduceOp::Sum);
        let (results, _) = run_compressed(
            n, &inputs, Codec::TopK { k: 0.1 }, 2, 1);
        for got in &results {
            assert_eq!(got[len - 2], reference[len - 2],
                       "protected loss must be the exact f32 chain sum");
            assert_eq!(got[len - 1], 1.0, "stop flag must survive");
        }
    }

    #[test]
    fn min_max_and_scalar_ignore_the_codec() {
        // Min/Max reductions and scalar agreements must stay exact
        // even when a lossy codec is configured (raw fallback) —
        // including SUM scalars whose values fp16 cannot represent.
        let n = 3;
        let world = inproc_world(n);
        let results: Vec<(f32, f32, Vec<f32>)> =
            std::thread::scope(|s| {
                let handles: Vec<_> = world
                    .into_iter()
                    .enumerate()
                    .map(|(r, comm)| {
                        s.spawn(move || {
                            let mut col = Collective::new(&comm);
                            col.set_codec(Codec::Fp16);
                            let min = col
                                .allreduce_scalar(10.0 + r as f32,
                                                  ReduceOp::Min)
                                .unwrap();
                            // 70001+70002+70003: each addend already
                            // overflows fp16 — must stay exact
                            let sum = col
                                .allreduce_scalar(
                                    70001.0 + r as f32,
                                    ReduceOp::Sum)
                                .unwrap();
                            col.set_codec(Codec::TopK { k: 0.1 });
                            let mut buf = vec![r as f32 + 0.125; 8];
                            col.allreduce(&mut buf, ReduceOp::Max)
                                .unwrap();
                            (min, sum, buf)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
        for (min, sum, maxes) in &results {
            assert_eq!(*min, 10.0);
            assert_eq!(*sum, 210_006.0);
            assert!(maxes.iter().all(|&v| v == 2.125));
        }
    }

    #[test]
    fn compression_cuts_wire_bytes_per_round() {
        let n = 4;
        let len = 4098; // gradient-sized, non-divisible by n
        let inputs = random_inputs(n, len, 5);
        let rounds = 3;
        let bytes = |codec| {
            let (_, b) = run_compressed(n, &inputs, codec, 2, rounds);
            b.iter().sum::<u64>() as f64 / rounds as f64
        };
        let raw = bytes(Codec::Fp32);
        let fp16 = bytes(Codec::Fp16);
        let topk = bytes(Codec::TopK { k: 0.1 });
        assert!(fp16 < 0.6 * raw,
                "fp16 {fp16} should be < 60% of fp32 {raw}");
        assert!(topk < 0.25 * raw,
                "topk:0.1 {topk} should be < 25% of fp32 {raw}");
    }

    // --- hierarchical collectives -----------------------------------

    /// Reference reduction matching the hierarchical schedule's
    /// deterministic order: each group's sum in its ring order (see
    /// [`ring_order_reference`]), then the binary tree's fold at the
    /// root (own subtree, then left child's total, then right child's).
    fn hier_order_reference(inputs: &[Vec<f32>], layout: &GroupLayout)
        -> Vec<f32> {
        let group_sums: Vec<Vec<f32>> = layout
            .groups()
            .iter()
            .map(|members| {
                let ins: Vec<Vec<f32>> = members
                    .iter()
                    .map(|&r| inputs[r].clone())
                    .collect();
                ring_order_reference(&ins, ReduceOp::Sum)
            })
            .collect();
        fn tree_val(p: usize, sums: &[Vec<f32>]) -> Vec<f32> {
            let mut acc = sums[p].clone();
            for c in [2 * p + 1, 2 * p + 2] {
                if c < sums.len() {
                    for (a, b) in
                        acc.iter_mut().zip(tree_val(c, sums))
                    {
                        *a += b;
                    }
                }
            }
            acc
        }
        tree_val(0, &group_sums)
    }

    fn run_hier(n: usize, layout: &GroupLayout, inputs: &[Vec<f32>],
                codec: Codec, tail: usize) -> Vec<Vec<f32>> {
        let world = inproc_world(n);
        std::thread::scope(|s| {
            let handles: Vec<_> = world
                .into_iter()
                .zip(inputs.iter())
                .map(|(comm, input)| {
                    let layout = layout.clone();
                    s.spawn(move || {
                        let mut col = Collective::new(&comm);
                        col.set_codec(codec);
                        col.set_exact_tail(tail);
                        col.set_groups(Some(layout));
                        let mut buf = input.clone();
                        col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn group_layout_validation() {
        let l = GroupLayout::contiguous(8, 2).unwrap();
        assert_eq!(l.groups(), &[vec![0, 1, 2, 3], vec![4, 5, 6, 7]]);
        assert_eq!(l.leaders(), vec![0, 4]);
        assert_eq!(l.n_groups(), 2);
        assert_eq!(l.world_size(), 8);
        assert_eq!(l.group_of(5), Some(1));
        assert_eq!(l.group_of(9), None);
        assert!(GroupLayout::contiguous(8, 3).is_err(), "non-divisible");
        assert!(GroupLayout::contiguous(0, 2).is_err());
        assert!(GroupLayout::new(vec![]).is_err());
        assert!(GroupLayout::new(vec![vec![0], vec![]]).is_err());
        assert!(GroupLayout::new(vec![vec![0, 1], vec![1, 2]]).is_err(),
                "overlapping groups");
    }

    #[test]
    fn tree_reduce_then_broadcast_replicates_sum() {
        // 6 members: an unbalanced binary tree (positions 3..5 are
        // leaves at different depths). Integer inputs make the sum
        // order-independent, so exact equality is required.
        let n = 6;
        let members: Vec<usize> = (0..n).collect();
        let world = inproc_world(n);
        let results: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = world
                .into_iter()
                .enumerate()
                .map(|(r, comm)| {
                    let members = members.clone();
                    s.spawn(move || {
                        let mut col = Collective::new(&comm);
                        let mut buf =
                            vec![(r + 1) as f32, -(r as f32)];
                        col.tree_reduce_sum(&members, &mut buf)
                            .unwrap();
                        col.tree_broadcast(&members, &mut buf).unwrap();
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for got in &results {
            assert_eq!(got, &vec![21.0, -15.0]);
        }
    }

    #[test]
    fn tree_collectives_work_on_a_rank_subset() {
        // Only ranks 0, 2, 4 of a 5-rank world join the tree; the
        // others stay idle — the subset schedule must not involve them.
        let world = inproc_world(5);
        let members = vec![0usize, 2, 4];
        let results: Vec<Option<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = world
                .into_iter()
                .enumerate()
                .map(|(r, comm)| {
                    let members = members.clone();
                    s.spawn(move || {
                        if !members.contains(&r) {
                            return None;
                        }
                        let mut col = Collective::new(&comm);
                        let mut buf = vec![r as f32 + 1.0];
                        col.tree_reduce_sum(&members, &mut buf)
                            .unwrap();
                        col.tree_broadcast(&members, &mut buf).unwrap();
                        Some(buf[0])
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (r, got) in results.iter().enumerate() {
            match got {
                Some(v) => assert_eq!(*v, 9.0, "member {r}"),
                None => assert!(!members.contains(&r)),
            }
        }
    }

    #[test]
    fn hier_allreduce_matches_reference_and_is_identical() {
        // The raw hierarchical schedule is exactly as deterministic as
        // the flat ring: every rank must match the reference BITWISE.
        for (n, g) in [(4usize, 2usize), (6, 2), (6, 3), (8, 2),
                       (8, 4), (9, 3)] {
            let layout = GroupLayout::contiguous(n, g).unwrap();
            for len in [1usize, 3, 7, 64, 65] {
                let inputs = random_inputs(
                    n, len, n as u64 * 977 + g as u64 * 31 + len as u64);
                let reference = hier_order_reference(&inputs, &layout);
                let results =
                    run_hier(n, &layout, &inputs, Codec::Fp32, 0);
                for (r, got) in results.iter().enumerate() {
                    assert!(
                        got.iter().zip(reference.iter()).all(
                            |(a, b)| a.to_bits() == b.to_bits()),
                        "rank {r} != reference (n={n} g={g} len={len})"
                    );
                }
            }
        }
    }

    #[test]
    fn hier_allreduce_compressed_bitwise_identical_across_ranks() {
        for codec in [Codec::Fp16, Codec::TopK { k: 0.25 }] {
            for (n, g) in [(4usize, 2usize), (8, 2), (8, 4), (9, 3)] {
                let layout = GroupLayout::contiguous(n, g).unwrap();
                for len in [1usize, 7, 65] {
                    let inputs = random_inputs(
                        n, len,
                        n as u64 * 389 + g as u64 * 7 + len as u64);
                    let results =
                        run_hier(n, &layout, &inputs, codec, 0);
                    let reference = &results[0];
                    for (r, got) in results.iter().enumerate() {
                        assert!(
                            got.iter().zip(reference.iter()).all(
                                |(a, b)| a.to_bits() == b.to_bits()),
                            "rank {r} diverged ({codec:?}, n={n}, \
                             g={g}, len={len})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn hier_fp16_tracks_exact_sum() {
        let n = 8;
        let len = 64;
        let layout = GroupLayout::contiguous(n, 2).unwrap();
        let inputs = random_inputs(n, len, 271);
        let reference = hier_order_reference(&inputs, &layout);
        let results = run_hier(n, &layout, &inputs, Codec::Fp16, 0);
        for (got, want) in results[0].iter().zip(&reference) {
            assert!((got - want).abs() <= 0.02 * want.abs() + 0.02,
                    "fp16 hier sum {got} too far from {want}");
        }
    }

    #[test]
    fn hier_exact_tail_survives_topk() {
        let n = 8;
        let len = 34; // 32 body + loss + stop flag
        let layout = GroupLayout::contiguous(n, 2).unwrap();
        let mut inputs = random_inputs(n, len, 17);
        for (r, input) in inputs.iter_mut().enumerate() {
            for v in input.iter_mut() {
                *v *= 100.0;
            }
            input[len - 2] = 0.25 + r as f32;
            input[len - 1] = if r == 5 { 1.0 } else { 0.0 };
        }
        let results = run_hier(n, &layout, &inputs,
                               Codec::TopK { k: 0.1 }, 2);
        for got in &results {
            assert!(got[len - 1] >= 1.0, "stop flag must survive");
        }
        // the protected tail also stays bitwise identical everywhere
        for got in &results {
            assert_eq!(got[len - 2].to_bits(),
                       results[0][len - 2].to_bits());
        }
    }

    #[test]
    fn hier_min_max_fall_back_to_flat_raw_ring() {
        // Min/Max ignore the layout (control-plane reductions).
        let n = 6;
        let layout = GroupLayout::contiguous(n, 2).unwrap();
        let world = inproc_world(n);
        let results: Vec<f32> = std::thread::scope(|s| {
            let handles: Vec<_> = world
                .into_iter()
                .enumerate()
                .map(|(r, comm)| {
                    let layout = layout.clone();
                    s.spawn(move || {
                        let mut col = Collective::new(&comm);
                        col.set_groups(Some(layout));
                        col.allreduce_scalar(10.0 + r as f32,
                                             ReduceOp::Min)
                            .unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(results.iter().all(|&v| v == 10.0), "{results:?}");
    }

    #[test]
    fn hier_layout_must_cover_the_world() {
        let world = inproc_world(3);
        let mut col = Collective::new(&world[0]);
        col.set_groups(Some(GroupLayout::contiguous(2, 2).unwrap()));
        let mut buf = vec![0.0f32; 4];
        assert!(matches!(col.allreduce(&mut buf, ReduceOp::Sum),
                         Err(CommError::Protocol(_))));
    }

    #[test]
    fn hier_allreduce_repeated_rounds_stay_identical() {
        // Error feedback carries state across rounds; ranks must stay
        // bitwise identical on every round, not just the first.
        let n = 8;
        let len = 40;
        let layout = GroupLayout::contiguous(n, 4).unwrap();
        let inputs = random_inputs(n, len, 23);
        let world = inproc_world(n);
        let per_round: Vec<Vec<Vec<f32>>> = std::thread::scope(|s| {
            let handles: Vec<_> = world
                .into_iter()
                .zip(inputs.iter())
                .map(|(comm, input)| {
                    let layout = layout.clone();
                    s.spawn(move || {
                        let mut col = Collective::new(&comm);
                        col.set_codec(Codec::TopK { k: 0.2 });
                        col.set_groups(Some(layout));
                        let mut rounds = Vec::new();
                        let mut buf = input.clone();
                        for r in 0..4 {
                            if r > 0 {
                                buf.copy_from_slice(input);
                            }
                            col.allreduce(&mut buf, ReduceOp::Sum)
                                .unwrap();
                            rounds.push(buf.clone());
                        }
                        rounds
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for round in 0..4 {
            let reference = &per_round[0][round];
            for (r, rank_rounds) in per_round.iter().enumerate() {
                assert!(
                    rank_rounds[round]
                        .iter()
                        .zip(reference.iter())
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "rank {r} diverged on round {round}"
                );
            }
        }
    }

    #[test]
    fn error_feedback_delivers_dropped_mass_over_rounds() {
        // Repeatedly all-reduce the SAME gradients under heavy top-k:
        // cumulative delivered mass must track rounds * true sum
        // (residuals bounded), the property that keeps top-k training
        // convergent.
        let n = 4;
        let len = 40;
        let inputs = random_inputs(n, len, 11);
        let true_sum = ring_order_reference(&inputs, ReduceOp::Sum);
        let rounds = 300;
        let world = inproc_world(n);
        let applied: Vec<Vec<f64>> = std::thread::scope(|s| {
            let handles: Vec<_> = world
                .into_iter()
                .zip(inputs.iter())
                .map(|(comm, input)| {
                    s.spawn(move || {
                        let mut col = Collective::new(&comm);
                        col.set_codec(Codec::TopK { k: 0.1 });
                        let mut total = vec![0.0f64; input.len()];
                        let mut buf = input.clone();
                        for r in 0..rounds {
                            if r > 0 {
                                buf.copy_from_slice(input);
                            }
                            col.allreduce(&mut buf, ReduceOp::Sum)
                                .unwrap();
                            for (t, &v) in total.iter_mut().zip(&buf) {
                                *t += v as f64;
                            }
                        }
                        total
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut err2 = 0.0f64;
        let mut ref2 = 0.0f64;
        for (i, &want) in true_sum.iter().enumerate() {
            let target = rounds as f64 * want as f64;
            err2 += (applied[0][i] - target).powi(2);
            ref2 += target.powi(2);
        }
        let rel = (err2 / ref2).sqrt();
        assert!(rel < 0.05,
                "cumulative delivery drifted: rel err {rel:.4}");
    }

    // --- bucketed (compute-overlapped) collectives ------------------

    #[test]
    fn window_chunks_tile_the_global_grid() {
        // For any window partition of 0..total, the non-empty
        // window∩chunk intersections tile each global chunk exactly —
        // the invariant that makes bucketing order-preserving.
        for n in [1usize, 2, 3, 5, 8] {
            for total in [0usize, 1, 5, 9, 64, 65] {
                let mut windows: Vec<(usize, usize)> = Vec::new();
                let mut lo = 0;
                for c in [total / 5, total / 3, total / 2, total] {
                    let hi = c.max(lo);
                    windows.push((lo, hi));
                    lo = hi;
                }
                for i in 0..n {
                    let (c0, c1) =
                        Collective::chunk_bounds(total, n, i);
                    let mut covered = c0;
                    for &(w0, w1) in &windows {
                        let (s0, s1) = Collective::window_chunk(
                            total, n, i, w0, w1);
                        assert!(s0 <= s1 && w0 <= s0 && s1 <= w1,
                                "n={n} total={total} i={i} \
                                 window=({w0},{w1})");
                        if s0 < s1 {
                            assert_eq!(s0, covered);
                            covered = s1;
                        }
                    }
                    assert_eq!(covered, c1,
                               "chunk {i} not tiled (n={n}, \
                                total={total})");
                }
            }
        }
    }

    /// Bucketed rounds: every window launched via `bucket_begin` (in
    /// order), then completed with one `bucket_finish_sum` — the
    /// worker's overlap schedule, minus the interleaved compute.
    fn run_bucketed(n: usize, inputs: &[Vec<f32>], codec: Codec,
                    tail: usize, windows: &[(usize, usize)],
                    layout: Option<&GroupLayout>, rounds: usize)
        -> Vec<Vec<f32>> {
        let world = inproc_world(n);
        std::thread::scope(|s| {
            let handles: Vec<_> = world
                .into_iter()
                .zip(inputs.iter())
                .map(|(comm, input)| {
                    let layout = layout.cloned();
                    s.spawn(move || {
                        let mut col = Collective::new(&comm);
                        col.set_codec(codec);
                        col.set_exact_tail(tail);
                        col.set_groups(layout);
                        let total = input.len();
                        let mut buf = input.clone();
                        for r in 0..rounds {
                            if r > 0 {
                                buf.copy_from_slice(input);
                            }
                            for (b, &(w0, w1)) in
                                windows.iter().enumerate()
                            {
                                col.bucket_begin(b, &buf, w0, w1,
                                                 total).unwrap();
                            }
                            assert_eq!(col.pending_buckets(),
                                       windows.len());
                            col.bucket_finish_sum(&mut buf).unwrap();
                            assert_eq!(col.pending_buckets(), 0);
                        }
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    /// Uneven layer-shaped windows over a 65-element buffer, including
    /// an empty window and a 2-element tail bucket.
    const WINDOWS_65: &[(usize, usize)] =
        &[(0, 20), (20, 23), (23, 23), (23, 63), (63, 65)];

    #[test]
    fn bucketed_allreduce_matches_monolithic_bitwise() {
        // fp32 AND fp16: splitting the round into buckets must not
        // change a single bit vs one monolithic all-reduce — global
        // chunking preserves every element's reduction order, and the
        // error-feedback residual sees identical windows. Checked over
        // multiple rounds so residual state is covered too.
        let rounds = 3;
        for codec in [Codec::Fp32, Codec::Fp16] {
            for n in [2usize, 3, 4, 8] {
                let len = 65;
                let inputs =
                    random_inputs(n, len, n as u64 * 541 + 13);
                let (mono, _) =
                    run_compressed(n, &inputs, codec, 2, rounds);
                let bucketed = run_bucketed(n, &inputs, codec, 2,
                                            WINDOWS_65, None, rounds);
                for (r, (got, want)) in
                    bucketed.iter().zip(mono.iter()).enumerate()
                {
                    assert!(
                        got.iter().zip(want.iter()).all(
                            |(a, b)| a.to_bits() == b.to_bits()),
                        "rank {r}: bucketed != monolithic \
                         ({codec:?}, n={n})"
                    );
                }
            }
        }
    }

    #[test]
    fn bucketed_hier_matches_monolithic_hier_bitwise() {
        // Same property through the hierarchical schedule: per-bucket
        // ring → tree → ring must equal the monolithic hierarchical
        // all-reduce bit for bit (fp32 and fp16).
        for codec in [Codec::Fp32, Codec::Fp16] {
            for (n, g) in [(4usize, 2usize), (8, 2), (8, 4), (9, 3)] {
                let layout = GroupLayout::contiguous(n, g).unwrap();
                let inputs = random_inputs(
                    n, 65, n as u64 * 733 + g as u64);
                let mono =
                    run_hier(n, &layout, &inputs, codec, 2);
                let bucketed = run_bucketed(n, &inputs, codec, 2,
                                            WINDOWS_65, Some(&layout),
                                            1);
                for (r, (got, want)) in
                    bucketed.iter().zip(mono.iter()).enumerate()
                {
                    assert!(
                        got.iter().zip(want.iter()).all(
                            |(a, b)| a.to_bits() == b.to_bits()),
                        "rank {r}: bucketed hier != monolithic \
                         ({codec:?}, n={n}, g={g})"
                    );
                }
            }
        }
    }

    #[test]
    fn bucketed_topk_identical_across_ranks_and_tail_exact() {
        // Top-k selects per packed slice, so bucket boundaries change
        // WHICH elements travel — bucketed top-k cannot equal the
        // monolith. The training-critical guarantees that must still
        // hold: every rank finishes bitwise identical, and the
        // protected tail (loss + stop flag) survives undropped.
        let n = 4;
        let len = 65;
        let mut inputs = random_inputs(n, len, 29);
        for (r, input) in inputs.iter_mut().enumerate() {
            for v in input.iter_mut() {
                *v *= 100.0;
            }
            input[len - 2] = 0.5 + r as f32;
            input[len - 1] = if r == 1 { 1.0 } else { 0.0 };
        }
        for layout in [None,
                       Some(GroupLayout::contiguous(n, 2).unwrap())] {
            let results = run_bucketed(n, &inputs,
                                       Codec::TopK { k: 0.1 }, 2,
                                       WINDOWS_65, layout.as_ref(), 3);
            let reference = &results[0];
            for (r, got) in results.iter().enumerate() {
                assert!(
                    got.iter().zip(reference.iter()).all(
                        |(a, b)| a.to_bits() == b.to_bits()),
                    "rank {r} diverged (layout={layout:?})"
                );
            }
            assert!(reference[len - 1] >= 1.0,
                    "stop flag must survive top-k bucketing");
        }
    }

    #[test]
    fn bucketed_with_more_buckets_than_elements() {
        // Degenerate shapes: windows narrower than the chunk grid (so
        // most window∩chunk intersections are empty) must still
        // complete in lockstep and produce the monolithic result.
        let n = 4;
        let len = 3;
        let windows = [(0usize, 1usize), (1, 1), (1, 2), (2, 3)];
        let inputs = random_inputs(n, len, 83);
        let reference = ring_order_reference(&inputs, ReduceOp::Sum);
        let results = run_bucketed(n, &inputs, Codec::Fp32, 0,
                                   &windows, None, 1);
        for got in &results {
            assert_eq!(got, &reference);
        }
    }

    #[test]
    fn bucketed_single_rank_is_identity() {
        let world = inproc_world(1);
        let mut col = Collective::new(&world[0]);
        let mut data = vec![4.0f32, -1.0, 2.5];
        col.bucket_begin(0, &data, 0, 2, 3).unwrap();
        col.bucket_begin(1, &data, 2, 3, 3).unwrap();
        assert_eq!(col.pending_buckets(), 2);
        col.bucket_finish_sum(&mut data).unwrap();
        assert_eq!(col.pending_buckets(), 0);
        assert_eq!(data, vec![4.0, -1.0, 2.5]);
    }

    // --- elastic worlds ---------------------------------------------

    /// A replanned world: 4 Comm ranks, survivors {0, 2, 3}. The
    /// member ring all-reduces among itself exactly like a fresh
    /// 3-rank world; rank 1 never participates.
    #[test]
    fn subset_ring_allreduce_over_survivors() {
        let inputs: Vec<Vec<f32>> = vec![
            vec![1.0, 2.0, 3.0, 4.0, 5.0],
            vec![10.0, 20.0, 30.0, 40.0, 50.0],
            vec![100.0, 200.0, 300.0, 400.0, 500.0],
        ];
        let reference = ring_order_reference(&inputs, ReduceOp::Sum);
        let members = vec![0usize, 2, 3];
        let mut world: Vec<Option<Comm>> =
            inproc_world(4).into_iter().map(Some).collect();
        let survivors: Vec<Comm> = members
            .iter()
            .map(|&r| world[r].take().unwrap())
            .collect();
        let results: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = survivors
                .into_iter()
                .zip(inputs.iter())
                .map(|(comm, input)| {
                    let members = members.clone();
                    let mut buf = input.clone();
                    s.spawn(move || {
                        let mut col = Collective::new(&comm);
                        col.adopt_world(1, Some(members));
                        assert_eq!(col.n_ranks(), 3);
                        col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
                        // broadcast from a mid-list member too
                        let mut extra = if comm.rank() == 2 {
                            vec![7.0f32, 8.0]
                        } else {
                            vec![0.0f32; 2]
                        };
                        col.broadcast(2, &mut extra).unwrap();
                        assert_eq!(extra, vec![7.0, 8.0]);
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for got in &results {
            assert_eq!(got, &reference);
        }
    }

    /// A straggler chunk stamped with a replaced world's generation
    /// must be dropped by the receiver, not summed into the round.
    #[test]
    fn stale_generation_chunks_are_dropped() {
        let world = inproc_world(2);
        let results: Vec<Vec<f32>> = std::thread::scope(|s| {
            let h0 = s.spawn(|| {
                let comm = &world[0];
                // gen-0 straggler racing into the gen-1 world
                comm.send(1, Tag::RingChunk,
                          Payload::floats(7, vec![99.0]))
                    .unwrap();
                let mut col = Collective::new(comm);
                col.adopt_world(1, None);
                let mut buf = vec![1.0f32, 2.0];
                col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
                buf
            });
            let h1 = s.spawn(|| {
                let mut col = Collective::new(&world[1]);
                col.adopt_world(1, None);
                let mut buf = vec![10.0f32, 20.0];
                col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
                buf
            });
            vec![h0.join().unwrap(), h1.join().unwrap()]
        });
        for got in &results {
            assert_eq!(got, &vec![11.0, 22.0]);
        }
    }

    /// In elastic mode a membership-control envelope aborts the
    /// in-flight collective with `Interrupted` and is preserved in the
    /// stash for the agreement protocol.
    #[test]
    fn elastic_control_interrupts_a_collective() {
        let world = inproc_world(2);
        std::thread::scope(|s| {
            let h = s.spawn(|| {
                let mut col = Collective::new(&world[1]);
                col.set_elastic(true);
                col.set_recv_timeout(Duration::from_secs(10));
                let mut buf = vec![0.0f32; 4];
                let err = col
                    .allreduce(&mut buf, ReduceOp::Sum)
                    .unwrap_err();
                assert!(matches!(err, CommError::Interrupted(_)),
                        "{err:?}");
                assert!(col.stash_mut().iter()
                    .any(|e| e.tag == Tag::ElasticProbe));
            });
            world[0]
                .send(1, Tag::ElasticProbe, Payload::floats(0, vec![]))
                .unwrap();
            h.join().unwrap();
        });
    }

    /// Without elastic mode, control traffic is stashed silently and
    /// the collective completes — PS/EASGD worlds and tests that never
    /// opt in see no behavior change.
    #[test]
    fn elastic_control_is_stashed_when_not_elastic() {
        let world = inproc_world(2);
        let results: Vec<Vec<f32>> = std::thread::scope(|s| {
            let h0 = s.spawn(|| {
                let comm = &world[0];
                comm.send(1, Tag::ElasticJoin, Payload::Empty).unwrap();
                let mut col = Collective::new(comm);
                let mut buf = vec![1.0f32, -1.0];
                col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
                buf
            });
            let h1 = s.spawn(|| {
                let mut col = Collective::new(&world[1]);
                let mut buf = vec![2.0f32, 5.0];
                col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
                assert_eq!(col.pending_joiners(), vec![0]);
                buf
            });
            vec![h0.join().unwrap(), h1.join().unwrap()]
        });
        for got in &results {
            assert_eq!(got, &vec![3.0, 4.0]);
        }
    }
}
