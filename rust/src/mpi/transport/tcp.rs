//! TCP transport: a full socket mesh, the cluster analogue.
//!
//! Frame format on each stream: `[u32 src][u64 body_len][body]` where the
//! body is `message::encode(tag, payload)`. A background reader thread per
//! incoming connection decodes frames into the rank's mpsc queue, giving
//! the exact same `Comm` semantics as the in-process transport.
//!
//! Mesh bring-up: every rank listens on `base_port + rank` and dials every
//! higher rank once (lower rank dials, higher accepts), so each unordered
//! pair shares one duplex stream.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::mpi::comm::{Comm, CommError, Sender};
use crate::mpi::message::{self, Envelope, Payload, Rank, Tag};

/// Writer half of the mesh: rank -> shared stream. The map sits behind
/// a `RefCell` so a dead peer's socket can be purged without `&mut`
/// (the owning `Comm` is `!Sync`, so the single-threaded borrow is
/// safe): before this, a failed send left the half-open connection in
/// the peer map forever, and every later send to the departed rank
/// re-attempted a write into a dead socket instead of failing fast.
pub struct TcpSenders {
    streams: std::cell::RefCell<BTreeMap<Rank, Arc<Mutex<TcpStream>>>>,
    /// Reusable wire-frame buffers (the send-side mirror of the
    /// runtime's `Arena`): a steady-state round encodes header + body
    /// into a warm `Vec<u8>` and reallocates only when a frame outgrows
    /// every previous one. Before this pool, every send allocated the
    /// encoded body AND a second frame Vec, then memcpy'd one into the
    /// other.
    frame_bufs: std::cell::RefCell<Vec<Vec<u8>>>,
}

impl TcpSenders {
    pub(crate) fn send(&self, src: Rank, to: Rank, tag: Tag,
                       payload: &Payload) -> Result<(), CommError> {
        // Clone the Arc out of the borrow before locking: purging on
        // error re-borrows the map, and a reader must never observe a
        // held RefCell borrow across the blocking write.
        let stream = self
            .streams
            .borrow()
            .get(&to)
            .cloned()
            .ok_or(CommError::SendFailed(to))?;
        let body_len = payload.nbytes();
        let mut frame = self
            .frame_bufs
            .borrow_mut()
            .pop()
            .unwrap_or_default();
        frame.clear();
        frame.reserve(12 + body_len);
        frame.extend_from_slice(&(src as u32).to_le_bytes());
        frame.extend_from_slice(&(body_len as u64).to_le_bytes());
        message::encode_append(&mut frame, tag, payload);
        debug_assert_eq!(frame.len(), 12 + body_len);
        let mut guard = stream.lock().expect("tcp stream poisoned");
        let result = guard.write_all(&frame);
        self.frame_bufs.borrow_mut().push(frame);
        if result.is_err() {
            // the peer is gone: shut the socket down and drop it from
            // the map so the connection does not linger half-open
            let _ = guard.shutdown(std::net::Shutdown::Both);
            drop(guard);
            self.streams.borrow_mut().remove(&to);
            return Err(CommError::SendFailed(to));
        }
        Ok(())
    }

    /// Proactively tear down the connection to a departed peer.
    pub(crate) fn close_peer(&self, peer: Rank) {
        if let Some(stream) = self.streams.borrow_mut().remove(&peer) {
            let guard = stream.lock().expect("tcp stream poisoned");
            let _ = guard.shutdown(std::net::Shutdown::Both);
        }
    }

    pub(crate) fn has_peer(&self, peer: Rank) -> bool {
        self.streams.borrow().contains_key(&peer)
    }
}

fn spawn_reader(stream: TcpStream, queue: mpsc::Sender<Envelope>) {
    std::thread::spawn(move || {
        let mut stream = stream;
        let mut header = [0u8; 12];
        loop {
            if stream.read_exact(&mut header).is_err() {
                return; // peer closed
            }
            let src = u32::from_le_bytes(header[0..4].try_into().unwrap())
                as Rank;
            let len = u64::from_le_bytes(header[4..12].try_into().unwrap())
                as usize;
            let mut body = vec![0u8; len];
            if stream.read_exact(&mut body).is_err() {
                return;
            }
            match message::decode(&body) {
                Ok((tag, payload)) => {
                    if queue.send(Envelope { src, tag, payload }).is_err() {
                        return; // local endpoint dropped
                    }
                }
                Err(e) => {
                    log::error!("tcp reader: bad frame from {src}: {e}");
                    return;
                }
            }
        }
    });
}

/// Bring up rank `rank` of an `n`-rank mesh on localhost.
///
/// All ranks must call this concurrently (threads or processes).
pub fn endpoint(rank: Rank, n: usize, base_port: u16)
    -> Result<Comm, CommError> {
    let listener = TcpListener::bind(("127.0.0.1", base_port + rank as u16))?;
    let (queue_tx, queue_rx) = mpsc::channel::<Envelope>();
    let mut streams: BTreeMap<Rank, Arc<Mutex<TcpStream>>> = BTreeMap::new();

    // Lower ranks dial higher ranks; a rank accepts `rank` connections
    // (from every lower rank) and dials `n - rank - 1` (to every higher).
    let accept_count = rank;
    let accepter = std::thread::spawn(move || -> std::io::Result<
        Vec<TcpStream>> {
        let mut accepted = Vec::new();
        for _ in 0..accept_count {
            let (stream, _) = listener.accept()?;
            stream.set_nodelay(true)?;
            accepted.push(stream);
        }
        Ok(accepted)
    });

    for peer in (rank + 1)..n {
        let addr = ("127.0.0.1", base_port + peer as u16);
        let deadline = Instant::now() + Duration::from_secs(10);
        let stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) if Instant::now() < deadline => {
                    // peer's listener may not be up yet
                    let _ = e;
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(CommError::Io(e)),
            }
        };
        stream.set_nodelay(true)?;
        // identify ourselves so the acceptor can map stream -> rank
        let mut s = stream.try_clone()?;
        s.write_all(&(rank as u32).to_le_bytes())?;
        spawn_reader(stream.try_clone()?, queue_tx.clone());
        streams.insert(peer, Arc::new(Mutex::new(stream)));
    }

    for stream in accepter.join().expect("accepter panicked")? {
        let mut id = [0u8; 4];
        let mut s = stream.try_clone()?;
        s.read_exact(&mut id)?;
        let peer = u32::from_le_bytes(id) as Rank;
        spawn_reader(stream.try_clone()?, queue_tx.clone());
        streams.insert(peer, Arc::new(Mutex::new(stream)));
    }

    Ok(Comm::new(
        rank,
        n,
        Sender::Tcp(TcpSenders {
            streams: std::cell::RefCell::new(streams),
            frame_bufs: std::cell::RefCell::new(Vec::new()),
        }),
        queue_rx,
    ))
}

/// Convenience: bring up all `n` endpoints on threads and return them
/// (used by tests/benches; real cluster deployments call `endpoint` from
/// separate processes).
pub fn world(n: usize, base_port: u16) -> Result<Vec<Comm>, CommError> {
    let handles: Vec<_> = (0..n)
        .map(|rank| {
            std::thread::spawn(move || endpoint(rank, n, base_port))
        })
        .collect();
    let mut comms = Vec::with_capacity(n);
    for h in handles {
        comms.push(h.join().expect("endpoint thread panicked")?);
    }
    comms.sort_by_key(|c| c.rank());
    Ok(comms)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Port allocation: keep test meshes on distinct ranges.
    const PORT_A: u16 = 46100;
    const PORT_B: u16 = 46140;
    const PORT_C: u16 = 46180;

    #[test]
    fn mesh_roundtrip_three_ranks() {
        let mut w = world(3, PORT_A).unwrap();
        let c2 = w.pop().unwrap();
        let c1 = w.pop().unwrap();
        let c0 = w.pop().unwrap();
        c0.send(2, Tag::Weights, Payload::floats(5, vec![1.5; 64]))
            .unwrap();
        c1.send(2, Tag::Gradients, Payload::floats(6, vec![2.5; 32]))
            .unwrap();
        let mut srcs = Vec::new();
        for _ in 0..2 {
            let env = c2.recv().unwrap();
            srcs.push((env.src, env.tag));
        }
        srcs.sort();
        assert_eq!(srcs, vec![(0, Tag::Weights), (1, Tag::Gradients)]);
    }

    #[test]
    fn large_payload_survives_framing() {
        let mut w = world(2, PORT_B).unwrap();
        let c1 = w.pop().unwrap();
        let c0 = w.pop().unwrap();
        let data: Vec<f32> = (0..200_000).map(|i| (i % 97) as f32).collect();
        c0.send(1, Tag::Weights, Payload::floats(1, data.clone())).unwrap();
        match c1.recv().unwrap().payload {
            Payload::Floats { step, data: got } => {
                assert_eq!(step, 1);
                assert_eq!(*got, data);
            }
            p => panic!("unexpected {p:?}"),
        }
    }

    #[test]
    fn duplex_same_stream() {
        let mut w = world(2, PORT_C).unwrap();
        let c1 = w.pop().unwrap();
        let c0 = w.pop().unwrap();
        c0.send(1, Tag::Ping, Payload::Empty).unwrap();
        let e = c1.recv().unwrap();
        assert_eq!(e.src, 0);
        c1.send(0, Tag::Ping, Payload::Empty).unwrap();
        let e = c0.recv().unwrap();
        assert_eq!(e.src, 1);
    }
}
