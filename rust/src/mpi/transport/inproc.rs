//! In-process transport: one OS thread per rank, mpsc channels as links.
//!
//! This is the shared-memory case of the paper's evaluation (the 8-GPU
//! Supermicro server, where "communication between processes is
//! accomplished via shared memory"). A `World::inproc(n)` hands back `n`
//! [`Comm`] endpoints to move into rank threads.

use std::sync::mpsc;

use crate::mpi::comm::{Comm, Sender};
use crate::mpi::message::Envelope;

/// Build an `n`-rank world; element `i` is rank `i`'s endpoint.
pub fn world(n: usize) -> Vec<Comm> {
    assert!(n >= 1, "world needs at least one rank");
    let mut txs = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = mpsc::channel::<Envelope>();
        txs.push(tx);
        rxs.push(rx);
    }
    rxs.into_iter()
        .enumerate()
        .map(|(rank, rx)| {
            // rank i gets senders to every peer except itself
            let peers: Vec<Option<mpsc::Sender<Envelope>>> = txs
                .iter()
                .enumerate()
                .map(|(j, tx)| if j == rank { None } else {
                    Some(tx.clone())
                })
                .collect();
            Comm::new(rank, n,
                      Sender::Inproc(std::cell::RefCell::new(peers)), rx)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::message::{Payload, Tag};
    use std::time::Duration;

    #[test]
    fn two_rank_roundtrip() {
        let mut w = world(2);
        let c1 = w.pop().unwrap();
        let c0 = w.pop().unwrap();
        c0.send(1, Tag::Ping, Payload::floats(7, vec![1.0, 2.0])).unwrap();
        let env = c1.recv().unwrap();
        assert_eq!(env.src, 0);
        assert_eq!(env.tag, Tag::Ping);
        assert_eq!(env.payload, Payload::floats(7, vec![1.0, 2.0]));
    }

    #[test]
    fn per_pair_ordering_preserved() {
        let mut w = world(2);
        let c1 = w.pop().unwrap();
        let c0 = w.pop().unwrap();
        for i in 0..100u64 {
            c0.send(1, Tag::Gradients, Payload::floats(i, vec![]))
                .unwrap();
        }
        for i in 0..100u64 {
            match c1.recv().unwrap().payload {
                Payload::Floats { step, .. } => assert_eq!(step, i),
                p => panic!("unexpected {p:?}"),
            }
        }
    }

    #[test]
    fn any_source_recv_across_threads() {
        let mut w = world(4);
        let master = w.remove(0);
        let handles: Vec<_> = w
            .into_iter()
            .map(|c| {
                std::thread::spawn(move || {
                    c.send(0, Tag::Ready, Payload::Empty).unwrap();
                })
            })
            .collect();
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..3 {
            seen.insert(master.recv().unwrap().src);
        }
        assert_eq!(seen, [1, 2, 3].into_iter().collect());
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn try_recv_empty_then_delivered() {
        let mut w = world(2);
        let c1 = w.pop().unwrap();
        let c0 = w.pop().unwrap();
        assert!(c1.try_recv().unwrap().is_none());
        c0.send(1, Tag::Exit, Payload::Empty).unwrap();
        // channel delivery is immediate for inproc
        assert!(c1.try_recv().unwrap().is_some());
    }

    #[test]
    fn recv_timeout_times_out() {
        let mut w = world(2);
        let c1 = w.pop().unwrap();
        let _c0 = w.pop().unwrap();
        let err = c1.recv_timeout(Duration::from_millis(20));
        assert!(matches!(err,
            Err(crate::mpi::comm::CommError::Timeout(_))));
    }

    #[test]
    fn invalid_rank_rejected() {
        let mut w = world(2);
        let _c1 = w.pop().unwrap();
        let c0 = w.pop().unwrap();
        assert!(c0.send(5, Tag::Ping, Payload::Empty).is_err());
    }

    #[test]
    fn send_to_self_errors_instead_of_panicking() {
        let mut w = world(2);
        let _c1 = w.pop().unwrap();
        let c0 = w.pop().unwrap();
        match c0.send(0, Tag::Ping, Payload::Empty) {
            Err(crate::mpi::comm::CommError::InvalidRank { rank, size }) => {
                assert_eq!(rank, 0);
                assert_eq!(size, 2);
            }
            other => panic!("expected InvalidRank, got {other:?}"),
        }
        // failed self-sends must not count as traffic
        assert_eq!(c0.bytes_sent(), 0);
    }

    #[test]
    fn recv_tag_preserves_fifo_within_and_across_tags() {
        let mut w = world(2);
        let c1 = w.pop().unwrap();
        let c0 = w.pop().unwrap();
        c0.send(1, Tag::Gradients, Payload::floats(1, vec![])).unwrap();
        c0.send(1, Tag::Weights, Payload::floats(2, vec![])).unwrap();
        c0.send(1, Tag::Gradients, Payload::floats(3, vec![])).unwrap();
        c0.send(1, Tag::Ping, Payload::Empty).unwrap();

        let step_of = |env: crate::mpi::Envelope| match env.payload {
            Payload::Floats { step, .. } => step,
            p => panic!("unexpected {p:?}"),
        };
        let mut stash = Vec::new();
        // pull the last tag first: the earlier three detour via the stash
        let env = c1.recv_tag(Tag::Ping, &mut stash).unwrap();
        assert_eq!(env.tag, Tag::Ping);
        assert_eq!(stash.len(), 3);
        // same-tag messages must come back in send order
        assert_eq!(step_of(c1.recv_tag(Tag::Gradients, &mut stash)
                       .unwrap()), 1);
        assert_eq!(step_of(c1.recv_tag(Tag::Gradients, &mut stash)
                       .unwrap()), 3);
        assert_eq!(step_of(c1.recv_tag(Tag::Weights, &mut stash)
                       .unwrap()), 2);
        assert!(stash.is_empty());
    }

    #[test]
    fn byte_counters_track_payload() {
        let mut w = world(2);
        let c1 = w.pop().unwrap();
        let c0 = w.pop().unwrap();
        let p = Payload::floats(0, vec![0.0; 100]);
        let n = p.nbytes() as u64;
        c0.send(1, Tag::Weights, p).unwrap();
        c1.recv().unwrap();
        assert_eq!(c0.bytes_sent(), n);
        assert_eq!(c1.bytes_recv(), n);
    }
}
