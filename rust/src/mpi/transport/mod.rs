//! Pluggable transports for the MPI-style substrate.

pub mod inproc;
pub mod tcp;
