//! Host-side tensors and flat parameter sets.
//!
//! The coordinator keeps model state as a [`ParamSet`]: one contiguous
//! `Vec<f32>` with a named-view table. A single flat buffer makes the
//! Downpour hot path cheap — gradients travel as one message, the
//! optimizer update is one fused loop, and PJRT literals are sliced views.

use std::fmt;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} incompatible with {} elements",
            data.len()
        );
        Self { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

impl fmt::Display for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)
    }
}

/// Name + shape + offset of one parameter inside the flat buffer.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamView {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub len: usize,
}

/// Named set of parameters in one contiguous buffer.
///
/// Iteration/views follow the order the views were declared in — the
/// AOT manifest's sorted-name order, which is also the positional order
/// the HLO artifacts expect.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSet {
    views: Vec<ParamView>,
    data: Vec<f32>,
}

impl ParamSet {
    /// Build a zero-initialized set from (name, shape) pairs.
    pub fn zeros(specs: &[(String, Vec<usize>)]) -> Self {
        let mut views = Vec::with_capacity(specs.len());
        let mut offset = 0usize;
        for (name, shape) in specs {
            let len = shape.iter().product();
            views.push(ParamView {
                name: name.clone(),
                shape: shape.clone(),
                offset,
                len,
            });
            offset += len;
        }
        Self { views, data: vec![0.0; offset] }
    }

    /// Glorot-uniform init for >=2-D params, zeros for 1-D (biases) — the
    /// same scheme `model.py` uses, so Rust- and Python-initialized models
    /// start from the same distribution family.
    pub fn glorot_init(specs: &[(String, Vec<usize>)],
                       rng: &mut crate::util::rng::Rng) -> Self {
        let mut set = Self::zeros(specs);
        for vi in 0..set.views.len() {
            let view = set.views[vi].clone();
            if view.shape.len() >= 2 {
                let fan_in = view.shape[0] as f32;
                let fan_out = *view.shape.last().unwrap() as f32;
                let lim = (6.0 / (fan_in + fan_out)).sqrt();
                for x in set.view_mut(&view.name).unwrap() {
                    *x = rng.uniform_f32(-lim, lim);
                }
            }
        }
        set
    }

    pub fn num_params(&self) -> usize {
        self.data.len()
    }

    pub fn num_tensors(&self) -> usize {
        self.views.len()
    }

    pub fn views(&self) -> &[ParamView] {
        &self.views
    }

    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    pub fn flat_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Replace the whole buffer (weights received from the master).
    pub fn set_flat(&mut self, values: &[f32]) {
        assert_eq!(values.len(), self.data.len(), "flat size mismatch");
        self.data.copy_from_slice(values);
    }

    pub fn view(&self, name: &str) -> Option<&[f32]> {
        self.views
            .iter()
            .find(|v| v.name == name)
            .map(|v| &self.data[v.offset..v.offset + v.len])
    }

    pub fn view_mut(&mut self, name: &str) -> Option<&mut [f32]> {
        let v = self.views.iter().find(|v| v.name == name)?.clone();
        Some(&mut self.data[v.offset..v.offset + v.len])
    }

    /// Slice for the i-th parameter in declaration order.
    pub fn slice(&self, i: usize) -> &[f32] {
        let v = &self.views[i];
        &self.data[v.offset..v.offset + v.len]
    }

    /// `self += alpha * other` over the flat buffer.
    pub fn axpy(&mut self, alpha: f32, other: &[f32]) {
        assert_eq!(other.len(), self.data.len());
        for (w, g) in self.data.iter_mut().zip(other) {
            *w += alpha * g;
        }
    }

    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Stable per-layer slices of the flat buffer: consecutive views
    /// whose names share a layer prefix (the part before the last `_`,
    /// e.g. `fc0_b`/`fc0_w` -> `fc0`) are grouped into one contiguous
    /// range. Because views are declared in sorted-name order and a
    /// layer's params sort together, each layer is one contiguous slice
    /// — which is what lets all-reduce buckets map 1:1 onto layers
    /// without changing the flat layout or the checkpoint format.
    pub fn layer_ranges(&self) -> Vec<(String, std::ops::Range<usize>)> {
        let prefix = |name: &str| {
            match name.rfind('_') {
                Some(i) => name[..i].to_string(),
                None => name.to_string(),
            }
        };
        let mut out: Vec<(String, std::ops::Range<usize>)> = Vec::new();
        for v in &self.views {
            let p = prefix(&v.name);
            match out.last_mut() {
                Some((name, range)) if *name == p => {
                    debug_assert_eq!(range.end, v.offset,
                                     "layer views must be contiguous");
                    range.end = v.offset + v.len;
                }
                _ => out.push((p, v.offset..v.offset + v.len)),
            }
        }
        out
    }

    /// Checkpoint serialization: name/shape table + raw f32 payload.
    ///
    /// The write is atomic with respect to concurrent readers: bytes go
    /// to a `<path>.tmp` sibling first, then `fs::rename` publishes the
    /// file in one step. A hot-reload watcher polling `path` therefore
    /// sees either the complete old file or the complete new one —
    /// never a torn, half-written checkpoint.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp);
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(b"MPLW")?; // magic
        f.write_all(&(1u32).to_le_bytes())?; // version
        f.write_all(&(self.views.len() as u32).to_le_bytes())?;
        for v in &self.views {
            let name = v.name.as_bytes();
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name)?;
            f.write_all(&(v.shape.len() as u32).to_le_bytes())?;
            for d in &v.shape {
                f.write_all(&(*d as u64).to_le_bytes())?;
            }
        }
        // Explicit little-endian bytes: `load` decodes f32::from_le_bytes,
        // so a native-endian raw dump would corrupt checkpoints on
        // big-endian hosts (and the unsafe reinterpret was never needed).
        let mut bytes = Vec::with_capacity(self.data.len() * 4);
        for v in &self.data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        f.write_all(&bytes)?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<Self> {
        use std::io::Read;
        let mut f = std::fs::File::open(path)?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        let bad = |m: String| std::io::Error::new(
            std::io::ErrorKind::InvalidData, m);
        if buf.len() < 12 || &buf[..4] != b"MPLW" {
            return Err(bad("not a ParamSet checkpoint".into()));
        }
        // Every read below is bounds-checked: a truncated file must
        // produce a descriptive io::Error (the hot-reload watcher
        // logs it and keeps serving), never a slice-index panic.
        let mut pos = 4usize;
        fn need(buf: &[u8], pos: usize, n: usize, what: &str)
            -> std::io::Result<()> {
            if buf.len() - pos < n {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!(
                        "truncated checkpoint: {what} needs {n} bytes at \
                         offset {pos}, only {} remain",
                        buf.len() - pos
                    ),
                ));
            }
            Ok(())
        }
        fn rd_u32(buf: &[u8], pos: &mut usize, what: &str)
            -> std::io::Result<u32> {
            need(buf, *pos, 4, what)?;
            let v = u32::from_le_bytes(buf[*pos..*pos + 4].try_into()
                .unwrap());
            *pos += 4;
            Ok(v)
        }
        let version = rd_u32(&buf, &mut pos, "version")?;
        if version != 1 {
            return Err(bad(format!(
                "unsupported checkpoint version {version} (expected 1)"
            )));
        }
        let nviews = rd_u32(&buf, &mut pos, "view count")? as usize;
        let mut specs = Vec::with_capacity(nviews.min(1024));
        for _ in 0..nviews {
            let nlen = rd_u32(&buf, &mut pos, "name length")? as usize;
            need(&buf, pos, nlen, "view name")?;
            let name = String::from_utf8(buf[pos..pos + nlen].to_vec())
                .map_err(|_| bad("bad name".into()))?;
            pos += nlen;
            let ndim = rd_u32(&buf, &mut pos, "dim count")? as usize;
            need(&buf, pos, ndim.saturating_mul(8), "shape dims")?;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                let d = u64::from_le_bytes(buf[pos..pos + 8].try_into()
                    .unwrap());
                pos += 8;
                shape.push(d as usize);
            }
            specs.push((name, shape));
        }
        let mut set = Self::zeros(&specs);
        let want = set.data.len() * 4;
        let got = buf.len() - pos;
        if got != want {
            return Err(bad(format!(
                "payload size mismatch: header declares {} f32s \
                 (expected {want} payload bytes), file has {got}",
                set.data.len()
            )));
        }
        for (i, chunk) in buf[pos..].chunks_exact(4).enumerate() {
            set.data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        Ok(set)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn specs() -> Vec<(String, Vec<usize>)> {
        vec![
            ("lstm_b".into(), vec![80]),
            ("lstm_wh".into(), vec![20, 80]),
            ("lstm_wx".into(), vec![16, 80]),
            ("out_b".into(), vec![3]),
            ("out_w".into(), vec![20, 3]),
        ]
    }

    #[test]
    fn layout_is_contiguous_and_ordered() {
        let ps = ParamSet::zeros(&specs());
        assert_eq!(ps.num_params(), 80 + 1600 + 1280 + 3 + 60);
        let mut expect_offset = 0;
        for v in ps.views() {
            assert_eq!(v.offset, expect_offset);
            expect_offset += v.len;
        }
    }

    #[test]
    fn views_alias_flat_buffer() {
        let mut ps = ParamSet::zeros(&specs());
        ps.view_mut("out_b").unwrap().copy_from_slice(&[1.0, 2.0, 3.0]);
        let off = ps.views().iter().find(|v| v.name == "out_b").unwrap()
            .offset;
        assert_eq!(&ps.flat()[off..off + 3], &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn glorot_bounds_and_bias_zero() {
        let mut rng = Rng::new(0);
        let ps = ParamSet::glorot_init(&specs(), &mut rng);
        let lim = (6.0f32 / (16.0 + 80.0)).sqrt();
        for &x in ps.view("lstm_wx").unwrap() {
            assert!(x.abs() <= lim);
        }
        assert!(ps.view("lstm_b").unwrap().iter().all(|&x| x == 0.0));
        // matrices must actually be non-zero
        assert!(ps.view("lstm_wx").unwrap().iter().any(|&x| x != 0.0));
    }

    #[test]
    fn axpy_updates_everything() {
        let mut ps = ParamSet::zeros(&specs());
        let g = vec![2.0f32; ps.num_params()];
        ps.axpy(-0.5, &g);
        assert!(ps.flat().iter().all(|&x| x == -1.0));
    }

    #[test]
    fn layer_ranges_group_consecutive_prefixes() {
        // lstm layer = views 0..3 (b, wh, wx), out layer = views 3..5
        let ps = ParamSet::zeros(&specs());
        let ranges = ps.layer_ranges();
        assert_eq!(ranges.len(), 2);
        assert_eq!(ranges[0], ("lstm".to_string(), 0..80 + 1600 + 1280));
        assert_eq!(ranges[1], ("out".to_string(), 2960..2960 + 3 + 60));
        // ranges partition the flat buffer
        assert_eq!(ranges[0].1.end, ranges[1].1.start);
        assert_eq!(ranges.last().unwrap().1.end, ps.num_params());
    }

    #[test]
    fn layer_ranges_mlp_shape() {
        let ps = ParamSet::zeros(&[
            ("fc0_b".into(), vec![64]),
            ("fc0_w".into(), vec![480, 64]),
            ("fc1_b".into(), vec![32]),
            ("fc1_w".into(), vec![64, 32]),
            ("fc2_b".into(), vec![3]),
            ("fc2_w".into(), vec![32, 3]),
        ]);
        let ranges = ps.layer_ranges();
        let names: Vec<&str> =
            ranges.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["fc0", "fc1", "fc2"]);
        let mut end = 0;
        for (_, r) in &ranges {
            assert_eq!(r.start, end);
            end = r.end;
        }
        assert_eq!(end, ps.num_params());
    }

    #[test]
    fn layer_ranges_underscore_free_names() {
        let ps = ParamSet::zeros(&[
            ("alpha".into(), vec![4]),
            ("beta".into(), vec![2]),
        ]);
        let ranges = ps.layer_ranges();
        assert_eq!(ranges.len(), 2);
        assert_eq!(ranges[0], ("alpha".to_string(), 0..4));
        assert_eq!(ranges[1], ("beta".to_string(), 4..6));
    }

    #[test]
    fn checkpoint_roundtrip() {
        let mut rng = Rng::new(7);
        let ps = ParamSet::glorot_init(&specs(), &mut rng);
        let path = std::env::temp_dir().join("mpi_learn_ckpt_test.bin");
        ps.save(&path).unwrap();
        let loaded = ParamSet::load(&path).unwrap();
        assert_eq!(ps, loaded);
    }

    #[test]
    fn checkpoint_payload_is_little_endian_bytes() {
        // Byte-level check independent of `load`: the payload tail must
        // be the explicit to_le_bytes encoding of the flat buffer, on
        // every host endianness.
        let mut ps = ParamSet::zeros(&[("w".into(), vec![2])]);
        ps.flat_mut().copy_from_slice(&[1.0, -2.5]);
        let path = std::env::temp_dir().join("mpi_learn_ckpt_le_test.bin");
        ps.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let tail = &bytes[bytes.len() - 8..];
        let mut expect = Vec::new();
        expect.extend_from_slice(&1.0f32.to_le_bytes());
        expect.extend_from_slice(&(-2.5f32).to_le_bytes());
        assert_eq!(tail, &expect[..]);
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join("mpi_learn_ckpt_bad.bin");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(ParamSet::load(&path).is_err());
    }

    #[test]
    fn load_rejects_every_truncation_without_panicking() {
        // Write a valid checkpoint, then sweep every prefix length: each
        // truncated file must come back as a descriptive io::Error (a
        // torn file must never panic the hot-reload watcher).
        let mut rng = Rng::new(11);
        let ps = ParamSet::glorot_init(&specs(), &mut rng);
        let dir = std::env::temp_dir();
        let full = dir.join("mpi_learn_ckpt_trunc_full.bin");
        ps.save(&full).unwrap();
        let bytes = std::fs::read(&full).unwrap();
        let cut = dir.join("mpi_learn_ckpt_trunc_cut.bin");
        for len in 0..bytes.len() {
            std::fs::write(&cut, &bytes[..len]).unwrap();
            let err = ParamSet::load(&cut).expect_err("truncated file");
            assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        }
        // The untruncated file still loads.
        assert_eq!(ParamSet::load(&full).unwrap(), ps);
    }

    #[test]
    fn load_names_expected_vs_actual_bytes_on_short_payload() {
        let ps = ParamSet::zeros(&[("w".into(), vec![4])]);
        let dir = std::env::temp_dir();
        let path = dir.join("mpi_learn_ckpt_short_payload.bin");
        ps.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Drop the last 4 bytes: header is intact, payload is one f32
        // short — the error must name expected (16) vs actual (12).
        std::fs::write(&path, &bytes[..bytes.len() - 4]).unwrap();
        let err = ParamSet::load(&path).expect_err("short payload");
        let msg = err.to_string();
        assert!(msg.contains("16"), "missing expected bytes: {msg}");
        assert!(msg.contains("12"), "missing actual bytes: {msg}");
    }

    #[test]
    fn save_is_atomic_for_concurrent_readers() {
        // A reader polling the path while a writer repeatedly saves must
        // only ever observe a complete old or complete new checkpoint —
        // never a torn file. This is the contract the serving hot-reload
        // watcher depends on (save writes <path>.tmp then renames).
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let dir = std::env::temp_dir().join("mpi_learn_atomic_save");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.mplw");
        let mk = |fill: f32| {
            let mut ps = ParamSet::zeros(&specs());
            ps.flat_mut().fill(fill);
            ps
        };
        mk(0.0).save(&path).unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let writer = {
            let (stop, path) = (stop.clone(), path.clone());
            std::thread::spawn(move || {
                let mut i = 0u32;
                while !stop.load(Ordering::Relaxed) {
                    mk(i as f32).save(&path).unwrap();
                    i += 1;
                }
            })
        };
        let n = ParamSet::zeros(&specs()).num_params();
        for _ in 0..200 {
            let ps = ParamSet::load(&path)
                .expect("reader must never see a torn file");
            assert_eq!(ps.num_params(), n);
            let first = ps.flat()[0];
            assert!(ps.flat().iter().all(|&x| x == first),
                    "mixed old/new bytes observed");
        }
        stop.store(true, Ordering::Relaxed);
        writer.join().unwrap();
    }

    #[test]
    #[should_panic(expected = "flat size mismatch")]
    fn set_flat_size_checked() {
        let mut ps = ParamSet::zeros(&specs());
        ps.set_flat(&[0.0; 3]);
    }
}
