//! Hot checkpoint reload: poll the training output dir, swap in the
//! newest valid `ParamSet` without dropping traffic.
//!
//! The watcher thread scans every `poll` interval for the newest
//! `*.mplw` file (ignoring the `.tmp` siblings `ParamSet::save` stages
//! writes through), fingerprints it (length + crc32), and on change
//! attempts a load. A valid checkpoint of the right parameter count is
//! published with one atomic `Arc` flip — in-flight requests keep the
//! `Arc` they already cloned and finish on the old weights; every
//! request that starts afterwards sees the new ones. An invalid file
//! (torn copy from a non-atomic producer, wrong model, truncation) is
//! logged and skipped: the server keeps serving the last good weights.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::serving::ServeState;
use crate::tensor::ParamSet;

/// Newest checkpoint in `dir`: `*.mplw` files only (the `.tmp` staging
/// siblings are in-progress writes), ordered by modification time with
/// a numeric-friendly name tiebreak — `(len, lexicographic)`, so
/// `checkpoint-10` beats `checkpoint-9` written in the same instant.
pub fn scan_newest(dir: &Path) -> Option<PathBuf> {
    let entries = std::fs::read_dir(dir).ok()?;
    let mut best: Option<(std::time::SystemTime, (usize, String),
                          PathBuf)> = None;
    for entry in entries.flatten() {
        let path = entry.path();
        let name = match path.file_name().and_then(|n| n.to_str()) {
            Some(n) => n.to_string(),
            None => continue,
        };
        if !name.ends_with(".mplw") {
            continue;
        }
        let mtime = match entry.metadata().and_then(|m| m.modified()) {
            Ok(t) => t,
            Err(_) => continue,
        };
        let key = (mtime, (name.len(), name));
        match &best {
            Some((t, n, _)) if (t, n) >= (&key.0, &key.1) => {}
            _ => best = Some((key.0, key.1, path)),
        }
    }
    best.map(|(_, _, p)| p)
}

/// Cheap change detector: a checkpoint is "new" if its (path, length,
/// crc32) differs from the last one we acted on. Length alone misses
/// same-size rewrites; mtime alone has filesystem granularity issues.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Fingerprint {
    pub path: PathBuf,
    pub len: u64,
    pub crc: u32,
}

pub fn fingerprint(path: &Path) -> std::io::Result<Fingerprint> {
    let bytes = std::fs::read(path)?;
    let mut h = crc32fast::Hasher::new();
    h.update(&bytes);
    Ok(Fingerprint {
        path: path.to_path_buf(),
        len: bytes.len() as u64,
        crc: h.finalize(),
    })
}

/// One poll step, factored out of the thread loop for direct testing:
/// returns `Some(version)` if a new checkpoint was published.
pub fn poll_once(dir: &Path, state: &ServeState,
                 last: &mut Option<Fingerprint>) -> Option<u64> {
    let path = scan_newest(dir)?;
    let fp = match fingerprint(&path) {
        Ok(fp) => fp,
        // Racing a producer's rename or delete — try again next poll.
        Err(_) => return None,
    };
    if last.as_ref() == Some(&fp) {
        return None;
    }
    match ParamSet::load(&path) {
        Ok(ps) if ps.num_params() == state.expected_params() => {
            // Remember the fingerprint only once acted on, so a file
            // that changes again mid-poll is re-examined.
            *last = Some(fp);
            let version = state.publish(ps, &path.display().to_string());
            log::info!(
                "serve: reloaded weights v{version} from {}",
                path.display()
            );
            Some(version)
        }
        Ok(ps) => {
            *last = Some(fp);
            state.note_reload_error();
            log::warn!(
                "serve: ignoring {} — has {} params, model expects {} \
                 (wrong model family?); still serving v{}",
                path.display(),
                ps.num_params(),
                state.expected_params(),
                state.version()
            );
            None
        }
        Err(e) => {
            *last = Some(fp);
            state.note_reload_error();
            log::warn!(
                "serve: failed to load {}: {e}; still serving v{}",
                path.display(),
                state.version()
            );
            None
        }
    }
}

/// Handle to the watcher thread; `stop()` joins it.
pub struct Watcher {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Watcher {
    /// Spawn the polling thread. The initial fingerprint covers the
    /// checkpoint the server booted from (if any), so startup does not
    /// immediately re-publish identical weights.
    pub fn start(dir: PathBuf, poll: Duration, state: Arc<ServeState>,
                 initial: Option<Fingerprint>) -> Watcher {
        let stop = Arc::new(AtomicBool::new(false));
        let thread = {
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut last = initial;
                while !stop.load(Ordering::Relaxed) {
                    poll_once(&dir, &state, &mut last);
                    std::thread::sleep(poll);
                }
            })
        };
        Watcher { stop, thread: Some(thread) }
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Watcher {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ParamSet;

    fn specs() -> Vec<(String, Vec<usize>)> {
        vec![("w".into(), vec![4]), ("b".into(), vec![2])]
    }

    fn ps(fill: f32) -> ParamSet {
        let mut p = ParamSet::zeros(&specs());
        p.flat_mut().fill(fill);
        p
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("mpi_learn_reload_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn scan_skips_tmp_and_prefers_numeric_order() {
        let d = tmpdir("scan");
        ps(1.0).save(&d.join("checkpoint-9.mplw")).unwrap();
        ps(2.0).save(&d.join("checkpoint-10.mplw")).unwrap();
        std::fs::write(d.join("checkpoint-99.mplw.tmp"), b"torn")
            .unwrap();
        std::fs::write(d.join("notes.txt"), b"ignored").unwrap();
        // Equal-mtime tiebreak must pick checkpoint-10 over -9; if the
        // filesystem gave -10 a later mtime the outcome is the same.
        let newest = scan_newest(&d).unwrap();
        assert_eq!(newest.file_name().unwrap(), "checkpoint-10.mplw");
    }

    #[test]
    fn scan_empty_dir_is_none() {
        let d = tmpdir("empty");
        assert_eq!(scan_newest(&d), None);
        assert_eq!(scan_newest(&d.join("missing")), None);
    }

    #[test]
    fn poll_publishes_new_checkpoint_and_bumps_version() {
        let d = tmpdir("publish");
        let state = ServeState::new(ps(0.0), "boot");
        let mut last = None;
        // Nothing there yet.
        assert_eq!(poll_once(&d, &state, &mut last), None);
        ps(1.5).save(&d.join("checkpoint-1.mplw")).unwrap();
        assert_eq!(poll_once(&d, &state, &mut last), Some(1));
        assert_eq!(state.version(), 1);
        assert!(state.params().flat().iter().all(|&x| x == 1.5));
        // Unchanged file: no re-publish.
        assert_eq!(poll_once(&d, &state, &mut last), None);
        assert_eq!(state.version(), 1);
        // A newer checkpoint wins.
        ps(2.5).save(&d.join("checkpoint-2.mplw")).unwrap();
        assert_eq!(poll_once(&d, &state, &mut last), Some(2));
        assert!(state.params().flat().iter().all(|&x| x == 2.5));
    }

    #[test]
    fn poll_keeps_serving_through_bad_checkpoints() {
        let d = tmpdir("bad");
        let state = ServeState::new(ps(7.0), "boot");
        let mut last = None;
        // Corrupt file: logged, counted, old weights keep serving.
        std::fs::write(d.join("checkpoint-1.mplw"), b"MPLWgarbage")
            .unwrap();
        assert_eq!(poll_once(&d, &state, &mut last), None);
        assert_eq!(state.version(), 0);
        assert_eq!(state.reload_errors(), 1);
        assert!(state.params().flat().iter().all(|&x| x == 7.0));
        // Wrong parameter count: same containment.
        let wrong = ParamSet::zeros(&[("w".into(), vec![3])]);
        wrong.save(&d.join("checkpoint-2.mplw")).unwrap();
        assert_eq!(poll_once(&d, &state, &mut last), None);
        assert_eq!(state.version(), 0);
        assert_eq!(state.reload_errors(), 2);
        // And a good one still gets through afterwards.
        ps(9.0).save(&d.join("checkpoint-3.mplw")).unwrap();
        assert_eq!(poll_once(&d, &state, &mut last), Some(1));
        assert!(state.params().flat().iter().all(|&x| x == 9.0));
    }

    #[test]
    fn watcher_thread_picks_up_changes() {
        let d = tmpdir("thread");
        let state = Arc::new(ServeState::new(ps(0.0), "boot"));
        let mut w = Watcher::start(d.clone(),
                                   Duration::from_millis(10),
                                   state.clone(), None);
        ps(3.0).save(&d.join("best.mplw")).unwrap();
        let deadline = std::time::Instant::now()
            + Duration::from_secs(10);
        while state.version() == 0 {
            assert!(std::time::Instant::now() < deadline,
                    "watcher never published the new checkpoint");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(state.params().flat().iter().all(|&x| x == 3.0));
        w.stop();
    }
}
