//! Dependency-free HTTP/1.1 front-end for the serving stack.
//!
//! Thread-per-connection with keep-alive, `Content-Length` framed
//! bodies, and three typed routes:
//!
//! * `POST /v1/predict` — JSON instances in, logits out (through the
//!   micro-batcher). 400 malformed, 413 over `--max-batch`, 503 when
//!   the batch's executor failed, 200 otherwise with the weight
//!   version the answer was computed with.
//! * `GET /healthz` — liveness + which weights are serving.
//! * `GET /metrics` — latency/batch-size histograms and counters.
//!
//! No TLS, no chunked encoding, no HTTP/2 — the paper's deployment
//! story is a trusted cluster network behind a real ingress; what
//! matters here is that the stack stays vendored-deps-only.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::serving::batcher::Batcher;
use crate::serving::json::{error_body, parse_predict_request,
                           predict_response, BodyError};
use crate::serving::ServeState;
use crate::util::json::Json;

/// Hard cap on request bodies, before JSON parsing even starts.
const MAX_BODY_BYTES: usize = 16 * 1024 * 1024;

/// Everything a handler thread needs, shared across connections.
pub struct ServeCtx {
    pub state: Arc<ServeState>,
    pub batcher: Arc<Batcher>,
    pub model_key: String,
    pub row_len: usize,
    pub classes: usize,
    pub max_batch: usize,
    pub replicas: usize,
}

/// Listener + accept thread. `shutdown()` stops accepting and joins
/// the accept loop; live handler threads finish their current request
/// and exit on the stop flag.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    requests: Arc<AtomicU64>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `127.0.0.1:port` (0 = ephemeral, for tests) and start
    /// accepting.
    pub fn start(port: u16, ctx: Arc<ServeCtx>)
        -> std::io::Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let requests = Arc::new(AtomicU64::new(0));
        let accept = {
            let stop = stop.clone();
            let requests = requests.clone();
            std::thread::spawn(move || {
                accept_loop(&listener, &ctx, &stop, &requests)
            })
        };
        Ok(Server { addr, stop, requests, accept: Some(accept) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Requests answered since boot (all routes, all statuses).
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: &TcpListener, ctx: &Arc<ServeCtx>,
               stop: &Arc<AtomicBool>, requests: &Arc<AtomicU64>) {
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = conn else { continue };
        let ctx = ctx.clone();
        let stop = stop.clone();
        let requests = requests.clone();
        std::thread::spawn(move || {
            handle_conn(stream, &ctx, &stop, &requests);
        });
    }
}

struct Request {
    method: String,
    path: String,
    body: String,
    keep_alive: bool,
}

enum ReadError {
    Io(std::io::Error),
    TooLarge(usize),
    Malformed(String),
}

impl From<std::io::Error> for ReadError {
    fn from(e: std::io::Error) -> ReadError {
        ReadError::Io(e)
    }
}

/// Parse one request off the wire. `Ok(None)` is a clean EOF between
/// keep-alive requests.
fn read_request(r: &mut impl BufRead)
    -> Result<Option<Request>, ReadError> {
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() {
        return Err(ReadError::Malformed("malformed request line".into()));
    }
    // HTTP/1.1 defaults to keep-alive, 1.0 to close.
    let mut keep_alive = version != "HTTP/1.0";
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        if r.read_line(&mut h)? == 0 {
            return Ok(None); // peer vanished mid-headers
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let Some((name, value)) = h.split_once(':') else { continue };
        let value = value.trim();
        match name.trim().to_ascii_lowercase().as_str() {
            "content-length" => {
                content_length = value.parse().map_err(|_| {
                    ReadError::Malformed("bad content-length".into())
                })?;
            }
            "connection" => match value.to_ascii_lowercase().as_str() {
                "close" => keep_alive = false,
                "keep-alive" => keep_alive = true,
                _ => {}
            },
            _ => {}
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(ReadError::TooLarge(content_length));
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body)?;
    let body = String::from_utf8(body).map_err(|_| {
        ReadError::Malformed("body is not valid UTF-8".into())
    })?;
    Ok(Some(Request { method, path, body, keep_alive }))
}

struct Response {
    status: u16,
    reason: &'static str,
    body: String,
}

fn resp(status: u16, reason: &'static str, body: String) -> Response {
    Response { status, reason, body }
}

fn write_response(w: &mut impl Write, r: &Response, keep_alive: bool)
    -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: {}\r\n\r\n{}",
        r.status, r.reason, r.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
        r.body
    )?;
    w.flush()
}

fn handle_conn(stream: TcpStream, ctx: &ServeCtx, stop: &AtomicBool,
               requests: &AtomicU64) {
    // Idle keep-alive connections die after this, which also bounds
    // how long a handler thread can outlive `Server::shutdown`.
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut stream = stream;
    while !stop.load(Ordering::SeqCst) {
        let req = match read_request(&mut reader) {
            Ok(Some(req)) => req,
            Ok(None) => break,
            Err(ReadError::TooLarge(n)) => {
                let body = error_body(&format!(
                    "request body of {n} bytes exceeds the \
                     {MAX_BODY_BYTES} byte limit"
                ));
                let _ = write_response(
                    &mut stream,
                    &resp(413, "Payload Too Large", body), false);
                break;
            }
            Err(ReadError::Malformed(m)) => {
                let _ = write_response(
                    &mut stream,
                    &resp(400, "Bad Request", error_body(&m)), false);
                break;
            }
            Err(ReadError::Io(_)) => break,
        };
        requests.fetch_add(1, Ordering::Relaxed);
        let keep_alive = req.keep_alive;
        let response = route(ctx, &req);
        if write_response(&mut stream, &response, keep_alive).is_err() {
            break;
        }
        if !keep_alive {
            break;
        }
    }
}

fn route(ctx: &ServeCtx, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/predict") => predict(ctx, &req.body),
        ("GET", "/healthz") => healthz(ctx),
        ("GET", "/metrics") => metrics(ctx),
        (_, "/v1/predict") | (_, "/healthz") | (_, "/metrics") => resp(
            405,
            "Method Not Allowed",
            error_body("/v1/predict takes POST; /healthz and /metrics \
                        take GET"),
        ),
        _ => resp(404, "Not Found",
                  error_body("routes: POST /v1/predict, GET /healthz, \
                              GET /metrics")),
    }
}

fn predict(ctx: &ServeCtx, body: &str) -> Response {
    match parse_predict_request(body, ctx.row_len, ctx.max_batch) {
        Ok(req) => match ctx.batcher.predict(req.rows, req.x) {
            Ok((version, logits)) => resp(
                200, "OK",
                predict_response(&logits, ctx.classes, version)),
            // The batch failed (replica timeout after retry, executor
            // error) — only this request's batch, hence 503 here and
            // healthy answers on the very next flush.
            Err(e) => resp(503, "Service Unavailable", error_body(&e)),
        },
        Err(BodyError::TooLarge { rows, max_rows }) => resp(
            413, "Payload Too Large",
            error_body(&BodyError::TooLarge { rows, max_rows }
                .to_string()),
        ),
        Err(BodyError::Bad(m)) => {
            resp(400, "Bad Request", error_body(&m))
        }
    }
}

fn healthz(ctx: &ServeCtx) -> Response {
    let (version, _) = ctx.state.params_versioned();
    let body = Json::obj(vec![
        ("status", Json::str("ok")),
        ("model", Json::str(ctx.model_key.clone())),
        ("weight_version", Json::Num(version as f64)),
        ("weight_source", Json::str(ctx.state.source())),
        ("replicas", Json::Num(ctx.replicas as f64)),
        ("reload_errors",
         Json::Num(ctx.state.reload_errors() as f64)),
    ])
    .to_string_compact();
    resp(200, "OK", body)
}

fn metrics(ctx: &ServeCtx) -> Response {
    let body = Json::obj(vec![
        ("latency_ns", ctx.batcher.latency().to_json()),
        ("batch_rows", ctx.batcher.batch_rows().to_json()),
        ("weight_version",
         Json::Num(ctx.state.version() as f64)),
        ("reload_errors",
         Json::Num(ctx.state.reload_errors() as f64)),
    ])
    .to_string_compact();
    resp(200, "OK", body)
}

/// Minimal one-shot HTTP client (tests, benches, the e2e suite): one
/// connection, `Connection: close`, returns `(status, body)`.
pub fn client_request(addr: SocketAddr, method: &str, path: &str,
                      body: &str) -> std::io::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\n\
         Connection: close\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    let mut raw = Vec::new();
    let mut reader = BufReader::new(stream);
    reader.read_to_end(&mut raw)?;
    let text = String::from_utf8_lossy(&raw);
    let bad = || std::io::Error::new(std::io::ErrorKind::InvalidData,
                                     "malformed http response");
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(bad)?;
    let body = text
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .ok_or_else(bad)?;
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::batcher::{BatchExec, Batcher, BatcherConfig};
    use crate::tensor::ParamSet;
    use std::io::Cursor;

    #[test]
    fn parses_request_with_body_and_keep_alive() {
        let raw = "POST /v1/predict HTTP/1.1\r\nHost: x\r\n\
                   Content-Length: 4\r\n\r\nabcdGET /healthz HTTP/1.1\
                   \r\nConnection: close\r\n\r\n";
        let mut r = Cursor::new(raw.as_bytes());
        let one = read_request(&mut r).ok().flatten().unwrap();
        assert_eq!(one.method, "POST");
        assert_eq!(one.path, "/v1/predict");
        assert_eq!(one.body, "abcd");
        assert!(one.keep_alive, "HTTP/1.1 defaults to keep-alive");
        let two = read_request(&mut r).ok().flatten().unwrap();
        assert_eq!(two.method, "GET");
        assert!(!two.keep_alive, "Connection: close honored");
        assert!(read_request(&mut r).ok().flatten().is_none(),
                "clean EOF after the last request");
    }

    #[test]
    fn rejects_oversized_and_malformed_requests() {
        let raw = format!(
            "POST /v1/predict HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        match read_request(&mut Cursor::new(raw.as_bytes())) {
            Err(ReadError::TooLarge(n)) => {
                assert_eq!(n, MAX_BODY_BYTES + 1)
            }
            _ => panic!("oversized body must be refused up front"),
        }
        match read_request(&mut Cursor::new(b"garbage\r\n\r\n" as &[u8]))
        {
            Err(ReadError::Malformed(_)) => {}
            _ => panic!("malformed request line must error"),
        }
    }

    /// 2-float rows, 2 "classes": identity executor at version 3.
    struct Echo;

    impl BatchExec for Echo {
        fn predict(&self, _rows: usize, x: &[f32])
            -> Result<(u64, Vec<f32>), String> {
            Ok((3, x.to_vec()))
        }
    }

    fn test_ctx() -> Arc<ServeCtx> {
        let specs = vec![("w".to_string(), vec![2usize])];
        let state = Arc::new(ServeState::new(ParamSet::zeros(&specs),
                                             "boot"));
        let batcher = Arc::new(Batcher::start(
            BatcherConfig {
                max_batch: 4,
                deadline: Duration::from_millis(2),
                row_len: 2,
                classes: 2,
                max_inflight: 1,
            },
            Arc::new(Echo),
        ));
        Arc::new(ServeCtx {
            state,
            batcher,
            model_key: "echo_b4".into(),
            row_len: 2,
            classes: 2,
            max_batch: 4,
            replicas: 0,
        })
    }

    #[test]
    fn server_routes_and_status_codes_end_to_end() {
        let mut server = Server::start(0, test_ctx()).unwrap();
        let addr = server.addr();
        // 200 with echoed predictions + the executor's version.
        let (status, body) = client_request(
            addr, "POST", "/v1/predict",
            r#"{"instances": [[1.5, -2.0]]}"#).unwrap();
        assert_eq!(status, 200, "{body}");
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("weight_version").unwrap().as_i64(), Some(3));
        let preds = j.get("predictions").unwrap().as_arr().unwrap();
        assert_eq!(preds.len(), 1);
        // healthz reports the state's version (0 at boot).
        let (status, body) =
            client_request(addr, "GET", "/healthz", "").unwrap();
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("status").unwrap().as_str(), Some("ok"));
        assert_eq!(j.get("weight_version").unwrap().as_i64(), Some(0));
        // metrics is well-formed JSON with the histograms.
        let (status, body) =
            client_request(addr, "GET", "/metrics", "").unwrap();
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        assert!(j.get("latency_ns").unwrap().get("count").is_some());
        // Error statuses: 400 / 413 / 404 / 405.
        let (status, _) = client_request(
            addr, "POST", "/v1/predict", "not json").unwrap();
        assert_eq!(status, 400);
        let (status, _) = client_request(
            addr, "POST", "/v1/predict",
            r#"{"instances": [[1,2],[1,2],[1,2],[1,2],[1,2]]}"#)
            .unwrap();
        assert_eq!(status, 413, "5 rows > max_batch 4");
        let (status, _) =
            client_request(addr, "GET", "/nope", "").unwrap();
        assert_eq!(status, 404);
        let (status, _) =
            client_request(addr, "GET", "/v1/predict", "").unwrap();
        assert_eq!(status, 405);
        assert!(server.requests() >= 7);
        server.shutdown();
        // Shutdown is idempotent and new connections now fail fast or
        // get dropped; either way the server thread is gone.
        server.shutdown();
    }
}
