//! Rank-sharded inference replicas behind the micro-batcher.
//!
//! With `--replicas N` the serve plan is an (N+1)-rank world on the
//! existing [`crate::mpi::Comm`] layer (inproc or TCP): rank 0 is the
//! HTTP frontend, ranks `1..=N` each run a replica loop around
//! `ModelExecutables::predict_rows`. The frontend's dispatcher thread
//! owns the rank-0 `Comm` and fans flushed batches over idle replicas
//! using the serve tag lane ([`Tag::ServeRequest`]/[`Tag::ServeReply`],
//! pinned above the bucket block like PR 5's all-reduce lanes).
//!
//! Failure policy, per the serving contract: a replica that misses its
//! per-batch deadline (or whose link drops) is marked dead and the
//! batch is retried ONCE on another live replica; if the retry also
//! fails — or no replica remains — only that batch's requests error
//! (HTTP 503). Weight reloads are broadcast on the [`Tag::Weights`]
//! lane; per-link FIFO ordering guarantees a replica finishes every
//! batch accepted before the swap on the old weights.

use std::collections::{HashMap, VecDeque};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::mpi::{Comm, Payload, Rank, Tag};
use crate::runtime::executor::ModelExecutables;
use crate::serving::batcher::BatchExec;
use crate::tensor::ParamSet;

enum PoolMsg {
    Job(Job),
    Weights(u64, Arc<Vec<f32>>),
    Shutdown,
}

struct Job {
    x: Vec<f32>,
    retried: bool,
    reply: mpsc::Sender<Result<(u64, Vec<f32>), String>>,
}

/// Reply `step` packing: low 32 bits batch id, high 32 bits the weight
/// version the replica computed with — so the frontend can report the
/// exact weights behind every response without a second message.
const BATCH_ID_MASK: u64 = 0xFFFF_FFFF;

/// Frontend handle: dispatcher thread + replica worker threads.
/// Implements [`BatchExec`], so the batcher is oblivious to whether it
/// flushes into a local executor or this pool.
pub struct ReplicaPool {
    // `Mutex` rather than bare `Sender` so the pool is `Sync` on every
    // supported toolchain (std's Sender only became `Sync` recently).
    ctrl: Mutex<mpsc::Sender<PoolMsg>>,
    // Behind Mutexes so shutdown works through an `Arc<ReplicaPool>`
    // (the publish hook and the serve handle share one).
    dispatcher: Mutex<Option<std::thread::JoinHandle<()>>>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl ReplicaPool {
    /// Spawn replica loops on `comms[1..]` and the dispatcher on
    /// `comms[0]`. `init` is the flat weight vector every replica
    /// starts from (the frontend's boot checkpoint).
    pub fn start(mut comms: Vec<Comm>, exe: Arc<ModelExecutables>,
                 init: Arc<Vec<f32>>, timeout: Duration) -> ReplicaPool {
        assert!(comms.len() >= 2, "need a frontend and >=1 replica");
        let front = comms.remove(0);
        let ranks: Vec<Rank> = (1..=comms.len()).collect();
        let workers = comms
            .into_iter()
            .map(|comm| {
                let exe = exe.clone();
                let init = init.clone();
                std::thread::spawn(move || run_replica(comm, &exe, &init))
            })
            .collect();
        Self::start_frontend(front, ranks, timeout, workers)
    }

    /// Dispatcher only — tests use this to pair the frontend with
    /// scripted replica threads (swallowers, echoes).
    pub fn start_frontend(front: Comm, ranks: Vec<Rank>,
                          timeout: Duration,
                          workers: Vec<std::thread::JoinHandle<()>>)
        -> ReplicaPool {
        let (tx, rx) = mpsc::channel();
        let dispatcher = std::thread::spawn(move || {
            dispatch_loop(&front, ranks, timeout, &rx)
        });
        ReplicaPool {
            ctrl: Mutex::new(tx),
            dispatcher: Mutex::new(Some(dispatcher)),
            workers: Mutex::new(workers),
        }
    }

    /// Queue a weight swap: every live replica gets the new flat
    /// vector on the Weights lane. FIFO per link means batches already
    /// sent to a replica still run on the weights they were accepted
    /// under — the pool-side half of "reload never drops traffic".
    pub fn broadcast_weights(&self, version: u64, flat: Arc<Vec<f32>>) {
        let _ = self.ctrl.lock().unwrap()
            .send(PoolMsg::Weights(version, flat));
    }

    /// Stop the dispatcher (it drains in-flight batches, then sends
    /// Exit to live replicas) and join every thread.
    pub fn shutdown(&self) {
        let _ = self.ctrl.lock().unwrap().send(PoolMsg::Shutdown);
        if let Some(d) = self.dispatcher.lock().unwrap().take() {
            let _ = d.join();
        }
        for w in self.workers.lock().unwrap().drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ReplicaPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl BatchExec for ReplicaPool {
    fn predict(&self, rows: usize, x: &[f32])
        -> Result<(u64, Vec<f32>), String> {
        if rows == 0 || x.len() % rows != 0 {
            return Err(format!(
                "bad batch shape: {} floats / {rows} rows", x.len()
            ));
        }
        let (tx, rx) = mpsc::channel();
        let job = Job { x: x.to_vec(), retried: false, reply: tx };
        self.ctrl.lock().unwrap()
            .send(PoolMsg::Job(job))
            .map_err(|_| "replica pool stopped".to_string())?;
        rx.recv().unwrap_or_else(|_| Err("replica pool stopped".into()))
    }
}

/// The frontend dispatcher: single owner of the rank-0 `Comm`.
/// Batches arrive as control messages, go out tagged with a monotonic
/// batch id (`Floats.step`), and replies are matched by (rank, id) —
/// a late reply from a replica already declared dead is dropped.
fn dispatch_loop(front: &Comm, ranks: Vec<Rank>, timeout: Duration,
                 ctrl: &mpsc::Receiver<PoolMsg>) {
    let mut alive = ranks;
    let mut queued: VecDeque<Job> = VecDeque::new();
    let mut inflight: HashMap<Rank, (u64, Instant, Job)> = HashMap::new();
    let mut next_id: u64 = 1;
    let mut shutdown = false;
    loop {
        // 1. Control messages: block briefly when fully idle, poll when
        // work is pending.
        let idle = queued.is_empty() && inflight.is_empty();
        let first = if idle {
            match ctrl.recv_timeout(Duration::from_millis(20)) {
                Ok(m) => Some(m),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    Some(PoolMsg::Shutdown)
                }
            }
        } else {
            match ctrl.try_recv() {
                Ok(m) => Some(m),
                Err(mpsc::TryRecvError::Empty) => None,
                Err(mpsc::TryRecvError::Disconnected) => {
                    Some(PoolMsg::Shutdown)
                }
            }
        };
        let mut msgs: Vec<PoolMsg> = first.into_iter().collect();
        while let Ok(m) = ctrl.try_recv() {
            msgs.push(m);
        }
        for m in msgs {
            match m {
                PoolMsg::Job(j) => queued.push_back(j),
                PoolMsg::Weights(version, flat) => {
                    alive.retain(|&r| {
                        let p = Payload::floats_shared(version,
                                                       flat.clone());
                        let ok = front.send(r, Tag::Weights, p).is_ok();
                        if !ok {
                            log::warn!(
                                "serve: replica {r} unreachable on \
                                 weight broadcast; marking dead"
                            );
                        }
                        ok
                    });
                }
                PoolMsg::Shutdown => shutdown = true,
            }
        }
        if shutdown && queued.is_empty() && inflight.is_empty() {
            break;
        }
        let mut progress = false;
        // 2. Assign queued batches to idle live replicas.
        while let Some(job) = queued.pop_front() {
            let slot = alive.iter().copied()
                .find(|r| !inflight.contains_key(r));
            let Some(r) = slot else {
                queued.push_front(job);
                break;
            };
            let id = next_id;
            next_id += 1;
            let p = Payload::floats(id, job.x.clone());
            if front.send(r, Tag::ServeRequest, p).is_ok() {
                inflight.insert(r, (id, Instant::now(), job));
                progress = true;
            } else {
                log::warn!("serve: send to replica {r} failed; \
                            marking dead");
                alive.retain(|&a| a != r);
                fail_or_retry(job, &mut queued, &alive,
                              format!("replica {r} unreachable"));
            }
        }
        if alive.is_empty() {
            for job in queued.drain(..) {
                let _ = job.reply
                    .send(Err("no replicas alive".to_string()));
            }
        }
        // 3. Replies — matched by (source rank, batch id).
        while let Ok(Some(env)) = front.try_recv() {
            if env.tag != Tag::ServeReply {
                continue;
            }
            let src = env.src;
            match env.payload.weights_like() {
                Some((step, data)) => {
                    let id = step & BATCH_ID_MASK;
                    let version = step >> 32;
                    let hit = matches!(
                        inflight.get(&src),
                        Some(&(want, _, _)) if want & BATCH_ID_MASK == id
                    );
                    if hit {
                        let (_, _, job) = inflight.remove(&src).unwrap();
                        let _ = job.reply
                            .send(Ok((version, data.as_ref().clone())));
                        progress = true;
                    }
                    // else: stale reply from a timed-out batch — drop.
                }
                None => {
                    // Empty reply = replica-side predict error. That
                    // is deterministic (bad shape), so no retry.
                    if let Some((_, _, job)) = inflight.remove(&src) {
                        let _ = job.reply.send(Err(format!(
                            "replica {src} failed the batch"
                        )));
                        progress = true;
                    }
                }
            }
        }
        // 4. Timeouts: mark dead, single retry elsewhere.
        let now = Instant::now();
        let expired: Vec<Rank> = inflight
            .iter()
            .filter(|(_, (_, sent, _))| {
                now.duration_since(*sent) >= timeout
            })
            .map(|(&r, _)| r)
            .collect();
        for r in expired {
            let (_, _, job) = inflight.remove(&r).unwrap();
            alive.retain(|&a| a != r);
            log::warn!(
                "serve: replica {r} missed the {timeout:?} deadline; \
                 marking dead"
            );
            fail_or_retry(job, &mut queued, &alive,
                          format!("replica {r} timed out"));
            progress = true;
        }
        if !idle && !progress {
            // Busy-wait guard while batches are in flight.
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    for r in alive {
        let _ = front.send(r, Tag::Exit, Payload::Empty);
    }
}

/// Timeout/link-failure policy: one retry on another live replica,
/// else the batch (and only this batch) errors.
fn fail_or_retry(mut job: Job, queued: &mut VecDeque<Job>,
                 alive: &[Rank], why: String) {
    if job.retried || alive.is_empty() {
        let _ = job.reply.send(Err(why));
    } else {
        job.retried = true;
        queued.push_front(job);
    }
}

/// One replica's serve loop: answer ServeRequest batches with the
/// current weights, swap weights on the Weights lane, leave on Exit
/// (or when the frontend's link drops).
pub fn run_replica(comm: Comm, exe: &ModelExecutables,
                   init: &[f32]) {
    let mut params = ParamSet::zeros(&exe.meta.params);
    params.set_flat(init);
    let mut version: u64 = 0;
    let row_len = exe.meta.seq_len * exe.meta.features;
    loop {
        let env = match comm.recv() {
            Ok(e) => e,
            Err(_) => break,
        };
        match env.tag {
            Tag::ServeRequest => {
                let Some((id, data)) = env.payload.weights_like() else {
                    continue;
                };
                let step = (version << 32) | (id & BATCH_ID_MASK);
                let rows = data.len() / row_len;
                let reply = if data.len() % row_len != 0 || rows == 0 {
                    Payload::Empty
                } else {
                    match exe.predict_rows(&params, &data, rows) {
                        Ok(logits) => Payload::floats(step, logits),
                        Err(e) => {
                            log::error!(
                                "serve: replica {} predict failed: {e}",
                                comm.rank()
                            );
                            Payload::Empty
                        }
                    }
                };
                if comm.send(0, Tag::ServeReply, reply).is_err() {
                    break;
                }
            }
            Tag::Weights => {
                if let Some((v, flat)) = env.payload.weights_like() {
                    if flat.len() == params.num_params() {
                        params.set_flat(&flat);
                        version = v;
                        log::info!(
                            "serve: replica {} now on weights v{v}",
                            comm.rank()
                        );
                    } else {
                        log::error!(
                            "serve: replica {} ignoring weights v{v}: \
                             {} floats, expected {}",
                            comm.rank(), flat.len(), params.num_params()
                        );
                    }
                }
            }
            Tag::Exit => break,
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi;
    use crate::runtime::native::meta_for_key;
    use crate::util::rng::Rng;

    fn exe(key: &str) -> Arc<ModelExecutables> {
        let meta = meta_for_key(key).unwrap();
        Arc::new(ModelExecutables::native(&meta).unwrap())
    }

    fn init_flat(exe: &ModelExecutables, seed: u64) -> Arc<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let ps = exe.init_params(&mut rng);
        Arc::new(ps.flat().to_vec())
    }

    fn params_from(exe: &ModelExecutables, flat: &[f32]) -> ParamSet {
        let mut ps = ParamSet::zeros(&exe.meta.params);
        ps.set_flat(flat);
        ps
    }

    fn input(exe: &ModelExecutables, rows: usize) -> Vec<f32> {
        let row_len = exe.meta.seq_len * exe.meta.features;
        (0..rows * row_len)
            .map(|i| ((i % 89) as f32) * 0.02 - 0.9)
            .collect()
    }

    #[test]
    fn pool_matches_local_predict_over_inproc_world() {
        let exe = exe("mlp_b4");
        let init = init_flat(&exe, 11);
        let world = mpi::inproc_world(3);
        let pool = ReplicaPool::start(world, exe.clone(), init.clone(),
                                      Duration::from_secs(10));
        let reference = params_from(&exe, &init);
        for rows in [1usize, 3, 4] {
            let x = input(&exe, rows);
            let (v, got) = pool.predict(rows, &x).unwrap();
            let want = exe.predict_rows(&reference, &x, rows).unwrap();
            assert_eq!(v, 0, "boot weights are version 0");
            assert_eq!(got, want, "rows={rows}");
        }
    }

    #[test]
    fn concurrent_batches_fan_out_and_all_succeed() {
        let exe = exe("mlp_b4");
        let init = init_flat(&exe, 12);
        let world = mpi::inproc_world(4);
        let pool = Arc::new(ReplicaPool::start(
            world, exe.clone(), init.clone(),
            Duration::from_secs(10)));
        let reference = params_from(&exe, &init);
        let threads: Vec<_> = (0..6)
            .map(|_| {
                let pool = pool.clone();
                let exe = exe.clone();
                let x = input(&exe, 2);
                std::thread::spawn(move || {
                    (x.clone(), pool.predict(2, &x).unwrap().1)
                })
            })
            .collect();
        for t in threads {
            let (x, got) = t.join().unwrap();
            let want = exe.predict_rows(&reference, &x, 2).unwrap();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn weights_broadcast_swaps_replica_params() {
        let exe = exe("mlp_b4");
        let old = init_flat(&exe, 13);
        let new = init_flat(&exe, 14);
        assert_ne!(old.as_ref(), new.as_ref());
        let world = mpi::inproc_world(3);
        let pool = ReplicaPool::start(world, exe.clone(), old,
                                      Duration::from_secs(10));
        pool.broadcast_weights(1, new.clone());
        // Control channel + per-link FIFO: a job submitted after the
        // broadcast runs on the new weights on every replica.
        let reference = params_from(&exe, &new);
        for _ in 0..4 {
            let x = input(&exe, 2);
            let (v, got) = pool.predict(2, &x).unwrap();
            let want = exe.predict_rows(&reference, &x, 2).unwrap();
            assert_eq!(v, 1, "reply must carry the swapped-in version");
            assert_eq!(got, want);
        }
    }

    /// A replica that reads requests and never answers.
    fn spawn_swallower(comm: Comm) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || loop {
            match comm.recv() {
                Ok(env) if env.tag == Tag::Exit => break,
                Ok(_) => {}
                Err(_) => break,
            }
        })
    }

    /// A replica that echoes the request payload straight back.
    fn spawn_echo(comm: Comm) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || loop {
            match comm.recv() {
                Ok(env) if env.tag == Tag::ServeRequest => {
                    let (id, data) =
                        env.payload.weights_like().unwrap();
                    let p = Payload::floats(id, data.as_ref().clone());
                    if comm.send(0, Tag::ServeReply, p).is_err() {
                        break;
                    }
                }
                Ok(env) if env.tag == Tag::Exit => break,
                Ok(_) => {}
                Err(_) => break,
            }
        })
    }

    #[test]
    fn timeout_marks_replica_dead_and_retries_on_healthy_one() {
        let mut world = mpi::inproc_world(3);
        let front = world.remove(0);
        let swallow = spawn_swallower(world.remove(0));
        let echo = spawn_echo(world.remove(0));
        let timeout = Duration::from_millis(60);
        let pool = ReplicaPool::start_frontend(
            front, vec![1, 2], timeout, vec![swallow, echo]);
        // First batch lands on replica 1 (the swallower), times out,
        // and the single retry succeeds on replica 2.
        let t0 = Instant::now();
        let got = pool.predict(2, &[1.0, 2.0]).unwrap().1;
        assert_eq!(got, vec![1.0, 2.0]);
        assert!(t0.elapsed() >= timeout,
                "must have waited out the dead replica first");
        // Replica 1 stays dead; later batches go straight to 2.
        let t1 = Instant::now();
        let got = pool.predict(1, &[3.0]).unwrap().1;
        assert_eq!(got, vec![3.0]);
        assert!(t1.elapsed() < timeout,
                "dead replica must not be retried every batch");
        pool.shutdown();
    }

    #[test]
    fn timeout_with_no_replica_left_fails_only_that_batch_path() {
        let mut world = mpi::inproc_world(2);
        let front = world.remove(0);
        let swallow = spawn_swallower(world.remove(0));
        let timeout = Duration::from_millis(40);
        let pool = ReplicaPool::start_frontend(
            front, vec![1], timeout, vec![swallow]);
        let err = pool.predict(1, &[1.0]).unwrap_err();
        assert!(err.contains("timed out"), "{err}");
        // The pool survives: later calls error cleanly, no hang.
        let err = pool.predict(1, &[2.0]).unwrap_err();
        assert!(err.contains("no replicas alive"), "{err}");
        pool.shutdown();
    }

    #[test]
    fn pool_works_over_tcp_transport() {
        let exe = exe("mlp_b4");
        let init = init_flat(&exe, 15);
        let world = mpi::tcp_world(2, 47310).unwrap();
        let pool = ReplicaPool::start(world, exe.clone(), init.clone(),
                                      Duration::from_secs(10));
        let reference = params_from(&exe, &init);
        let x = input(&exe, 3);
        let got = pool.predict(3, &x).unwrap().1;
        let want = exe.predict_rows(&reference, &x, 3).unwrap();
        assert_eq!(got, want);
    }
}
