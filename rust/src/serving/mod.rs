//! HTTP inference serving for trained checkpoints (PR 6's tentpole).
//!
//! The `serve` subcommand turns a training output directory into a
//! dependency-free HTTP/1.1 prediction service running the native
//! Layer-DAG backend:
//!
//! ```text
//!   POST /v1/predict ── parse ([`json`]) ── [`batcher`] ──┐
//!   POST /v1/predict ── parse ─────────────── (coalesce) ──┤
//!                                                          ▼
//!                                 one predict_rows() pass, either
//!                                 in-process or fanned over rank-
//!                                 sharded replicas ([`replica`])
//!                                                          │
//!   GET /healthz, /metrics ◄── [`ServeState`] ◄── [`reload`] watcher
//! ```
//!
//! Three moving parts, each its own module with its own tests:
//!
//! * [`batcher`] — micro-batches concurrent requests into one forward
//!   pass (flush on `--max-batch` rows or `--batch-deadline-ms`,
//!   whichever first).
//! * [`replica`] — with `--replicas N`, an (N+1)-rank `Comm` world
//!   (inproc or TCP) where rank 0 dispatches batches to replica ranks
//!   with a per-batch timeout and a single retry on peer failure.
//! * [`reload`] — polls the checkpoint dir and hot-swaps the newest
//!   valid `ParamSet` with one atomic `Arc` flip; in-flight batches
//!   finish on the weights they started with, and a torn or wrong
//!   checkpoint is logged and skipped, never served.
//!
//! [`ServeState`] is the hinge: the current weights + version that the
//! HTTP layer reports, the reloader publishes to, and the executors
//! snapshot per batch.

pub mod batcher;
pub mod http;
pub mod json;
pub mod reload;
pub mod replica;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use crate::coordinator::config::ConfigError;
use crate::mpi;
use crate::runtime::executor::ModelExecutables;
use crate::runtime::native::meta_for_key;
use crate::simulator::CostModel;
use crate::tensor::ParamSet;
use crate::util::json::Json;

use batcher::{BatchExec, Batcher, BatcherConfig};
use reload::Watcher;
use replica::ReplicaPool;

/// Weight-publication hook: `(version, flat weights)`. The replica
/// pool registers one so a reload reaches every replica rank.
type PublishHook = Box<dyn Fn(u64, Arc<Vec<f32>>) + Send + Sync>;

/// What the one write lock guards: the weights, their version, and
/// where they came from — always consistent with each other, so an
/// executor's per-batch snapshot can truthfully report which version
/// it computed with.
struct Current {
    version: u64,
    params: Arc<ParamSet>,
    source: String,
}

/// The served weights and their provenance. Readers (`/healthz`, the
/// executors) clone an `Arc<ParamSet>` and are immune to concurrent
/// swaps; the reload watcher is the only writer after boot.
pub struct ServeState {
    current: RwLock<Current>,
    reload_errors: AtomicU64,
    expected_params: usize,
    on_publish: Mutex<Option<PublishHook>>,
}

impl ServeState {
    /// Boot with the initial weights. Version 0 is the boot version;
    /// every successful reload increments it.
    pub fn new(ps: ParamSet, source: &str) -> ServeState {
        ServeState {
            expected_params: ps.num_params(),
            current: RwLock::new(Current {
                version: 0,
                params: Arc::new(ps),
                source: source.to_string(),
            }),
            reload_errors: AtomicU64::new(0),
            on_publish: Mutex::new(None),
        }
    }

    /// Parameter count every published checkpoint must match.
    pub fn expected_params(&self) -> usize {
        self.expected_params
    }

    /// Atomically swap in new weights; returns the new version.
    /// In-flight batches keep the `Arc` they already snapshotted.
    pub fn publish(&self, ps: ParamSet, source: &str) -> u64 {
        assert_eq!(ps.num_params(), self.expected_params,
                   "publish with wrong parameter count");
        let flat = Arc::new(ps.flat().to_vec());
        let version = {
            let mut cur = self.current.write().unwrap();
            cur.version += 1;
            cur.params = Arc::new(ps);
            cur.source = source.to_string();
            cur.version
        };
        if let Some(hook) = self.on_publish.lock().unwrap().as_ref() {
            hook(version, flat);
        }
        version
    }

    pub fn version(&self) -> u64 {
        self.current.read().unwrap().version
    }

    /// Snapshot of the current weights.
    pub fn params(&self) -> Arc<ParamSet> {
        self.current.read().unwrap().params.clone()
    }

    /// Consistent (version, weights) snapshot for an executor.
    pub fn params_versioned(&self) -> (u64, Arc<ParamSet>) {
        let cur = self.current.read().unwrap();
        (cur.version, cur.params.clone())
    }

    /// Where the current weights came from (path or "init").
    pub fn source(&self) -> String {
        self.current.read().unwrap().source.clone()
    }

    pub fn note_reload_error(&self) {
        self.reload_errors.fetch_add(1, Ordering::SeqCst);
    }

    /// Checkpoints seen but rejected (corrupt / wrong model) since boot.
    pub fn reload_errors(&self) -> u64 {
        self.reload_errors.load(Ordering::SeqCst)
    }

    /// Register the weight-publication hook (replica broadcast).
    pub fn set_on_publish(&self, hook: PublishHook) {
        *self.on_publish.lock().unwrap() = Some(hook);
    }
}

/// `serve` subcommand configuration (flags or the `"serve"` block of a
/// JSON config file — see [`ServeConfig::from_json`]).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Model family: `mlp` | `lstm` (must match the checkpoint).
    pub model: String,
    /// Directory the training run writes `*.mplw` checkpoints into.
    pub checkpoint_dir: PathBuf,
    /// TCP port to listen on (0 = ephemeral, for tests).
    pub port: u16,
    /// Rows per forward pass — the compiled batch variant, the flush
    /// threshold, and the per-request row cap (HTTP 413 above it).
    pub max_batch: usize,
    /// Micro-batch flush deadline for partial batches.
    pub batch_deadline_ms: u64,
    /// Inference replica ranks (0 = run the model in-process).
    pub replicas: usize,
    /// Carry replica traffic over a localhost TCP mesh instead of
    /// in-process channels.
    pub tcp: bool,
    /// First port of the replica TCP mesh (with `tcp`).
    pub base_port: u16,
    /// Checkpoint dir poll interval.
    pub poll_ms: u64,
    /// Per-batch replica deadline before mark-dead + retry.
    pub replica_timeout_ms: u64,
    /// Compute threads for the native kernel pool behind every
    /// forward pass (replica ranks share the executor, so this covers
    /// them too). `0` = auto-detect; predictions are bitwise-identical
    /// at any value.
    pub threads: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            model: "lstm".into(),
            checkpoint_dir: PathBuf::from("runs/ckpt"),
            port: 8080,
            max_batch: 32,
            batch_deadline_ms: 5,
            replicas: 0,
            tcp: false,
            base_port: 47800,
            poll_ms: 500,
            replica_timeout_ms: 2_000,
            threads: 0,
        }
    }
}

impl ServeConfig {
    /// The compiled-variant key this config serves. Parameter counts
    /// are batch-independent, so any `--max-batch` serves checkpoints
    /// from any `train --batch`.
    pub fn model_key(&self) -> String {
        format!("{}_b{}", self.model, self.max_batch)
    }

    pub fn from_file(path: &Path) -> Result<ServeConfig, ConfigError> {
        Self::from_json(&crate::coordinator::config::load_json(path)?)
    }

    pub fn from_json_text(text: &str) -> Result<ServeConfig, ConfigError> {
        let j = Json::parse(text)
            .map_err(|e| ConfigError::Parse(e.to_string()))?;
        Self::from_json(&j)
    }

    /// Accepts either a bare object of serve keys or a file with a
    /// top-level `"serve"` block (so one job.json can hold both the
    /// train and serve halves of a deployment).
    pub fn from_json(j: &Json) -> Result<ServeConfig, ConfigError> {
        let invalid = ConfigError::Invalid;
        let j = j.get("serve").unwrap_or(j);
        let mut cfg = ServeConfig::default();
        if let Some(v) = j.get("model") {
            cfg.model = v.as_str()
                .ok_or_else(|| invalid("\"model\" must be a string"
                    .into()))?
                .to_string();
        }
        if let Some(v) = j.get("checkpoint_dir") {
            cfg.checkpoint_dir = PathBuf::from(v.as_str().ok_or_else(
                || invalid("\"checkpoint_dir\" must be a string".into()),
            )?);
        }
        let num = |key: &str, j: &Json| -> Result<Option<usize>,
                                                  ConfigError> {
            match j.get(key) {
                Some(v) => v.as_usize().map(Some).ok_or_else(|| {
                    ConfigError::Invalid(format!(
                        "\"{key}\" must be a non-negative integer"
                    ))
                }),
                None => Ok(None),
            }
        };
        if let Some(v) = num("port", j)? {
            cfg.port = v as u16;
        }
        if let Some(v) = num("max_batch", j)? {
            cfg.max_batch = v;
        }
        if let Some(v) = num("batch_deadline_ms", j)? {
            cfg.batch_deadline_ms = v as u64;
        }
        if let Some(v) = num("replicas", j)? {
            cfg.replicas = v;
        }
        if let Some(v) = j.get("tcp") {
            cfg.tcp = v.as_bool()
                .ok_or_else(|| invalid("\"tcp\" must be a bool".into()))?;
        }
        if let Some(v) = num("base_port", j)? {
            cfg.base_port = v as u16;
        }
        if let Some(v) = num("poll_ms", j)? {
            cfg.poll_ms = v as u64;
        }
        if let Some(v) = num("replica_timeout_ms", j)? {
            cfg.replica_timeout_ms = v as u64;
        }
        if let Some(v) = num("threads", j)? {
            cfg.threads = v;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.max_batch == 0 {
            return Err(ConfigError::Invalid(
                "\"max_batch\" must be >= 1".into()));
        }
        if self.replicas > 256 {
            return Err(ConfigError::Invalid(format!(
                "\"replicas\" ({}) exceeds the supported maximum (256)",
                self.replicas
            )));
        }
        if meta_for_key(&self.model_key()).is_none() {
            return Err(ConfigError::Invalid(format!(
                "unknown model family \"{}\" (mlp | lstm)", self.model
            )));
        }
        Ok(())
    }
}

/// In-process executor: snapshot the current weights, one batched
/// forward pass. The snapshot-per-batch is what makes hot reload safe
/// without locks in the compute path.
struct LocalExec {
    exe: Arc<ModelExecutables>,
    state: Arc<ServeState>,
}

impl BatchExec for LocalExec {
    fn predict(&self, rows: usize, x: &[f32])
        -> Result<(u64, Vec<f32>), String> {
        let (version, params) = self.state.params_versioned();
        self.exe
            .predict_rows(&params, x, rows)
            .map(|logits| (version, logits))
            .map_err(|e| e.to_string())
    }
}

/// A running serve stack; dropping (or `stop()`) shuts every layer
/// down in dependency order. Tests and the e2e suite boot this on an
/// ephemeral port instead of shelling out.
pub struct ServeHandle {
    server: http::Server,
    watcher: Watcher,
    batcher: Arc<Batcher>,
    state: Arc<ServeState>,
    pool: Option<Arc<ReplicaPool>>,
}

impl ServeHandle {
    pub fn addr(&self) -> std::net::SocketAddr {
        self.server.addr()
    }

    pub fn state(&self) -> &Arc<ServeState> {
        &self.state
    }

    pub fn stop(&mut self) {
        // Watcher first (no more publishes), then stop accepting HTTP,
        // then drain the batcher, then retire the replicas.
        self.watcher.stop();
        self.server.shutdown();
        self.batcher.shutdown();
        if let Some(pool) = &self.pool {
            pool.shutdown();
        }
    }
}

impl Drop for ServeHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Boot the full serving stack: initial weights (newest checkpoint in
/// the dir, else Glorot init at version 0), executor (in-process or
/// replica pool), micro-batcher, reload watcher, HTTP server.
pub fn start(cfg: &ServeConfig) -> Result<ServeHandle, String> {
    cfg.validate().map_err(|e| e.to_string())?;
    let meta = meta_for_key(&cfg.model_key())
        .ok_or_else(|| format!("unknown model key {}", cfg.model_key()))?;
    let exe = Arc::new(
        ModelExecutables::native(&meta).map_err(|e| e.to_string())?);
    // Size the compute pool once; replica ranks share this executor,
    // so they inherit the thread count.
    exe.set_threads(cfg.threads);

    // Initial weights: newest checkpoint if the dir has one.
    let mut initial_fp = None;
    let (boot, source) = match reload::scan_newest(&cfg.checkpoint_dir) {
        Some(path) => {
            let ps = ParamSet::load(&path).map_err(|e| {
                format!("loading {}: {e}", path.display())
            })?;
            if ps.num_params() != meta.param_count {
                return Err(format!(
                    "{} has {} params, {} expects {}",
                    path.display(), ps.num_params(), cfg.model_key(),
                    meta.param_count
                ));
            }
            initial_fp = reload::fingerprint(&path).ok();
            let source = path.display().to_string();
            (ps, source)
        }
        None => {
            log::warn!(
                "serve: no checkpoint in {} yet — serving Glorot-init \
                 weights until one appears",
                cfg.checkpoint_dir.display()
            );
            let mut rng = crate::util::rng::Rng::new(2017);
            (exe.init_params(&mut rng), "init".to_string())
        }
    };
    let state = Arc::new(ServeState::new(boot, &source));

    // Executor: local, or a replica pool over the Comm layer.
    let (exec, pool): (Arc<dyn BatchExec>, Option<Arc<ReplicaPool>>) =
        if cfg.replicas == 0 {
            (Arc::new(LocalExec { exe: exe.clone(),
                                  state: state.clone() }),
             None)
        } else {
            let world = if cfg.tcp {
                mpi::tcp_world(cfg.replicas + 1, cfg.base_port)
                    .map_err(|e| format!("replica tcp mesh: {e:?}"))?
            } else {
                mpi::inproc_world(cfg.replicas + 1)
            };
            let init = Arc::new(state.params().flat().to_vec());
            let pool = Arc::new(ReplicaPool::start(
                world, exe.clone(), init,
                Duration::from_millis(cfg.replica_timeout_ms)));
            let hooked = pool.clone();
            state.set_on_publish(Box::new(move |version, flat| {
                hooked.broadcast_weights(version, flat);
            }));
            (pool.clone() as Arc<dyn BatchExec>, Some(pool))
        };

    let batcher = Arc::new(Batcher::start(
        BatcherConfig {
            max_batch: cfg.max_batch,
            deadline: Duration::from_millis(cfg.batch_deadline_ms),
            row_len: meta.seq_len * meta.features,
            classes: meta.classes,
            max_inflight: cfg.replicas.max(1),
        },
        exec,
    ));

    let watcher = Watcher::start(
        cfg.checkpoint_dir.clone(),
        Duration::from_millis(cfg.poll_ms.max(1)),
        state.clone(),
        initial_fp,
    );

    let ctx = Arc::new(http::ServeCtx {
        state: state.clone(),
        batcher: batcher.clone(),
        model_key: cfg.model_key(),
        row_len: meta.seq_len * meta.features,
        classes: meta.classes,
        max_batch: cfg.max_batch,
        replicas: cfg.replicas,
    });
    let server = http::Server::start(cfg.port, ctx)
        .map_err(|e| format!("http listen on port {}: {e}", cfg.port))?;
    log::info!(
        "serve: {} on http://{} ({} replicas, max-batch {}, \
         weights from {})",
        cfg.model_key(), server.addr(), cfg.replicas, cfg.max_batch,
        source
    );
    Ok(ServeHandle { server, watcher, batcher, state, pool })
}

/// `serve` subcommand entry: boot and block forever (the process is
/// stopped by signal — systemd/CI kill the whole process group).
pub fn run_serve(cfg: &ServeConfig) -> Result<(), String> {
    let handle = start(cfg)?;
    // Periodic operational dump, JsonlLogger-style, to the log.
    loop {
        std::thread::sleep(Duration::from_secs(30));
        let lat = handle.batcher.latency();
        log::info!(
            "serve: weights v{} | {} batches | p50 {}ns p99 {}ns",
            handle.state.version(), lat.count(), lat.p50(), lat.p99()
        );
    }
}

/// Batch sizes the serving bench (and BENCH_pr.json block) covers.
pub const SERVE_BENCH_BATCHES: [usize; 3] = [1, 8, 32];
/// Replica counts the serving bench covers.
pub const SERVE_BENCH_REPLICAS: [usize; 2] = [1, 4];

/// The deterministic `serving` block of `BENCH_pr.json` (schema 3).
///
/// Like the `collective_ns` block, these are closed-form cost-model
/// numbers — reproducible on any machine, so the committed file can be
/// gated with `git diff --exit-code` in CI. The model: a forward pass
/// costs a third of [`CostModel::grad_time_nominal`] (one of
/// forward/backward/update), plus fixed HTTP+batching overhead and one
/// frontend→replica RPC hop on the cluster preset's intra-node link.
/// Real measured latencies go to `runs/bench/serve_bench.json` (not
/// committed) via `benches/serve_bench.rs`.
pub fn bench_block() -> Json {
    let cost = CostModel::cluster(3023);
    // Request parse + micro-batch assembly on the frontend.
    let http_overhead = 100.0e-6;
    // One ServeRequest/ServeReply round trip (intra-node link).
    let rpc_hop = 2.0 * cost.intra_latency + 20.0e-6;
    let mut p50: Vec<(String, Json)> = Vec::new();
    let mut p99: Vec<(String, Json)> = Vec::new();
    let mut qps: Vec<(String, Json)> = Vec::new();
    for &r in &SERVE_BENCH_REPLICAS {
        for &b in &SERVE_BENCH_BATCHES {
            let fwd = cost.grad_time_nominal(b) / 3.0;
            let lat50 = http_overhead + rpc_hop + fwd;
            // Tail: one straggling replica redo's worth of slack.
            let lat99 = lat50 * 1.25 + cost.latency;
            // Replicas pipeline independently; the frontend overhead
            // amortizes across in-flight batches.
            let throughput = r as f64 * b as f64 / (fwd + rpc_hop);
            let key = format!("b{b}_r{r}");
            p50.push((key.clone(), Json::Num((lat50 * 1e9).round())));
            p99.push((key.clone(), Json::Num((lat99 * 1e9).round())));
            qps.push((key, Json::Num(throughput.round())));
        }
    }
    let obj = |pairs: Vec<(String, Json)>| {
        Json::Obj(pairs.into_iter().collect())
    };
    Json::obj(vec![
        ("model_params", Json::Num(3023.0)),
        ("p50_ns", obj(p50)),
        ("p99_ns", obj(p99)),
        ("qps", obj(qps)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_config_parses_json_block() {
        let cfg = ServeConfig::from_json_text(
            r#"{"serve": {"model": "mlp", "checkpoint_dir": "out",
                 "port": 9000, "max_batch": 8, "replicas": 2,
                 "batch_deadline_ms": 3, "poll_ms": 100}}"#,
        )
        .unwrap();
        assert_eq!(cfg.model, "mlp");
        assert_eq!(cfg.model_key(), "mlp_b8");
        assert_eq!(cfg.port, 9000);
        assert_eq!(cfg.replicas, 2);
        assert_eq!(cfg.batch_deadline_ms, 3);
        assert_eq!(cfg.threads, 0, "default 0 = auto-detect");
        // Bare object (no "serve" wrapper) works too.
        let cfg = ServeConfig::from_json_text(
            r#"{"model": "lstm", "threads": 2}"#).unwrap();
        assert_eq!(cfg.model, "lstm");
        assert_eq!(cfg.threads, 2);
    }

    #[test]
    fn serve_config_rejects_bad_values() {
        for text in [
            r#"{"serve": {"max_batch": 0}}"#,
            r#"{"serve": {"replicas": 1000}}"#,
            r#"{"serve": {"model": "resnet"}}"#,
            r#"{"serve": {"port": "eighty"}}"#,
        ] {
            assert!(ServeConfig::from_json_text(text).is_err(),
                    "{text} must be rejected");
        }
    }

    #[test]
    fn serve_state_publish_runs_hook_and_snapshots() {
        let specs = vec![("w".to_string(), vec![3usize])];
        let state = ServeState::new(ParamSet::zeros(&specs), "boot");
        let seen: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
        {
            let seen = seen.clone();
            state.set_on_publish(Box::new(move |v, flat| {
                assert_eq!(flat.len(), 3);
                seen.lock().unwrap().push(v);
            }));
        }
        let before = state.params();
        let mut next = ParamSet::zeros(&specs);
        next.flat_mut().fill(4.0);
        assert_eq!(state.publish(next, "ckpt-1"), 1);
        assert_eq!(state.version(), 1);
        assert_eq!(state.source(), "ckpt-1");
        assert_eq!(*seen.lock().unwrap(), vec![1]);
        // The old snapshot is untouched — in-flight batches finish on
        // the weights they started with.
        assert!(before.flat().iter().all(|&x| x == 0.0));
        assert!(state.params().flat().iter().all(|&x| x == 4.0));
    }

    #[test]
    fn bench_block_is_deterministic_and_complete() {
        let a = bench_block();
        let b = bench_block();
        assert_eq!(a.to_string_compact(), b.to_string_compact());
        for section in ["p50_ns", "p99_ns", "qps"] {
            let s = a.get(section).unwrap().as_obj().unwrap();
            assert_eq!(s.len(), 6, "{section}");
            for r in SERVE_BENCH_REPLICAS {
                for bsz in SERVE_BENCH_BATCHES {
                    let key = format!("b{bsz}_r{r}");
                    assert!(s.contains_key(&key), "{section}.{key}");
                }
            }
        }
        let num = |sec: &str, key: &str| {
            a.get(sec).unwrap().get(key).unwrap().as_f64().unwrap()
        };
        for r in SERVE_BENCH_REPLICAS {
            for bsz in SERVE_BENCH_BATCHES {
                let key = format!("b{bsz}_r{r}");
                assert!(num("p99_ns", &key) > num("p50_ns", &key));
                assert!(num("qps", &key) > 0.0);
            }
        }
        // More replicas mean more throughput at the same batch size.
        assert!(num("qps", "b32_r4") > num("qps", "b32_r1"));
        // Bigger batches amortize overhead into higher QPS.
        assert!(num("qps", "b32_r1") > num("qps", "b1_r1"));
    }
}
