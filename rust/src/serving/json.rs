//! Typed JSON bodies for the serving API, built on the crate's own
//! parser ([`crate::util::json`] — no serde in the offline tree).
//!
//! Float transport is exact: an `f32` widened to `f64` serializes via
//! Rust's shortest-roundtrip formatting and parses back to the same
//! `f64`, whose narrowing to `f32` is the original value. The e2e suite
//! leans on this to compare HTTP responses *bitwise* against a locally
//! computed forward pass.

use crate::util::json::Json;

/// A decoded `POST /v1/predict` body: `rows` row-major input rows,
/// flattened.
#[derive(Clone, Debug, PartialEq)]
pub struct PredictRequest {
    pub rows: usize,
    pub x: Vec<f32>,
}

/// Why a request body was rejected (maps to HTTP 400 vs 413).
#[derive(Clone, Debug, PartialEq)]
pub enum BodyError {
    /// Malformed JSON or wrong shape/types — HTTP 400.
    Bad(String),
    /// Well-formed but more rows than the server's `--max-batch` —
    /// HTTP 413.
    TooLarge { rows: usize, max_rows: usize },
}

impl std::fmt::Display for BodyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BodyError::Bad(m) => write!(f, "{m}"),
            BodyError::TooLarge { rows, max_rows } => write!(
                f,
                "request has {rows} instances, server max-batch is \
                 {max_rows}"
            ),
        }
    }
}

/// Parse `{"instances": [[f32; row_len], ...]}`. Every instance must
/// be a flat array of exactly `row_len` finite numbers; at least one
/// and at most `max_rows` instances.
pub fn parse_predict_request(body: &str, row_len: usize, max_rows: usize)
    -> Result<PredictRequest, BodyError> {
    let bad = BodyError::Bad;
    let j = Json::parse(body)
        .map_err(|e| bad(format!("invalid JSON: {e}")))?;
    let instances = j
        .get("instances")
        .ok_or_else(|| bad("missing required key \"instances\"".into()))?
        .as_arr()
        .ok_or_else(|| bad("\"instances\" must be an array".into()))?;
    if instances.is_empty() {
        return Err(bad("\"instances\" must be non-empty".into()));
    }
    if instances.len() > max_rows {
        return Err(BodyError::TooLarge {
            rows: instances.len(),
            max_rows,
        });
    }
    let mut x = Vec::with_capacity(instances.len() * row_len);
    for (i, inst) in instances.iter().enumerate() {
        let row = inst.as_arr().ok_or_else(|| {
            bad(format!("instance {i} must be an array of numbers"))
        })?;
        if row.len() != row_len {
            return Err(bad(format!(
                "instance {i} has {} values, model expects {row_len} \
                 (seq_len * features)",
                row.len()
            )));
        }
        for (k, v) in row.iter().enumerate() {
            let f = v.as_f64().ok_or_else(|| {
                bad(format!("instance {i}[{k}] is not a number"))
            })?;
            if !f.is_finite() {
                return Err(bad(format!(
                    "instance {i}[{k}] is not finite"
                )));
            }
            x.push(f as f32);
        }
    }
    Ok(PredictRequest { rows: instances.len(), x })
}

/// `{"predictions": [[f32; classes], ...], "weight_version": v}`.
pub fn predict_response(logits: &[f32], classes: usize, version: u64)
    -> String {
    debug_assert_eq!(logits.len() % classes, 0);
    let rows: Vec<Json> = logits
        .chunks_exact(classes)
        .map(|row| {
            Json::Arr(row.iter().map(|&v| Json::Num(v as f64)).collect())
        })
        .collect();
    Json::obj(vec![
        ("predictions", Json::Arr(rows)),
        ("weight_version", Json::Num(version as f64)),
    ])
    .to_string_compact()
}

/// `{"error": msg}` — every non-200 body has this shape.
pub fn error_body(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).to_string_compact()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_well_formed_request() {
        let body = r#"{"instances": [[1.0, 2.5], [-3.0, 0.125]]}"#;
        let req = parse_predict_request(body, 2, 8).unwrap();
        assert_eq!(req.rows, 2);
        assert_eq!(req.x, vec![1.0, 2.5, -3.0, 0.125]);
    }

    #[test]
    fn rejects_malformed_bodies_with_reasons() {
        for (body, needle) in [
            ("not json", "invalid JSON"),
            ("{}", "instances"),
            (r#"{"instances": 3}"#, "array"),
            (r#"{"instances": []}"#, "non-empty"),
            (r#"{"instances": [[1.0]]}"#, "expects 2"),
            (r#"{"instances": [[1.0, "x"]]}"#, "not a number"),
        ] {
            match parse_predict_request(body, 2, 8) {
                Err(BodyError::Bad(m)) => {
                    assert!(m.contains(needle), "{body}: {m}")
                }
                other => panic!("{body}: expected Bad, got {other:?}"),
            }
        }
    }

    #[test]
    fn oversized_request_is_too_large_not_bad() {
        let body = r#"{"instances": [[1.0], [2.0], [3.0]]}"#;
        assert_eq!(
            parse_predict_request(body, 1, 2),
            Err(BodyError::TooLarge { rows: 3, max_rows: 2 })
        );
    }

    #[test]
    fn f32_roundtrips_bitwise_through_response_json() {
        // Awkward values: subnormal, almost-1, big, tiny negative.
        let logits = [
            1.1754944e-38f32,
            0.1,
            -0.30000001,
            3.4e38,
            0.999_999_94,
            -7.0e-9,
        ];
        let body = predict_response(&logits, 3, 42);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("weight_version").unwrap().as_i64(), Some(42));
        let rows = j.get("predictions").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        let mut back = Vec::new();
        for row in rows {
            for v in row.as_arr().unwrap() {
                back.push(v.as_f64().unwrap() as f32);
            }
        }
        assert_eq!(back.len(), logits.len());
        for (a, b) in back.iter().zip(&logits) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
        }
    }

    #[test]
    fn error_body_is_json() {
        let b = error_body("bad \"thing\"\n");
        let j = Json::parse(&b).unwrap();
        assert_eq!(j.get("error").unwrap().as_str(),
                   Some("bad \"thing\"\n"));
    }
}
