//! Request micro-batcher: the serving hot path's fan-in point.
//!
//! Concurrent HTTP handler threads submit small predict requests; one
//! flusher thread coalesces them into a single forward pass. A batch
//! flushes when the queued rows reach `max_batch` OR when the oldest
//! queued request has waited `deadline` — whichever comes first — so
//! throughput under load and tail latency when idle are both bounded.
//!
//! The executor is injected as a [`BatchExec`] trait object: in
//! single-process serving it wraps `ModelExecutables::predict_rows`
//! against the hot-reloadable `ParamSet`; with `--replicas N` it is the
//! replica pool dispatching over `Comm`. That seam is what lets the
//! flush policy be unit-tested with a scripted executor.

use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::metrics::Histogram;

/// One micro-batch's executor: `rows` rows of flat input (row-major,
/// `rows * row_len` floats) -> `(weight_version, flat logits)` with
/// `rows * classes` logits. The version is the one the pass actually
/// computed with — under a concurrent hot reload it may lag the
/// published version, and responses must report the truth so clients
/// (and the e2e suite) can tie outputs to exact weights. An `Err`
/// fails only the requests in this batch (HTTP 503), never the server.
pub trait BatchExec: Send + Sync {
    fn predict(&self, rows: usize, x: &[f32])
        -> Result<(u64, Vec<f32>), String>;
}

/// Flush policy + shapes.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Flush when this many rows are queued (also the executor's
    /// compiled batch capacity — one request may not exceed it).
    pub max_batch: usize,
    /// Flush when the oldest queued request has waited this long.
    pub deadline: Duration,
    /// Floats per input row (`seq_len * features`).
    pub row_len: usize,
    /// Floats per output row.
    pub classes: usize,
    /// Batches allowed in flight at once. 1 serializes the executor;
    /// with `--replicas N` the serve loop sets `N` so the replica pool
    /// can keep every replica busy while the batcher keeps collecting.
    pub max_inflight: usize,
}

struct Pending {
    rows: usize,
    x: Vec<f32>,
    enqueued: Instant,
    reply: mpsc::Sender<Result<(u64, Vec<f32>), String>>,
}

#[derive(Default)]
struct Queue {
    pending: Vec<Pending>,
    queued_rows: usize,
    inflight: usize,
    shutdown: bool,
}

struct Shared {
    cfg: BatcherConfig,
    queue: Mutex<Queue>,
    /// Woken on submit and on shutdown.
    cv: Condvar,
    /// End-to-end batch latency (enqueue of the oldest request ->
    /// responses sent), nanoseconds.
    latency: Mutex<Histogram>,
    /// Rows per flushed batch — how full the batcher runs.
    batch_rows: Mutex<Histogram>,
}

/// Handle to the flusher thread. Dropping without `shutdown()` leaves
/// the thread running until the process exits (the serve loop's normal
/// lifetime); tests call `shutdown()` for a clean join.
pub struct Batcher {
    shared: Arc<Shared>,
    // Behind a Mutex so shutdown works through an `Arc<Batcher>` (the
    // HTTP layer and the serve handle share one).
    flusher: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Batcher {
    pub fn start(cfg: BatcherConfig, exec: Arc<dyn BatchExec>) -> Batcher {
        assert!(cfg.max_batch >= 1, "max_batch must be >= 1");
        assert!(cfg.max_inflight >= 1, "max_inflight must be >= 1");
        let shared = Arc::new(Shared {
            cfg,
            queue: Mutex::new(Queue::default()),
            cv: Condvar::new(),
            latency: Mutex::new(Histogram::new()),
            batch_rows: Mutex::new(Histogram::new()),
        });
        let flusher = {
            let shared = shared.clone();
            std::thread::spawn(move || flush_loop(shared, exec))
        };
        Batcher { shared, flusher: Mutex::new(Some(flusher)) }
    }

    /// Enqueue one request and block until its `(weight_version,
    /// logits)` (or the batch's error) come back. `rows` must be
    /// `1..=max_batch` and `x.len() == rows * row_len` — the HTTP
    /// layer enforces both before calling (400/413), so violations
    /// here are bugs.
    pub fn predict(&self, rows: usize, x: Vec<f32>)
        -> Result<(u64, Vec<f32>), String> {
        assert!((1..=self.shared.cfg.max_batch).contains(&rows),
                "rows {rows} outside 1..={}", self.shared.cfg.max_batch);
        assert_eq!(x.len(), rows * self.shared.cfg.row_len,
                   "input length / rows mismatch");
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            if q.shutdown {
                return Err("server shutting down".into());
            }
            q.queued_rows += rows;
            q.pending.push(Pending {
                rows,
                x,
                enqueued: Instant::now(),
                reply: tx,
            });
        }
        self.shared.cv.notify_all();
        rx.recv().unwrap_or_else(|_| Err("batcher stopped".into()))
    }

    /// Snapshot of the end-to-end batch latency histogram.
    pub fn latency(&self) -> Histogram {
        self.shared.latency.lock().unwrap().clone()
    }

    /// Snapshot of the rows-per-flush histogram.
    pub fn batch_rows(&self) -> Histogram {
        self.shared.batch_rows.lock().unwrap().clone()
    }

    /// Stop the flusher. Queued requests still flush first (drain, then
    /// exit) and in-flight batches finish, so no accepted request is
    /// dropped.
    pub fn shutdown(&self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.cv.notify_all();
        if let Some(h) = self.flusher.lock().unwrap().take() {
            let _ = h.join();
        }
        let mut q = self.shared.queue.lock().unwrap();
        while q.inflight > 0 {
            q = self.shared.cv.wait(q).unwrap();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn flush_loop(shared: Arc<Shared>, exec: Arc<dyn BatchExec>) {
    let cfg = shared.cfg;
    loop {
        // Decide under the lock, execute outside it.
        let batch: Vec<Pending>;
        {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if q.pending.is_empty() {
                    if q.shutdown {
                        return;
                    }
                    // Nothing queued: an empty flush must never reach
                    // the executor, so just wait for a submit.
                    q = shared.cv.wait(q).unwrap();
                    continue;
                }
                if q.inflight >= cfg.max_inflight {
                    // At the concurrency cap: wait for a batch thread
                    // to finish (it notifies the condvar).
                    q = shared.cv.wait(q).unwrap();
                    continue;
                }
                let waited = q.pending[0].enqueued.elapsed();
                if q.queued_rows >= cfg.max_batch
                    || waited >= cfg.deadline
                    || q.shutdown {
                    break;
                }
                let (nq, _) = shared.cv
                    .wait_timeout(q, cfg.deadline - waited)
                    .unwrap();
                q = nq;
            }
            // Take whole requests in arrival order while they fit the
            // executor's batch; a request that would overflow waits for
            // the next flush (its rows stay counted in queued_rows).
            let mut take = 0usize;
            let mut rows = 0usize;
            while take < q.pending.len()
                && rows + q.pending[take].rows <= cfg.max_batch {
                rows += q.pending[take].rows;
                take += 1;
            }
            batch = q.pending.drain(..take).collect();
            q.queued_rows -= rows;
            if !batch.is_empty() {
                q.inflight += 1;
            }
        }
        if batch.is_empty() {
            continue;
        }
        // Run the batch on its own thread so the flusher can keep
        // collecting: with `--replicas N` up to `max_inflight` batches
        // dispatch concurrently and the replica pool fans them out.
        let shared = shared.clone();
        let exec = exec.clone();
        std::thread::spawn(move || run_batch(&shared, exec.as_ref(), batch));
    }
}

fn run_batch(shared: &Shared, exec: &dyn BatchExec, batch: Vec<Pending>) {
    let cfg = shared.cfg;
    let rows: usize = batch.iter().map(|p| p.rows).sum();
    let oldest = batch[0].enqueued;
    let mut x = Vec::with_capacity(rows * cfg.row_len);
    for p in &batch {
        x.extend_from_slice(&p.x);
    }
    let result = exec.predict(rows, &x);
    shared.batch_rows.lock().unwrap().record(rows as u64);
    // Record latency before replying so a caller that returns from
    // `predict` observes its own flush in the histogram.
    let ns = oldest.elapsed().as_nanos().min(u128::from(u64::MAX));
    shared.latency.lock().unwrap().record(ns as u64);
    match result {
        Ok((version, logits)) => {
            // Split in arrival order: request i gets its own rows'
            // logits, so responses are order-preserving however
            // arrivals interleaved.
            let mut off = 0usize;
            for p in &batch {
                let n = p.rows * cfg.classes;
                let _ = p.reply
                    .send(Ok((version, logits[off..off + n].to_vec())));
                off += n;
            }
        }
        Err(e) => {
            // Fail only this batch; later batches are unaffected.
            for p in &batch {
                let _ = p.reply.send(Err(e.clone()));
            }
        }
    }
    let mut q = shared.queue.lock().unwrap();
    q.inflight -= 1;
    drop(q);
    shared.cv.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Echo executor: classes == row_len, output row == input row.
    /// Records every call's row count so tests can assert flush shape.
    struct Echo {
        calls: Mutex<Vec<usize>>,
        delay: Duration,
    }

    impl Echo {
        fn new() -> Arc<Echo> {
            Arc::new(Echo {
                calls: Mutex::new(Vec::new()),
                delay: Duration::ZERO,
            })
        }

        fn call_sizes(&self) -> Vec<usize> {
            self.calls.lock().unwrap().clone()
        }
    }

    impl BatchExec for Echo {
        fn predict(&self, rows: usize, x: &[f32])
            -> Result<(u64, Vec<f32>), String> {
            self.calls.lock().unwrap().push(rows);
            if !self.delay.is_zero() {
                std::thread::sleep(self.delay);
            }
            Ok((7, x.to_vec()))
        }
    }

    fn cfg(max_batch: usize, deadline_ms: u64) -> BatcherConfig {
        BatcherConfig {
            max_batch,
            deadline: Duration::from_millis(deadline_ms),
            row_len: 4,
            classes: 4,
            max_inflight: 2,
        }
    }

    fn row(fill: f32) -> Vec<f32> {
        vec![fill; 4]
    }

    #[test]
    fn max_batch_flushes_before_deadline() {
        let echo = Echo::new();
        // Deadline far away: only the rows threshold can flush.
        let b = Arc::new(Batcher::start(cfg(4, 60_000), echo.clone()));
        let t0 = Instant::now();
        let threads: Vec<_> = (0..4)
            .map(|i| {
                let b = b.clone();
                std::thread::spawn(move || {
                    b.predict(1, row(i as f32)).unwrap()
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert!(t0.elapsed() < Duration::from_secs(30),
                "must flush on max-batch, not deadline");
        assert_eq!(echo.call_sizes(), vec![4],
                   "four 1-row requests coalesce into one 4-row pass");
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let echo = Echo::new();
        let b = Batcher::start(cfg(32, 30), echo.clone());
        let t0 = Instant::now();
        let (v, out) = b.predict(2, [row(1.0), row(2.0)].concat())
            .unwrap();
        let waited = t0.elapsed();
        assert_eq!(v, 7, "executor's weight version must pass through");
        assert_eq!(out, [row(1.0), row(2.0)].concat());
        assert!(waited >= Duration::from_millis(25),
                "flushed after only {waited:?} — deadline not honored");
        assert_eq!(echo.call_sizes(), vec![2]);
    }

    #[test]
    fn empty_queue_never_calls_predict() {
        let echo = Echo::new();
        let b = Batcher::start(cfg(8, 10), echo.clone());
        // Several deadline periods pass with nothing queued.
        std::thread::sleep(Duration::from_millis(60));
        b.shutdown();
        assert!(echo.call_sizes().is_empty(),
                "idle batcher must never flush an empty batch");
    }

    #[test]
    fn response_order_preserved_under_interleaved_arrivals() {
        let echo = Echo::new();
        let b = Arc::new(Batcher::start(cfg(8, 5), echo.clone()));
        let mut threads = Vec::new();
        for i in 0..24 {
            let b = b.clone();
            threads.push(std::thread::spawn(move || {
                let fill = i as f32;
                // 1- and 2-row requests interleave arbitrarily.
                let rows = 1 + (i % 2);
                let x: Vec<f32> = vec![fill; 4 * rows];
                let (_, out) = b.predict(rows, x.clone()).unwrap();
                (x, out)
            }));
        }
        for t in threads {
            let (sent, got) = t.join().unwrap();
            assert_eq!(sent, got,
                       "a request must get back exactly its own rows");
        }
        let sizes = echo.call_sizes();
        assert!(sizes.iter().all(|&r| (1..=8).contains(&r)), "{sizes:?}");
        assert_eq!(sizes.iter().sum::<usize>(), 24 + 12,
                   "every submitted row flushed exactly once");
    }

    /// A failing executor fails only the requests in that flush; the
    /// next batch succeeds — the per-batch 503 contract.
    struct FailOnce {
        failed: AtomicUsize,
        inner: Arc<Echo>,
    }

    impl BatchExec for FailOnce {
        fn predict(&self, rows: usize, x: &[f32])
            -> Result<(u64, Vec<f32>), String> {
            if self.failed.fetch_add(1, Ordering::SeqCst) == 0 {
                return Err("replica timeout".into());
            }
            self.inner.predict(rows, x)
        }
    }

    #[test]
    fn failed_batch_503s_only_its_own_requests() {
        let exec = Arc::new(FailOnce {
            failed: AtomicUsize::new(0),
            inner: Echo::new(),
        });
        let b = Batcher::start(cfg(8, 10), exec);
        let first = b.predict(1, row(1.0));
        assert_eq!(first.unwrap_err(), "replica timeout");
        let second = b.predict(1, row(2.0));
        assert_eq!(second.unwrap().1, row(2.0),
                   "a batch failure must not poison later batches");
    }

    #[test]
    fn latency_histogram_records_flushes() {
        let echo = Echo::new();
        let b = Batcher::start(cfg(4, 5), echo);
        for _ in 0..3 {
            b.predict(1, row(0.0)).unwrap();
        }
        let lat = b.latency();
        assert_eq!(lat.count(), 3);
        assert!(lat.max() > 0);
        let rows = b.batch_rows();
        assert_eq!(rows.count(), 3);
        b.shutdown();
    }
}
