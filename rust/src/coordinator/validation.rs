//! Master-side validation (§V): serial, so its cost directly eats into
//! scaling — exactly the bottleneck the paper discusses. The frequency is
//! controlled by `Algo::validate_every` and each round's size by
//! `Algo::max_val_batches`.

use crate::data::DataSet;
use crate::runtime::ModelExecutables;
use crate::tensor::ParamSet;

/// One validation sweep over (a prefix of) the held-out set.
///
/// Returns (mean loss, accuracy). Uses fixed-order batches so successive
/// rounds are comparable.
pub fn run_validation(exes: &ModelExecutables, params: &ParamSet,
                      val: &DataSet, max_batches: usize)
    -> Result<(f32, f32), crate::runtime::RuntimeError> {
    let batch = exes.meta.batch;
    let mut total_loss = 0.0f64;
    let mut total_correct = 0.0f64;
    let mut batches = 0usize;
    let mut err: Option<crate::runtime::RuntimeError> = None;
    val.for_each_batch_ordered(batch, |x, y| {
        if err.is_some() || (max_batches > 0 && batches >= max_batches) {
            return;
        }
        match exes.eval_step(params, x, y) {
            Ok((loss, ncorrect)) => {
                total_loss += loss as f64;
                total_correct += ncorrect as f64;
                batches += 1;
            }
            Err(e) => err = Some(e),
        }
    });
    if let Some(e) = err {
        return Err(e);
    }
    if batches == 0 {
        return Ok((f32::NAN, 0.0));
    }
    let n = (batches * batch) as f64;
    Ok(((total_loss / batches as f64) as f32,
        (total_correct / n) as f32))
}

/// Validation scheduling policy: run every `every` master updates.
#[derive(Clone, Debug)]
pub struct ValidationSchedule {
    every: u64,
    last_run_at: u64,
}

impl ValidationSchedule {
    pub fn new(every: u64) -> Self {
        Self { every, last_run_at: 0 }
    }

    /// Should validation run after master update number `update`?
    pub fn due(&mut self, update: u64) -> bool {
        if self.every == 0 {
            return false;
        }
        if update >= self.last_run_at + self.every {
            self.last_run_at = update;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_fires_on_period() {
        let mut s = ValidationSchedule::new(10);
        assert!(!s.due(5));
        assert!(s.due(10));
        assert!(!s.due(11));
        assert!(!s.due(19));
        assert!(s.due(20));
        // skipping far ahead still fires once, then re-arms
        assert!(s.due(45));
        assert!(!s.due(46));
        assert!(s.due(55));
    }

    #[test]
    fn zero_period_never_fires() {
        let mut s = ValidationSchedule::new(0);
        for u in 0..100 {
            assert!(!s.due(u));
        }
    }
}
