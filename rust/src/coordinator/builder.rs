//! The paper's user-interface classes (§III-B): `ModelBuilder` and `Data`.
//!
//! - `ModelBuilder` selects the model architecture. In `mpi_learn` it
//!   builds a Keras model from JSON or code; here it names an AOT-compiled
//!   artifact variant (model family + batch size) from the manifest.
//! - `Data` provides the training input. The user "may provide a list of
//!   input file paths, which are divided evenly among all worker
//!   processes" — that is [`Data::Files`]; [`Data::Synthetic`] generates
//!   the benchmark dataset in memory (tests/benches).

use std::path::PathBuf;

use crate::data::{divide_files, generator, DataSet, GeneratorConfig};
use crate::util::rng::Rng;

/// Selects which compiled model variant to train.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelBuilder {
    /// Model family: "lstm" (paper benchmark), "mlp", "transformer".
    pub model: String,
    /// Batch size — must match an AOT artifact (`{model}_b{batch}`).
    pub batch: usize,
}

impl ModelBuilder {
    pub fn new(model: &str, batch: usize) -> Self {
        Self { model: model.to_string(), batch }
    }

    pub fn variant_key(&self) -> String {
        format!("{}_b{}", self.model, self.batch)
    }
}

/// Training + validation data source.
///
/// The same even division serves every training mode: Downpour/EASGD
/// workers each load their share, and in `Mode::AllReduce` every rank of
/// the masterless world is a "worker" (rank r takes division r of n).
/// Uneven divisions are safe in all modes — the all-reduce loop agrees
/// on the minimum per-epoch batch count up front.
#[derive(Clone, Debug)]
pub enum Data {
    /// Shard files on disk, divided evenly among workers (paper §III-B).
    Files { train: Vec<PathBuf>, val: PathBuf },
    /// In-memory synthetic benchmark data: each worker generates its own
    /// shard-equivalent from a forked deterministic stream.
    Synthetic {
        gen: GeneratorConfig,
        samples_per_worker: usize,
        val_samples: usize,
    },
}

impl Data {
    /// Materialize worker `w`-of-`n`'s training set.
    pub fn worker_dataset(&self, w: usize, n: usize)
        -> Result<DataSet, crate::data::ShardError> {
        match self {
            Data::Files { train, .. } => {
                let mine = divide_files(train, w, n);
                DataSet::from_files(&mine)
            }
            Data::Synthetic { gen, samples_per_worker, .. } => {
                let mut rng = Rng::new(gen.seed).fork(w as u64 + 1);
                Ok(DataSet::from_shard(generator::generate_shard(
                    gen, *samples_per_worker, &mut rng)))
            }
        }
    }

    /// Materialize the held-out validation set.
    pub fn validation_dataset(&self)
        -> Result<DataSet, crate::data::ShardError> {
        match self {
            Data::Files { val, .. } => {
                DataSet::from_files(std::slice::from_ref(val))
            }
            Data::Synthetic { gen, val_samples, .. } => {
                let mut rng = Rng::new(gen.seed).fork(0xA11_DA7A);
                Ok(DataSet::from_shard(generator::generate_shard(
                    gen, *val_samples, &mut rng)))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_key_format() {
        assert_eq!(ModelBuilder::new("lstm", 100).variant_key(),
                   "lstm_b100");
    }

    #[test]
    fn synthetic_workers_get_distinct_data() {
        let data = Data::Synthetic {
            gen: GeneratorConfig { seq_len: 4, features: 3,
                                   ..Default::default() },
            samples_per_worker: 50,
            val_samples: 20,
        };
        let a = data.worker_dataset(0, 2).unwrap();
        let b = data.worker_dataset(1, 2).unwrap();
        assert_eq!(a.n_samples(), 50);
        assert_eq!(b.n_samples(), 50);
        assert_ne!(a.labels(), b.labels());
        let val = data.validation_dataset().unwrap();
        assert_eq!(val.n_samples(), 20);
    }

    #[test]
    fn synthetic_is_deterministic() {
        let data = Data::Synthetic {
            gen: GeneratorConfig { seq_len: 4, features: 3, seed: 7,
                                   ..Default::default() },
            samples_per_worker: 30,
            val_samples: 10,
        };
        let a = data.worker_dataset(1, 4).unwrap();
        let b = data.worker_dataset(1, 4).unwrap();
        assert_eq!(a.labels(), b.labels());
    }
}
