//! The worker process: read a batch, compute the gradient, trade it for
//! fresh weights (Downpour), or train locally and exchange elastically
//! (EASGD). Paper §III-A.
//!
//! Also home to [`RingWorker`], the masterless peer of `Mode::AllReduce`:
//! every rank trains, the world averages gradients with a chunked ring
//! all-reduce, and each rank applies an identical replicated optimizer
//! step — so all ranks hold bitwise-identical weights at every round.
//! Rank 0 doubles as the *observer* (validation + callbacks); an early
//! stop piggybacks as one extra element on the next collective, so every
//! rank breaks in lockstep with identical weights.

use std::time::{Duration, Instant};

use crate::coordinator::algo::{Algo, Mode};
use crate::coordinator::callbacks::{LrScheduleSpec, Observer};
use crate::coordinator::elastic::{self, MemberOutcome, NewWorld};
use crate::coordinator::planner::RetuneConfig;
use crate::coordinator::topology::WorldPlan;
use crate::data::DataSet;
use crate::metrics::{History, Stopwatch, WorkerReport};
use crate::mpi::codec::{grad_payload, Compressor};
use crate::mpi::collective::{Collective, GroupLayout, ReduceOp};
use crate::mpi::{tags, Comm, CommError, Envelope, Payload, Rank, Tag,
                 WorkerStats};
use crate::runtime::{BucketReady, GradSink, ModelExecutables};
use crate::tensor::ParamSet;
use crate::util::rng::Rng;

/// Worker configuration + state.
pub struct Worker<'a> {
    comm: &'a Comm,
    master: Rank,
    algo: &'a Algo,
    exes: &'a ModelExecutables,
    data: &'a DataSet,
    rng: Rng,
}

#[derive(Debug)]
pub enum WorkerError {
    Runtime(crate::runtime::RuntimeError),
    Comm(crate::mpi::CommError),
    Protocol(Tag),
    EarlyExit,
    Unsupported(&'static str),
    /// Elastic membership agreement failed (e.g. the coordinator's
    /// plan never arrived — rank 0 is gone, which ends the job).
    Elastic(String),
    /// Chaos hook: this rank was told to die mid-run
    /// ([`RingWorker::with_fault_after`]) and is simulating a crash —
    /// no stats, no wind-down.
    FaultInjected,
}

impl std::fmt::Display for WorkerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WorkerError::Runtime(e) => write!(f, "runtime: {e}"),
            WorkerError::Comm(e) => write!(f, "comm: {e}"),
            WorkerError::Protocol(tag) => {
                write!(f, "master sent unexpected tag {tag:?}")
            }
            WorkerError::EarlyExit => {
                write!(f, "master told us to exit early")
            }
            WorkerError::Unsupported(msg) => {
                write!(f, "unsupported: {msg}")
            }
            WorkerError::Elastic(msg) => write!(f, "elastic: {msg}"),
            WorkerError::FaultInjected => {
                write!(f, "fault injection: this rank crashed on cue")
            }
        }
    }
}

impl std::error::Error for WorkerError {}

impl From<crate::runtime::RuntimeError> for WorkerError {
    fn from(e: crate::runtime::RuntimeError) -> Self {
        WorkerError::Runtime(e)
    }
}

impl From<crate::mpi::CommError> for WorkerError {
    fn from(e: crate::mpi::CommError) -> Self {
        WorkerError::Comm(e)
    }
}

impl<'a> Worker<'a> {
    pub fn new(comm: &'a Comm, master: Rank, algo: &'a Algo,
               exes: &'a ModelExecutables, data: &'a DataSet, seed: u64)
        -> Self {
        Self { comm, master, algo, exes, data, rng: Rng::new(seed) }
    }

    /// Announce readiness and receive the initial weights (raw or
    /// fp16-packed, per the master's codec).
    fn handshake(&mut self, params: &mut ParamSet)
        -> Result<u64, WorkerError> {
        self.comm.send(self.master, Tag::Ready, Payload::Empty)?;
        let env = self.comm.recv()?;
        match env.tag {
            Tag::Weights => match env.payload.weights_like() {
                Some((step, data)) => {
                    params.set_flat(&data);
                    Ok(step)
                }
                None => Err(WorkerError::Protocol(Tag::Weights)),
            },
            Tag::Exit => Err(WorkerError::EarlyExit),
            tag => Err(WorkerError::Protocol(tag)),
        }
    }

    /// Run the configured number of epochs; returns the final report.
    pub fn run(mut self) -> Result<WorkerReport, WorkerError> {
        let mut params = ParamSet::zeros(&self.exes.meta.params);
        let step0 = match self.handshake(&mut params) {
            Ok(step0) => step0,
            Err(WorkerError::EarlyExit) => {
                // an early-stopping master may wind the world down
                // before we ever trained: report zero work and leave
                // cleanly so the master's Exit count completes
                let report = WorkerReport {
                    rank: self.comm.rank(),
                    ..Default::default()
                };
                self.finish(&report)?;
                return Ok(report);
            }
            Err(e) => return Err(e),
        };
        match self.algo.mode.clone() {
            Mode::Downpour { .. } => self.run_downpour(params, step0),
            Mode::Easgd { tau, alpha, worker_optimizer } => {
                self.run_easgd(params, tau, alpha, &worker_optimizer)
            }
            Mode::AllReduce => Err(WorkerError::Unsupported(
                "Mode::AllReduce has no master/worker roles; the driver \
                 runs RingWorker on every rank instead",
            )),
        }
    }

    fn finish(&self, report: &WorkerReport) -> Result<(), WorkerError> {
        self.comm.send(
            self.master,
            Tag::TrainStats,
            Payload::Stats(WorkerStats {
                epoch: report.epochs,
                batches_done: report.batches,
                samples_done: report.samples,
                train_loss: report.last_train_loss,
                grad_time_s: report.grad_time_s,
                comm_wait_s: report.comm_wait_s,
            }),
        )?;
        self.comm.send(self.master, Tag::Exit, Payload::Empty)?;
        Ok(())
    }

    fn run_downpour(&mut self, mut params: ParamSet, step0: u64)
        -> Result<WorkerReport, WorkerError> {
        let batch = self.algo.batch_size;
        let mut report = WorkerReport {
            rank: self.comm.rank(),
            ..Default::default()
        };
        let mut grad_timer = Stopwatch::new();
        let mut comm_timer = Stopwatch::new();
        let mut model_step = step0;
        // Gradient-uplink codec state: the error-feedback residual
        // persists across batches AND epochs (dropped mass is delayed,
        // never lost).
        let mut compressor = Compressor::new(self.algo.compression);
        compressor.set_pool(self.exes.thread_pool());
        for epoch in 0..self.algo.epochs {
            let mut rng = self.rng.fork(epoch as u64);
            let mut failure: Option<WorkerError> = None;
            // buffers move through the closure; results come back via refs
            let params_ref = &mut params;
            let step_ref = &mut model_step;
            let report_ref = &mut report;
            let gt = &mut grad_timer;
            let ct = &mut comm_timer;
            let comp = &mut compressor;
            self.data.for_each_batch(batch, &mut rng, |x, y| {
                if failure.is_some() {
                    return;
                }
                let out = match gt.time(|| self.exes.grad_step(
                    params_ref, x, y)) {
                    Ok(o) => o,
                    Err(e) => {
                        failure = Some(e.into());
                        return;
                    }
                };
                report_ref.last_train_loss = out.loss;
                let send_recv = || -> Result<(), WorkerError> {
                    self.comm.send(
                        self.master,
                        Tag::Gradients,
                        grad_payload(comp, *step_ref, out.loss,
                                     out.grads),
                    )?;
                    let env = self.comm.recv()?;
                    match env.tag {
                        Tag::Weights => {
                            match env.payload.weights_like() {
                                Some((step, data)) => {
                                    params_ref.set_flat(&data);
                                    *step_ref = step;
                                    Ok(())
                                }
                                None => Err(WorkerError::Protocol(
                                    Tag::Weights)),
                            }
                        }
                        Tag::Exit => Err(WorkerError::EarlyExit),
                        tag => Err(WorkerError::Protocol(tag)),
                    }
                };
                if let Err(e) = ct.time(send_recv) {
                    failure = Some(e);
                    return;
                }
                report_ref.batches += 1;
                report_ref.samples += batch as u64;
            });
            match failure {
                Some(WorkerError::EarlyExit) => break,
                Some(e) => return Err(e),
                None => {}
            }
            report.epochs = epoch + 1;
            log::debug!("epoch {} done, loss={:.4}", epoch + 1,
                        report.last_train_loss);
        }
        report.grad_time_s = grad_timer.total_s();
        report.comm_wait_s = comm_timer.total_s();
        self.finish(&report)?;
        Ok(report)
    }

    fn run_easgd(&mut self, mut params: ParamSet, tau: u32, alpha: f32,
                 worker_opt: &crate::optim::OptimizerConfig)
        -> Result<WorkerReport, WorkerError> {
        let batch = self.algo.batch_size;
        let mut opt = worker_opt.build(params.num_params());
        opt.set_pool(self.exes.thread_pool());
        let mut report = WorkerReport {
            rank: self.comm.rank(),
            ..Default::default()
        };
        let mut grad_timer = Stopwatch::new();
        let mut comm_timer = Stopwatch::new();
        let mut since_exchange = 0u32;
        for epoch in 0..self.algo.epochs {
            let mut rng = self.rng.fork(epoch as u64);
            let mut failure: Option<WorkerError> = None;
            let params_ref = &mut params;
            let report_ref = &mut report;
            let opt_ref = &mut opt;
            let since_ref = &mut since_exchange;
            let gt = &mut grad_timer;
            let ct = &mut comm_timer;
            self.data.for_each_batch(batch, &mut rng, |x, y| {
                if failure.is_some() {
                    return;
                }
                let out = match gt.time(|| self.exes.grad_step(
                    params_ref, x, y)) {
                    Ok(o) => o,
                    Err(e) => {
                        failure = Some(e.into());
                        return;
                    }
                };
                report_ref.last_train_loss = out.loss;
                // local update — workers explore independently
                opt_ref.update(params_ref.flat_mut(), &out.grads);
                report_ref.batches += 1;
                report_ref.samples += batch as u64;
                *since_ref += 1;
                if *since_ref >= tau {
                    *since_ref = 0;
                    let exchange = || -> Result<(), WorkerError> {
                        // weight exchange is a replication hop: fp16
                        // compresses it, top-k never does
                        self.comm.send(
                            self.master,
                            Tag::ExchangeWeights,
                            self.algo.compression.weights_payload(
                                report_ref.batches,
                                params_ref.flat()),
                        )?;
                        let env = self.comm.recv()?;
                        match env.tag {
                            Tag::Center => {
                                let center = env
                                    .payload
                                    .weights_like()
                                    .ok_or(WorkerError::Protocol(
                                        Tag::Center))?
                                    .1;
                                // elastic pull toward the center
                                let w = params_ref.flat_mut();
                                for (wi, ci) in
                                    w.iter_mut().zip(center.iter())
                                {
                                    *wi -= alpha * (*wi - ci);
                                }
                                Ok(())
                            }
                            Tag::Exit => Err(WorkerError::EarlyExit),
                            tag => Err(WorkerError::Protocol(tag)),
                        }
                    };
                    if let Err(e) = ct.time(exchange) {
                        failure = Some(e);
                    }
                }
            });
            match failure {
                Some(WorkerError::EarlyExit) => break,
                Some(e) => return Err(e),
                None => {}
            }
            report.epochs = epoch + 1;
        }
        report.grad_time_s = grad_timer.total_s();
        report.comm_wait_s = comm_timer.total_s();
        self.finish(&report)?;
        Ok(report)
    }
}

/// [`GradSink`] that launches one bucket collective per layer the
/// moment its gradient lands during backprop (`Algo::buckets`). The
/// launched collectives complete later via
/// [`Collective::bucket_finish_sum`]; a failed launch is latched here
/// and surfaced after the gradient step (backprop itself is
/// infallible, so nothing is lost by finishing it).
struct BucketLauncher<'c, 'w> {
    col: &'c mut Collective<'w>,
    /// Global element count of the round's reduce vector
    /// (n_params + piggybacked loss + stop flag).
    total: usize,
    err: Option<crate::mpi::CommError>,
}

impl GradSink for BucketLauncher<'_, '_> {
    fn bucket_ready(&mut self, ready: BucketReady, grads: &[f32]) {
        if self.err.is_some() {
            return;
        }
        let bucket = self.col.pending_buckets();
        if let Err(e) = self.col.bucket_begin(
            bucket, grads, ready.param_range.start,
            ready.param_range.end, self.total)
        {
            self.err = Some(e);
        }
    }
}

/// Result of one rank's all-reduce training run. All ranks finish with
/// bitwise-identical `weights`; `history` is populated on rank 0.
pub struct RingOutcome {
    pub report: WorkerReport,
    pub weights: ParamSet,
    pub history: History,
}

/// One rank of the masterless `Mode::AllReduce` world (every rank runs
/// this — there is no master). Per round: local gradient, ring
/// all-reduce to average gradients (the batch loss and the stop flag
/// piggyback as two extra elements, so a round costs exactly one
/// collective), then an identical replicated optimizer step. With
/// `Algo::buckets`, the single collective becomes one collective per
/// layer bucket, each launched mid-backprop as its layer's gradient
/// lands (`BucketLauncher`) and drained after the step — identical
/// results, communication overlapped with compute (DESIGN.md §Layer
/// DAG & bucketed overlap). Rank 0
/// additionally drives the [`Observer`] (validation schedule +
/// callbacks) and owns the returned [`History`]; when a callback
/// requests a stop, rank 0 raises the flag and every rank abandons the
/// flagged round before applying its update — lockstep, so weights stay
/// bitwise-identical.
pub struct RingWorker<'a> {
    comm: &'a Comm,
    algo: &'a Algo,
    exes: &'a ModelExecutables,
    data: &'a DataSet,
    rng: Rng,
    /// Replicated LR schedule: a pure function of the update count,
    /// applied identically on every rank (callbacks only run on rank 0,
    /// so a stateful master-side schedule would diverge the replicas).
    lr: Option<LrScheduleSpec>,
    /// Grouped topology for the gradient collectives (hierarchical
    /// all-reduce: intra-group ring + inter-group leader tree). `None`
    /// keeps the flat ring.
    groups: Option<GroupLayout>,
    /// Elastic mode: the versioned [`WorldPlan`] this rank replans
    /// from when membership churns. `None` = fixed world (any comm
    /// failure is fatal, the historical behavior).
    elastic_plan: Option<WorldPlan>,
    /// Failure-detection + agreement window (elastic mode only).
    elastic_timeout: Duration,
    /// Re-shards the dataset after a replan: `(member_position,
    /// n_members) -> DataSet`. Without one, survivors keep training
    /// their launch shard (coverage gaps are accepted).
    resharder: Option<&'a ReshardFn>,
    /// Chaos hook: simulate a crash once `update_count` reaches this.
    fault_after: Option<u64>,
}

/// Re-sharding callback: `(member_position, n_members)` over the NEW
/// member list -> that member's dataset. Shared across rank threads by
/// the driver, hence `Sync`.
pub type ReshardFn =
    dyn Fn(usize, usize) -> Result<DataSet, String> + Sync;

/// Give up after this many back-to-back agreement attempts (churn
/// during recovery restarts the agreement; a world this unstable is
/// better off failing loudly).
const MAX_RECOVERY_ATTEMPTS: u32 = 5;

/// A joiner waits this many elastic-timeout windows to be admitted:
/// joins are only folded in at a round boundary or the next churn, so
/// the wait spans training rounds, not one agreement.
const JOIN_WAIT_WINDOWS: u32 = 20;

/// Rank-0 state of the online re-tuner (DESIGN.md §Autotuning): holds
/// the planner's predicted round time against measured windows. A
/// window's cost is the delta of the grad + collective + update timers
/// — exactly the terms the prediction covers, so validation and
/// callback time on the observer can never fake a divergence.
struct RetuneState {
    cfg: RetuneConfig,
    /// grad+comm+update seconds already on the timers at window start.
    window_work_s: f64,
    window_rounds: u64,
    replans_done: u32,
    /// Round time the divergence test compares against: the planner's
    /// prediction at launch, the measured average after a re-plan.
    baseline_s: f64,
    /// Measured average to adopt as the new baseline once the re-plan
    /// this window triggered completes.
    pending_baseline_s: Option<f64>,
    /// The one-shot "cannot re-plan" hint was already logged.
    hinted: bool,
}

impl RetuneState {
    fn new(cfg: RetuneConfig) -> Self {
        RetuneState { cfg, window_work_s: 0.0, window_rounds: 0,
                      replans_done: 0,
                      baseline_s: cfg.predicted_round_s,
                      pending_baseline_s: None, hinted: false }
    }

    /// Restart the measurement window from `work_now_s` on the clocks
    /// (also called after recovery, so an aborted round's timeout wait
    /// never pollutes the next window).
    fn reset_window(&mut self, work_now_s: f64) {
        self.window_work_s = work_now_s;
        self.window_rounds = 0;
    }

    /// Account one finished round; at the window boundary, return the
    /// measured average round time iff it diverged past the trigger
    /// (`baseline * factor * (1 + noise_floor)`).
    fn round_done(&mut self, work_now_s: f64) -> Option<f64> {
        self.window_rounds += 1;
        if self.window_rounds < self.cfg.window {
            return None;
        }
        let avg = (work_now_s - self.window_work_s)
            / self.window_rounds as f64;
        self.reset_window(work_now_s);
        let trigger = self.baseline_s * self.cfg.factor
            * (1.0 + self.cfg.noise_floor);
        (avg > trigger).then_some(avg)
    }
}

impl<'a> RingWorker<'a> {
    pub fn new(comm: &'a Comm, algo: &'a Algo,
               exes: &'a ModelExecutables, data: &'a DataSet, seed: u64,
               lr: Option<LrScheduleSpec>) -> Self {
        Self { comm, algo, exes, data, rng: Rng::new(seed), lr,
               groups: None, elastic_plan: None,
               elastic_timeout: elastic::DEFAULT_ELASTIC_TIMEOUT,
               resharder: None, fault_after: None }
    }

    /// Route the gradient all-reduces through a hierarchical
    /// [`GroupLayout`] (every rank of the world must get the identical
    /// layout). The initial weight broadcast and the round-count
    /// agreement stay on the flat raw ring either way.
    pub fn with_groups(mut self, groups: Option<GroupLayout>) -> Self {
        self.groups = groups;
        self
    }

    /// Enable elastic membership (DESIGN.md §Elasticity): collective
    /// failures trigger the suspect → agree → replan → resume protocol
    /// instead of aborting the job, `timeout` bounds both failure
    /// detection (the collective's neighbor wait) and the agreement
    /// window. A rank that is not a member of `plan` enters as a
    /// JOINER: it waits to be admitted and receives replicated weights.
    pub fn with_elastic(mut self, plan: WorldPlan, timeout: Duration)
        -> Self {
        self.elastic_plan = Some(plan);
        self.elastic_timeout = timeout;
        self
    }

    /// Install the re-sharding callback used after each replan.
    pub fn with_resharder(mut self, f: &'a ReshardFn) -> Self {
        self.resharder = Some(f);
        self
    }

    /// Chaos hook (tests/failure drills): simulate a crash — return
    /// [`WorkerError::FaultInjected`] without stats or wind-down — as
    /// soon as `updates` updates have been applied.
    pub fn with_fault_after(mut self, updates: u64) -> Self {
        self.fault_after = Some(updates);
        self
    }

    /// Train to completion. `init` is consumed on rank 0 and broadcast
    /// to the world; other ranks pass `None`. `observer` is consulted
    /// on rank 0 only (pass `Observer::disabled()` elsewhere).
    ///
    /// In elastic mode ([`RingWorker::with_elastic`]) a failed round
    /// does not kill the job: the survivors agree on a new world,
    /// re-sync weights from the most advanced member, and restart the
    /// interrupted data epoch (the optimizer's momentum is
    /// deterministically reset on every member, so replicas stay
    /// bitwise-identical — DESIGN.md §Elasticity).
    pub fn run(mut self, init: Option<ParamSet>,
               observer: &mut Observer<'_>)
        -> Result<RingOutcome, WorkerError> {
        let n = self.comm.size();
        let rank = self.comm.rank();
        let batch = self.algo.batch_size;
        let started = Instant::now();
        let mut col = Collective::new(self.comm);
        // Wire codec for the gradient collectives. The initial weight
        // broadcast and the round-count agreement below stay raw; the
        // two piggybacked control elements (mean loss, stop flag) are
        // exempt from lossy dropping.
        col.set_codec(self.algo.compression);
        col.set_exact_tail(2);
        // The compute pool behind the model's kernels also partitions
        // the codec pack/unpack and reduce loops — bitwise-identical,
        // the pool never changes accumulation order.
        col.set_pool(self.exes.thread_pool());
        // Grouped topology (hierarchical all-reduce); sum collectives
        // dispatch to ring → tree → ring, control traffic stays flat.
        col.set_groups(self.groups.take());

        let elastic = self.elastic_plan.is_some();
        let mut cur_plan = self.elastic_plan.take();
        if elastic {
            col.set_elastic(true);
            // failure detection latency == the neighbor-wait bound
            col.set_recv_timeout(self.elastic_timeout);
            let p = cur_plan.as_ref().unwrap();
            col.adopt_world(p.epoch(), p.collective_members());
        }
        let fallback = self.data;
        let fault_after = self.fault_after;
        let resharder = self.resharder;
        let mut owned_data: Option<DataSet> = None;

        let mut params = match init {
            Some(p) if rank == 0 => p,
            _ => ParamSet::zeros(&self.exes.meta.params),
        };
        let n_params = params.num_params();
        let mut opt = self.algo.build_master_optimizer(n_params);
        opt.set_pool(self.exes.thread_pool());
        let lr_spec = self.lr;
        let mut history = History::default();
        let mut grad_timer = Stopwatch::new();
        let mut comm_timer = Stopwatch::new();
        let mut update_timer = Stopwatch::new();
        let mut update_count = 0u64;
        let mut last_loss = 0.0f32;
        let mut epochs_done = 0u32;
        let mut epoch = 0u32;
        let mut rounds;
        // Early-stop lockstep: rank 0 raises the flag after its
        // callbacks request a stop; the flagged round is abandoned by
        // every rank before the update, keeping weights identical.
        let mut stop_flag = 0.0f32;
        let mut stopped = false;

        if elastic && !cur_plan.as_ref().unwrap().is_member(rank) {
            // JOINER: this rank is excluded from the launch plan. It
            // announces itself to the coordinator and idles until an
            // agreement admits it (replicated weights arrive via the
            // resume broadcast, so it enters bitwise-identical).
            let world = elastic::request_join(
                &mut col,
                self.elastic_timeout
                    .saturating_mul(JOIN_WAIT_WINDOWS),
            )
            .map_err(WorkerError::Elastic)?;
            let rs = apply_world(
                &mut col, cur_plan.as_ref().unwrap(), &world,
                &mut params, 0, batch, resharder, &mut owned_data,
                fallback)?;
            opt = self.algo.build_master_optimizer(n_params);
            opt.set_pool(self.exes.thread_pool());
            update_count = rs.update_count;
            epoch = rs.epoch;
            rounds = rs.rounds;
            cur_plan = Some(rs.plan);
            log::info!(
                "elastic rank {rank}: joined epoch-{} world of {} \
                 members at update {update_count}",
                world.epoch,
                world.members.len());
        } else {
            // Identical start everywhere: rank 0's init circulates the
            // ring.
            let mut weights_buf = params.flat().to_vec();
            col.broadcast(0, &mut weights_buf)?;
            if rank != 0 {
                params.set_flat(&weights_buf);
            }
            drop(weights_buf);

            // Agree on the common per-epoch round count: the minimum
            // of the ranks' local batch counts. Uneven data divisions
            // would otherwise leave the lockstep collectives waiting
            // forever on a rank that ran out of batches.
            let local_batches = fallback.batches_per_epoch(batch);
            rounds = col
                .allreduce_scalar(local_batches as f32, ReduceOp::Min)?
                as u64;
            if (rounds as usize) < local_batches {
                log::debug!(
                    "allreduce rank {rank}: trimming epoch to {rounds} \
                     common rounds (local {local_batches})"
                );
            }
        }

        // Bucketed overlap: one collective per layer bucket, launched
        // mid-backprop as each layer's gradient lands, plus one tail
        // bucket for the piggybacked loss + stop flag. Requires a tag
        // lane per bucket; a model with more layers than lanes falls
        // back to the monolithic collective.
        let n_buckets = params.layer_ranges().len() + 1;
        let bucket_lanes_ok = n_buckets <= tags::MAX_BUCKETS as usize;
        let mut n_live = col.n_ranks();
        let mut use_buckets =
            self.algo.buckets && n_live > 1 && bucket_lanes_ok;
        if self.algo.buckets && !bucket_lanes_ok && n_live > 1
            && rank == 0
        {
            log::warn!(
                "allreduce: {n_buckets} buckets exceed the \
                 {} tag lanes; using the monolithic all-reduce",
                tags::MAX_BUCKETS
            );
        }
        let mut inv_n = 1.0 / n_live as f32;

        let exes = self.exes;
        let algo = self.algo;

        // Online re-tuner (auto mode): rank 0 holds measured windows
        // against the planner's predicted round time and triggers a
        // bounded re-plan through the elastic path on divergence.
        let mut retune = if rank == 0 {
            algo.retune.map(RetuneState::new)
        } else {
            None
        };

        while epoch < algo.epochs {
            let mut erng = self.rng.fork(epoch as u64);
            let mut done_rounds = 0u64;
            let mut failure: Option<WorkerError> = None;
            {
                let data: &DataSet =
                    owned_data.as_ref().unwrap_or(fallback);
                data.for_each_batch(batch, &mut erng, |x, y| {
                    if failure.is_some() || stopped
                        || done_rounds >= rounds {
                        return;
                    }
                    if fault_after.map_or(false, |f| update_count >= f)
                    {
                        failure = Some(WorkerError::FaultInjected);
                        return;
                    }
                    if elastic && rank == 0 {
                        // Scale-up entry: fold pending joiners in at a
                        // round boundary by aborting into the same
                        // agreement path a failure takes. The drained
                        // requests go back into the stash so the
                        // coordinator sees them.
                        let joiners = col.pending_joiners();
                        if !joiners.is_empty() {
                            for &r in &joiners {
                                col.stash_mut().push(Envelope {
                                    src: r,
                                    tag: Tag::ElasticJoin,
                                    payload: Payload::Empty,
                                });
                            }
                            failure = Some(WorkerError::Comm(
                                CommError::Interrupted(format!(
                                    "join request from ranks \
                                     {joiners:?}"))));
                            return;
                        }
                    }
                    // Bucketed mode starts each layer's collective
                    // inside the gradient step (that launch time IS
                    // the overlap, so it stays on the grad timer); the
                    // monolithic path computes the whole gradient
                    // first.
                    let (step, sink_err) = grad_timer.time(|| {
                        if use_buckets {
                            let mut sink = BucketLauncher {
                                col: &mut col,
                                total: n_params + 2,
                                err: None,
                            };
                            let res = exes.grad_step_overlapped(
                                &params, x, y, &mut sink);
                            (res, sink.err)
                        } else {
                            (exes.grad_step(&params, x, y), None)
                        }
                    });
                    let out = match (step, sink_err) {
                        (Ok(o), None) => o,
                        (Err(e), _) => {
                            failure = Some(e.into());
                            return;
                        }
                        (_, Some(e)) => {
                            failure = Some(e.into());
                            return;
                        }
                    };
                    last_loss = out.loss;
                    // average gradients world-wide; the local loss and
                    // the stop flag ride along as two extra elements
                    // (grad_step allocates the buffer with spare
                    // slots, so these pushes never reallocate the
                    // gradient on the hot path)
                    let mut reduced = out.grads;
                    reduced.push(out.loss);
                    reduced.push(stop_flag);
                    let comm_result = comm_timer.time(|| {
                        if use_buckets {
                            // tail bucket (loss + stop flag), then
                            // drain every in-flight bucket in launch
                            // order
                            let tail = col.pending_buckets();
                            col.bucket_begin(tail, &reduced, n_params,
                                             n_params + 2,
                                             n_params + 2)?;
                            col.bucket_finish_sum(&mut reduced)
                        } else {
                            col.allreduce(&mut reduced, ReduceOp::Sum)
                        }
                    });
                    if let Err(e) = comm_result {
                        failure = Some(e.into());
                        return;
                    }
                    if reduced[n_params + 1] > 0.0 {
                        // someone (rank 0) requested a stop before
                        // this round: abandon it pre-update on every
                        // rank
                        stopped = true;
                        return;
                    }
                    for v in reduced.iter_mut().take(n_params + 1) {
                        *v *= inv_n;
                    }
                    let mean_loss = reduced[n_params];
                    if let Some(spec) = lr_spec {
                        opt.set_lr_scale(
                            spec.scale_for_update(update_count + 1));
                    }
                    update_timer.start();
                    opt.update(params.flat_mut(),
                               &reduced[..n_params]);
                    update_timer.stop();
                    update_count += 1;
                    done_rounds += 1;
                    if rank == 0 {
                        observer.after_update(
                            update_count, mean_loss, &params,
                            started.elapsed().as_secs_f64(),
                            &mut history);
                        if observer.should_stop() {
                            stop_flag = 1.0;
                        }
                        if let Some(rt) = retune.as_mut() {
                            let work = grad_timer.total_s()
                                + comm_timer.total_s()
                                + update_timer.total_s();
                            if let Some(measured) = rt.round_done(work)
                            {
                                if elastic && rt.replans_done
                                    < rt.cfg.max_replans
                                {
                                    rt.replans_done += 1;
                                    rt.pending_baseline_s =
                                        Some(measured);
                                    log::warn!(
                                        "[retune] measured {measured:.3e}\
                                         s/round vs predicted {:.3e}s \
                                         (trigger x{:.2}); re-planning \
                                         ({}/{} used)",
                                        rt.baseline_s, rt.cfg.factor,
                                        rt.replans_done,
                                        rt.cfg.max_replans);
                                    // same latch the joiner fold-in
                                    // uses: abort into the agreement
                                    // path at the round boundary
                                    failure = Some(WorkerError::Comm(
                                        CommError::Interrupted(
                                            format!(
                                                "retune: measured \
                                                 {measured:.3e}s/round \
                                                 diverged from \
                                                 predicted {:.3e}s",
                                                rt.baseline_s))));
                                    return;
                                }
                                if !rt.hinted {
                                    rt.hinted = true;
                                    log::warn!(
                                        "[retune] measured {measured:.3e}\
                                         s/round vs predicted {:.3e}s — \
                                         {}; pin a topology or relaunch \
                                         with --auto (docs/RUNBOOK.md)",
                                        rt.baseline_s,
                                        if elastic {
                                            "re-plan budget exhausted"
                                        } else {
                                            "--elastic is off, cannot \
                                             re-plan in place"
                                        });
                                }
                            }
                        }
                    }
                });
            }
            match failure {
                None => {
                    if stopped {
                        break;
                    }
                    epochs_done = epoch + 1;
                    epoch += 1;
                }
                Some(e) if elastic && recoverable(&e) => {
                    // suspect → agree → replan → resume. Churn DURING
                    // recovery restarts the agreement from the newer
                    // generation, up to the attempt cap.
                    let mut err = e;
                    let mut attempt = 0u32;
                    loop {
                        attempt += 1;
                        if attempt > MAX_RECOVERY_ATTEMPTS {
                            return Err(err);
                        }
                        log::warn!(
                            "elastic rank {rank}: round aborted \
                             ({err}); membership agreement, attempt \
                             {attempt}/{MAX_RECOVERY_ATTEMPTS}"
                        );
                        // Interrupted = a control message told us (the
                        // coordinator already knows); anything else we
                        // detected ourselves and must announce.
                        let announce = !matches!(
                            &err,
                            WorkerError::Comm(
                                CommError::Interrupted(_)));
                        let outcome = if rank == 0 {
                            elastic::coordinate(
                                &mut col, cur_plan.as_ref().unwrap(),
                                update_count, self.elastic_timeout)
                                .map(MemberOutcome::Continue)
                        } else {
                            elastic::await_plan(
                                &mut col, update_count,
                                self.elastic_timeout, announce)
                        };
                        let world = match outcome {
                            Ok(MemberOutcome::Continue(w)) => w,
                            Ok(MemberOutcome::Evicted) => {
                                log::warn!(
                                    "elastic rank {rank}: evicted \
                                     from the new world; exiting \
                                     cleanly");
                                return Ok(RingOutcome {
                                    report: WorkerReport {
                                        rank,
                                        epochs: epochs_done,
                                        batches: update_count,
                                        samples: update_count
                                            * batch as u64,
                                        last_train_loss: last_loss,
                                        grad_time_s:
                                            grad_timer.total_s(),
                                        comm_wait_s:
                                            comm_timer.total_s(),
                                    },
                                    weights: params,
                                    history: History::default(),
                                });
                            }
                            Err(msg) => {
                                return Err(WorkerError::Elastic(msg));
                            }
                        };
                        match apply_world(
                            &mut col, cur_plan.as_ref().unwrap(),
                            &world, &mut params, epoch, batch,
                            resharder, &mut owned_data, fallback)
                        {
                            Ok(rs) => {
                                // momentum deterministically reset on
                                // EVERY member — replica-identical
                                opt = algo
                                    .build_master_optimizer(n_params);
                                opt.set_pool(exes.thread_pool());
                                update_count = rs.update_count;
                                epoch = rs.epoch;
                                rounds = rs.rounds;
                                n_live = rs.n;
                                inv_n = 1.0 / n_live as f32;
                                use_buckets = algo.buckets
                                    && n_live > 1 && bucket_lanes_ok;
                                cur_plan = Some(rs.plan);
                                log::info!(
                                    "elastic rank {rank}: resumed \
                                     epoch {epoch} at update \
                                     {update_count} in a {n_live}\
                                     -member world");
                                if let Some(rt) = retune.as_mut() {
                                    if let Some(b) =
                                        rt.pending_baseline_s.take()
                                    {
                                        rt.baseline_s = b;
                                        log::info!(
                                            "[retune] adopted measured \
                                             {b:.3e}s/round as the new \
                                             baseline");
                                    }
                                    rt.reset_window(
                                        grad_timer.total_s()
                                        + comm_timer.total_s()
                                        + update_timer.total_s());
                                }
                                break;
                            }
                            Err(e2) if recoverable(&e2) => err = e2,
                            Err(e2) => return Err(e2),
                        }
                    }
                }
                Some(e) => return Err(e),
            }
        }

        let report = WorkerReport {
            rank,
            epochs: epochs_done,
            batches: update_count,
            samples: update_count * batch as u64,
            last_train_loss: last_loss,
            grad_time_s: grad_timer.total_s(),
            comm_wait_s: comm_timer.total_s(),
        };

        if rank != 0 {
            self.comm.send(
                0,
                Tag::TrainStats,
                Payload::Stats(WorkerStats {
                    epoch: report.epochs,
                    batches_done: report.batches,
                    samples_done: report.samples,
                    train_loss: report.last_train_loss,
                    grad_time_s: report.grad_time_s,
                    comm_wait_s: report.comm_wait_s,
                }),
            )?;
            return Ok(RingOutcome {
                report,
                weights: params,
                history: History::default(),
            });
        }

        // Rank 0 wind-down: collect every peer's stats. Some may have
        // been stashed by the final collectives (a faster rank finishes
        // its last all-gather — and reports — before rank 0 does). In
        // elastic mode only the FINAL generation's members report, and
        // the collection is timeout-bounded so a peer dying during
        // wind-down cannot hang the job (its stats are simply missing
        // from the history).
        let peers: Vec<Rank> = match col.members() {
            Some(m) => m.iter().copied().filter(|&r| r != 0).collect(),
            None => (1..n).collect(),
        };
        let mut stash = col.into_stash();
        history.workers.push(report.clone());
        let stats_deadline =
            Instant::now() + self.elastic_timeout.saturating_mul(2);
        for _ in 0..peers.len() {
            let env = if elastic {
                match recv_tag_deadline(self.comm, Tag::TrainStats,
                                        &mut stash, stats_deadline) {
                    Some(env) => env,
                    None => {
                        log::warn!(
                            "elastic wind-down: missing TrainStats \
                             from some of {peers:?}; history is \
                             incomplete");
                        break;
                    }
                }
            } else {
                self.comm.recv_tag(Tag::TrainStats, &mut stash)?
            };
            if let Payload::Stats(s) = env.payload {
                history.workers.push(WorkerReport {
                    rank: env.src,
                    epochs: s.epoch,
                    batches: s.batches_done,
                    samples: s.samples_done,
                    last_train_loss: s.train_loss,
                    grad_time_s: s.grad_time_s,
                    comm_wait_s: s.comm_wait_s,
                });
            }
        }
        history.master_updates = update_count;
        history.master_update_time_s = update_timer.total_s();
        history.wallclock_s = started.elapsed().as_secs_f64();
        // final validation (every run ends with a measurement) + the
        // callbacks' on_train_end
        observer.finish(update_count, &params,
                        started.elapsed().as_secs_f64(), &mut history);
        Ok(RingOutcome { report, weights: params, history })
    }
}

/// Post-agreement state every member installs identically.
struct ResumeState {
    plan: WorldPlan,
    /// Data epoch training restarts from (the max across members — the
    /// interrupted epoch is replayed from its first round).
    epoch: u32,
    rounds: u64,
    update_count: u64,
    n: usize,
}

/// Can this error trigger the elastic recovery path (vs. a local bug
/// that must abort)?
fn recoverable(e: &WorkerError) -> bool {
    matches!(
        e,
        WorkerError::Comm(
            CommError::Interrupted(_)
            | CommError::Timeout(_)
            | CommError::SendFailed(_)))
}

/// The identical resume sequence every member of an agreed [`NewWorld`]
/// runs (DESIGN.md §Elasticity): adopt the plan (purging stale
/// generations and discarding the error-feedback residual), re-sync
/// weights from the sync root, Max-agree the data epoch to restart,
/// re-shard, and Min-agree the new common round count.
#[allow(clippy::too_many_arguments)]
fn apply_world(col: &mut Collective, base: &WorldPlan,
               world: &NewWorld, params: &mut ParamSet, my_epoch: u32,
               batch: usize, resharder: Option<&ReshardFn>,
               owned_data: &mut Option<DataSet>, fallback: &DataSet)
    -> Result<ResumeState, WorkerError> {
    let plan = base.with_members(world.epoch, world.members.clone());
    col.adopt_world(world.epoch, plan.collective_members());
    col.set_groups(plan.ring_layout());
    // bitwise-identical restart: the most advanced survivor's weights
    // replace everyone's
    let mut buf = params.flat().to_vec();
    col.broadcast(world.sync_root, &mut buf)?;
    params.set_flat(&buf);
    drop(buf);
    // members may sit one epoch apart (a failure at an epoch boundary);
    // joiners enter at 0 — everyone restarts the max
    let epoch =
        col.allreduce_scalar(my_epoch as f32, ReduceOp::Max)? as u32;
    if let Some(f) = resharder {
        let m = world.members.len();
        let pos = world
            .members
            .iter()
            .position(|&r| r == col.comm().rank())
            .expect("apply_world runs on members only");
        *owned_data = Some(f(pos, m).map_err(WorkerError::Elastic)?);
    }
    let local = owned_data
        .as_ref()
        .unwrap_or(fallback)
        .batches_per_epoch(batch);
    let rounds =
        col.allreduce_scalar(local as f32, ReduceOp::Min)? as u64;
    Ok(ResumeState {
        plan,
        epoch,
        rounds,
        update_count: world.resume_update,
        n: world.members.len(),
    })
}

/// Deadline-bounded [`Comm::recv_tag`]: `None` on timeout instead of
/// blocking forever on a peer that died during wind-down.
fn recv_tag_deadline(comm: &Comm, want: Tag,
                     stash: &mut Vec<Envelope>,
                     deadline: Instant) -> Option<Envelope> {
    if let Some(i) = stash.iter().position(|e| e.tag == want) {
        return Some(stash.remove(i));
    }
    loop {
        let now = Instant::now();
        if now >= deadline {
            return None;
        }
        match comm.recv_timeout(deadline - now) {
            Ok(env) if env.tag == want => return Some(env),
            Ok(env) => stash.push(env),
            Err(_) => return None,
        }
    }
}
