//! The worker process: read a batch, compute the gradient, trade it for
//! fresh weights (Downpour), or train locally and exchange elastically
//! (EASGD). Paper §III-A.

use crate::coordinator::algo::{Algo, Mode};
use crate::data::DataSet;
use crate::metrics::{Stopwatch, WorkerReport};
use crate::mpi::{Comm, Payload, Rank, Tag, WorkerStats};
use crate::runtime::ModelExecutables;
use crate::tensor::ParamSet;
use crate::util::rng::Rng;

/// Worker configuration + state.
pub struct Worker<'a> {
    comm: &'a Comm,
    master: Rank,
    algo: &'a Algo,
    exes: &'a ModelExecutables,
    data: &'a DataSet,
    rng: Rng,
}

#[derive(Debug, thiserror::Error)]
pub enum WorkerError {
    #[error("runtime: {0}")]
    Runtime(#[from] crate::runtime::RuntimeError),
    #[error("comm: {0}")]
    Comm(#[from] crate::mpi::CommError),
    #[error("master sent unexpected tag {0:?}")]
    Protocol(Tag),
    #[error("master told us to exit early")]
    EarlyExit,
}

impl<'a> Worker<'a> {
    pub fn new(comm: &'a Comm, master: Rank, algo: &'a Algo,
               exes: &'a ModelExecutables, data: &'a DataSet, seed: u64)
        -> Self {
        Self { comm, master, algo, exes, data, rng: Rng::new(seed) }
    }

    /// Announce readiness and receive the initial weights.
    fn handshake(&mut self, params: &mut ParamSet)
        -> Result<u64, WorkerError> {
        self.comm.send(self.master, Tag::Ready, Payload::Empty)?;
        let env = self.comm.recv()?;
        match (env.tag, env.payload) {
            (Tag::Weights, Payload::Floats { step, data }) => {
                params.set_flat(&data);
                Ok(step)
            }
            (Tag::Exit, _) => Err(WorkerError::EarlyExit),
            (tag, _) => Err(WorkerError::Protocol(tag)),
        }
    }

    /// Run the configured number of epochs; returns the final report.
    pub fn run(mut self) -> Result<WorkerReport, WorkerError> {
        let mut params = ParamSet::zeros(&self.exes.meta.params);
        let step0 = self.handshake(&mut params)?;
        match self.algo.mode.clone() {
            Mode::Downpour { .. } => self.run_downpour(params, step0),
            Mode::Easgd { tau, alpha, worker_optimizer } => {
                self.run_easgd(params, tau, alpha, &worker_optimizer)
            }
        }
    }

    fn finish(&self, report: &WorkerReport) -> Result<(), WorkerError> {
        self.comm.send(
            self.master,
            Tag::TrainStats,
            Payload::Stats(WorkerStats {
                epoch: report.epochs,
                batches_done: report.batches,
                samples_done: report.samples,
                train_loss: report.last_train_loss,
                grad_time_s: report.grad_time_s,
                comm_wait_s: report.comm_wait_s,
            }),
        )?;
        self.comm.send(self.master, Tag::Exit, Payload::Empty)?;
        Ok(())
    }

    fn run_downpour(&mut self, mut params: ParamSet, step0: u64)
        -> Result<WorkerReport, WorkerError> {
        let batch = self.algo.batch_size;
        let mut report = WorkerReport {
            rank: self.comm.rank(),
            ..Default::default()
        };
        let mut grad_timer = Stopwatch::new();
        let mut comm_timer = Stopwatch::new();
        let mut model_step = step0;
        for epoch in 0..self.algo.epochs {
            let mut rng = self.rng.fork(epoch as u64);
            let mut failure: Option<WorkerError> = None;
            // buffers move through the closure; results come back via refs
            let params_ref = &mut params;
            let step_ref = &mut model_step;
            let report_ref = &mut report;
            let gt = &mut grad_timer;
            let ct = &mut comm_timer;
            self.data.for_each_batch(batch, &mut rng, |x, y| {
                if failure.is_some() {
                    return;
                }
                let out = match gt.time(|| self.exes.grad_step(
                    params_ref, x, y)) {
                    Ok(o) => o,
                    Err(e) => {
                        failure = Some(e.into());
                        return;
                    }
                };
                report_ref.last_train_loss = out.loss;
                let send_recv = || -> Result<(), WorkerError> {
                    self.comm.send(
                        self.master,
                        Tag::Gradients,
                        Payload::grad(*step_ref, out.loss, out.grads),
                    )?;
                    let env = self.comm.recv()?;
                    match (env.tag, env.payload) {
                        (Tag::Weights, Payload::Floats { step, data }) => {
                            params_ref.set_flat(&data);
                            *step_ref = step;
                            Ok(())
                        }
                        (Tag::Exit, _) => Err(WorkerError::EarlyExit),
                        (tag, _) => Err(WorkerError::Protocol(tag)),
                    }
                };
                if let Err(e) = ct.time(send_recv) {
                    failure = Some(e);
                    return;
                }
                report_ref.batches += 1;
                report_ref.samples += batch as u64;
            });
            match failure {
                Some(WorkerError::EarlyExit) => break,
                Some(e) => return Err(e),
                None => {}
            }
            report.epochs = epoch + 1;
            log::debug!("epoch {} done, loss={:.4}", epoch + 1,
                        report.last_train_loss);
        }
        report.grad_time_s = grad_timer.total_s();
        report.comm_wait_s = comm_timer.total_s();
        self.finish(&report)?;
        Ok(report)
    }

    fn run_easgd(&mut self, mut params: ParamSet, tau: u32, alpha: f32,
                 worker_opt: &crate::optim::OptimizerConfig)
        -> Result<WorkerReport, WorkerError> {
        let batch = self.algo.batch_size;
        let mut opt = worker_opt.build(params.num_params());
        let mut report = WorkerReport {
            rank: self.comm.rank(),
            ..Default::default()
        };
        let mut grad_timer = Stopwatch::new();
        let mut comm_timer = Stopwatch::new();
        let mut since_exchange = 0u32;
        for epoch in 0..self.algo.epochs {
            let mut rng = self.rng.fork(epoch as u64);
            let mut failure: Option<WorkerError> = None;
            let params_ref = &mut params;
            let report_ref = &mut report;
            let opt_ref = &mut opt;
            let since_ref = &mut since_exchange;
            let gt = &mut grad_timer;
            let ct = &mut comm_timer;
            self.data.for_each_batch(batch, &mut rng, |x, y| {
                if failure.is_some() {
                    return;
                }
                let out = match gt.time(|| self.exes.grad_step(
                    params_ref, x, y)) {
                    Ok(o) => o,
                    Err(e) => {
                        failure = Some(e.into());
                        return;
                    }
                };
                report_ref.last_train_loss = out.loss;
                // local update — workers explore independently
                opt_ref.update(params_ref.flat_mut(), &out.grads);
                report_ref.batches += 1;
                report_ref.samples += batch as u64;
                *since_ref += 1;
                if *since_ref >= tau {
                    *since_ref = 0;
                    let exchange = || -> Result<(), WorkerError> {
                        self.comm.send(
                            self.master,
                            Tag::ExchangeWeights,
                            Payload::floats(report_ref.batches,
                                            params_ref.flat().to_vec()),
                        )?;
                        let env = self.comm.recv()?;
                        match (env.tag, env.payload) {
                            (Tag::Center,
                             Payload::Floats { data: center, .. }) => {
                                // elastic pull toward the center
                                let w = params_ref.flat_mut();
                                for (wi, ci) in w.iter_mut().zip(center.iter()) {
                                    *wi -= alpha * (*wi - ci);
                                }
                                Ok(())
                            }
                            (Tag::Exit, _) => Err(WorkerError::EarlyExit),
                            (tag, _) => Err(WorkerError::Protocol(tag)),
                        }
                    };
                    if let Err(e) = ct.time(exchange) {
                        failure = Some(e);
                    }
                }
            });
            match failure {
                Some(WorkerError::EarlyExit) => break,
                Some(e) => return Err(e),
                None => {}
            }
            report.epochs = epoch + 1;
        }
        report.grad_time_s = grad_timer.total_s();
        report.comm_wait_s = comm_timer.total_s();
        self.finish(&report)?;
        Ok(report)
    }
}
