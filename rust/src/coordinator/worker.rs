//! The worker process: read a batch, compute the gradient, trade it for
//! fresh weights (Downpour), or train locally and exchange elastically
//! (EASGD). Paper §III-A.
//!
//! Also home to [`RingWorker`], the masterless peer of `Mode::AllReduce`:
//! every rank trains, the world averages gradients with a chunked ring
//! all-reduce, and each rank applies an identical replicated optimizer
//! step — so all ranks hold bitwise-identical weights at every round.
//! Rank 0 doubles as the *observer* (validation + callbacks); an early
//! stop piggybacks as one extra element on the next collective, so every
//! rank breaks in lockstep with identical weights.

use std::time::Instant;

use crate::coordinator::algo::{Algo, Mode};
use crate::coordinator::callbacks::{LrScheduleSpec, Observer};
use crate::data::DataSet;
use crate::metrics::{History, Stopwatch, WorkerReport};
use crate::mpi::codec::{grad_payload, Compressor};
use crate::mpi::collective::{Collective, GroupLayout, ReduceOp};
use crate::mpi::{tags, Comm, Payload, Rank, Tag, WorkerStats};
use crate::runtime::{BucketReady, GradSink, ModelExecutables};
use crate::tensor::ParamSet;
use crate::util::rng::Rng;

/// Worker configuration + state.
pub struct Worker<'a> {
    comm: &'a Comm,
    master: Rank,
    algo: &'a Algo,
    exes: &'a ModelExecutables,
    data: &'a DataSet,
    rng: Rng,
}

#[derive(Debug)]
pub enum WorkerError {
    Runtime(crate::runtime::RuntimeError),
    Comm(crate::mpi::CommError),
    Protocol(Tag),
    EarlyExit,
    Unsupported(&'static str),
}

impl std::fmt::Display for WorkerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WorkerError::Runtime(e) => write!(f, "runtime: {e}"),
            WorkerError::Comm(e) => write!(f, "comm: {e}"),
            WorkerError::Protocol(tag) => {
                write!(f, "master sent unexpected tag {tag:?}")
            }
            WorkerError::EarlyExit => {
                write!(f, "master told us to exit early")
            }
            WorkerError::Unsupported(msg) => {
                write!(f, "unsupported: {msg}")
            }
        }
    }
}

impl std::error::Error for WorkerError {}

impl From<crate::runtime::RuntimeError> for WorkerError {
    fn from(e: crate::runtime::RuntimeError) -> Self {
        WorkerError::Runtime(e)
    }
}

impl From<crate::mpi::CommError> for WorkerError {
    fn from(e: crate::mpi::CommError) -> Self {
        WorkerError::Comm(e)
    }
}

impl<'a> Worker<'a> {
    pub fn new(comm: &'a Comm, master: Rank, algo: &'a Algo,
               exes: &'a ModelExecutables, data: &'a DataSet, seed: u64)
        -> Self {
        Self { comm, master, algo, exes, data, rng: Rng::new(seed) }
    }

    /// Announce readiness and receive the initial weights (raw or
    /// fp16-packed, per the master's codec).
    fn handshake(&mut self, params: &mut ParamSet)
        -> Result<u64, WorkerError> {
        self.comm.send(self.master, Tag::Ready, Payload::Empty)?;
        let env = self.comm.recv()?;
        match env.tag {
            Tag::Weights => match env.payload.weights_like() {
                Some((step, data)) => {
                    params.set_flat(&data);
                    Ok(step)
                }
                None => Err(WorkerError::Protocol(Tag::Weights)),
            },
            Tag::Exit => Err(WorkerError::EarlyExit),
            tag => Err(WorkerError::Protocol(tag)),
        }
    }

    /// Run the configured number of epochs; returns the final report.
    pub fn run(mut self) -> Result<WorkerReport, WorkerError> {
        let mut params = ParamSet::zeros(&self.exes.meta.params);
        let step0 = match self.handshake(&mut params) {
            Ok(step0) => step0,
            Err(WorkerError::EarlyExit) => {
                // an early-stopping master may wind the world down
                // before we ever trained: report zero work and leave
                // cleanly so the master's Exit count completes
                let report = WorkerReport {
                    rank: self.comm.rank(),
                    ..Default::default()
                };
                self.finish(&report)?;
                return Ok(report);
            }
            Err(e) => return Err(e),
        };
        match self.algo.mode.clone() {
            Mode::Downpour { .. } => self.run_downpour(params, step0),
            Mode::Easgd { tau, alpha, worker_optimizer } => {
                self.run_easgd(params, tau, alpha, &worker_optimizer)
            }
            Mode::AllReduce => Err(WorkerError::Unsupported(
                "Mode::AllReduce has no master/worker roles; the driver \
                 runs RingWorker on every rank instead",
            )),
        }
    }

    fn finish(&self, report: &WorkerReport) -> Result<(), WorkerError> {
        self.comm.send(
            self.master,
            Tag::TrainStats,
            Payload::Stats(WorkerStats {
                epoch: report.epochs,
                batches_done: report.batches,
                samples_done: report.samples,
                train_loss: report.last_train_loss,
                grad_time_s: report.grad_time_s,
                comm_wait_s: report.comm_wait_s,
            }),
        )?;
        self.comm.send(self.master, Tag::Exit, Payload::Empty)?;
        Ok(())
    }

    fn run_downpour(&mut self, mut params: ParamSet, step0: u64)
        -> Result<WorkerReport, WorkerError> {
        let batch = self.algo.batch_size;
        let mut report = WorkerReport {
            rank: self.comm.rank(),
            ..Default::default()
        };
        let mut grad_timer = Stopwatch::new();
        let mut comm_timer = Stopwatch::new();
        let mut model_step = step0;
        // Gradient-uplink codec state: the error-feedback residual
        // persists across batches AND epochs (dropped mass is delayed,
        // never lost).
        let mut compressor = Compressor::new(self.algo.compression);
        for epoch in 0..self.algo.epochs {
            let mut rng = self.rng.fork(epoch as u64);
            let mut failure: Option<WorkerError> = None;
            // buffers move through the closure; results come back via refs
            let params_ref = &mut params;
            let step_ref = &mut model_step;
            let report_ref = &mut report;
            let gt = &mut grad_timer;
            let ct = &mut comm_timer;
            let comp = &mut compressor;
            self.data.for_each_batch(batch, &mut rng, |x, y| {
                if failure.is_some() {
                    return;
                }
                let out = match gt.time(|| self.exes.grad_step(
                    params_ref, x, y)) {
                    Ok(o) => o,
                    Err(e) => {
                        failure = Some(e.into());
                        return;
                    }
                };
                report_ref.last_train_loss = out.loss;
                let send_recv = || -> Result<(), WorkerError> {
                    self.comm.send(
                        self.master,
                        Tag::Gradients,
                        grad_payload(comp, *step_ref, out.loss,
                                     out.grads),
                    )?;
                    let env = self.comm.recv()?;
                    match env.tag {
                        Tag::Weights => {
                            match env.payload.weights_like() {
                                Some((step, data)) => {
                                    params_ref.set_flat(&data);
                                    *step_ref = step;
                                    Ok(())
                                }
                                None => Err(WorkerError::Protocol(
                                    Tag::Weights)),
                            }
                        }
                        Tag::Exit => Err(WorkerError::EarlyExit),
                        tag => Err(WorkerError::Protocol(tag)),
                    }
                };
                if let Err(e) = ct.time(send_recv) {
                    failure = Some(e);
                    return;
                }
                report_ref.batches += 1;
                report_ref.samples += batch as u64;
            });
            match failure {
                Some(WorkerError::EarlyExit) => break,
                Some(e) => return Err(e),
                None => {}
            }
            report.epochs = epoch + 1;
            log::debug!("epoch {} done, loss={:.4}", epoch + 1,
                        report.last_train_loss);
        }
        report.grad_time_s = grad_timer.total_s();
        report.comm_wait_s = comm_timer.total_s();
        self.finish(&report)?;
        Ok(report)
    }

    fn run_easgd(&mut self, mut params: ParamSet, tau: u32, alpha: f32,
                 worker_opt: &crate::optim::OptimizerConfig)
        -> Result<WorkerReport, WorkerError> {
        let batch = self.algo.batch_size;
        let mut opt = worker_opt.build(params.num_params());
        let mut report = WorkerReport {
            rank: self.comm.rank(),
            ..Default::default()
        };
        let mut grad_timer = Stopwatch::new();
        let mut comm_timer = Stopwatch::new();
        let mut since_exchange = 0u32;
        for epoch in 0..self.algo.epochs {
            let mut rng = self.rng.fork(epoch as u64);
            let mut failure: Option<WorkerError> = None;
            let params_ref = &mut params;
            let report_ref = &mut report;
            let opt_ref = &mut opt;
            let since_ref = &mut since_exchange;
            let gt = &mut grad_timer;
            let ct = &mut comm_timer;
            self.data.for_each_batch(batch, &mut rng, |x, y| {
                if failure.is_some() {
                    return;
                }
                let out = match gt.time(|| self.exes.grad_step(
                    params_ref, x, y)) {
                    Ok(o) => o,
                    Err(e) => {
                        failure = Some(e.into());
                        return;
                    }
                };
                report_ref.last_train_loss = out.loss;
                // local update — workers explore independently
                opt_ref.update(params_ref.flat_mut(), &out.grads);
                report_ref.batches += 1;
                report_ref.samples += batch as u64;
                *since_ref += 1;
                if *since_ref >= tau {
                    *since_ref = 0;
                    let exchange = || -> Result<(), WorkerError> {
                        // weight exchange is a replication hop: fp16
                        // compresses it, top-k never does
                        self.comm.send(
                            self.master,
                            Tag::ExchangeWeights,
                            self.algo.compression.weights_payload(
                                report_ref.batches,
                                params_ref.flat()),
                        )?;
                        let env = self.comm.recv()?;
                        match env.tag {
                            Tag::Center => {
                                let center = env
                                    .payload
                                    .weights_like()
                                    .ok_or(WorkerError::Protocol(
                                        Tag::Center))?
                                    .1;
                                // elastic pull toward the center
                                let w = params_ref.flat_mut();
                                for (wi, ci) in
                                    w.iter_mut().zip(center.iter())
                                {
                                    *wi -= alpha * (*wi - ci);
                                }
                                Ok(())
                            }
                            Tag::Exit => Err(WorkerError::EarlyExit),
                            tag => Err(WorkerError::Protocol(tag)),
                        }
                    };
                    if let Err(e) = ct.time(exchange) {
                        failure = Some(e);
                    }
                }
            });
            match failure {
                Some(WorkerError::EarlyExit) => break,
                Some(e) => return Err(e),
                None => {}
            }
            report.epochs = epoch + 1;
        }
        report.grad_time_s = grad_timer.total_s();
        report.comm_wait_s = comm_timer.total_s();
        self.finish(&report)?;
        Ok(report)
    }
}

/// [`GradSink`] that launches one bucket collective per layer the
/// moment its gradient lands during backprop (`Algo::buckets`). The
/// launched collectives complete later via
/// [`Collective::bucket_finish_sum`]; a failed launch is latched here
/// and surfaced after the gradient step (backprop itself is
/// infallible, so nothing is lost by finishing it).
struct BucketLauncher<'c, 'w> {
    col: &'c mut Collective<'w>,
    /// Global element count of the round's reduce vector
    /// (n_params + piggybacked loss + stop flag).
    total: usize,
    err: Option<crate::mpi::CommError>,
}

impl GradSink for BucketLauncher<'_, '_> {
    fn bucket_ready(&mut self, ready: BucketReady, grads: &[f32]) {
        if self.err.is_some() {
            return;
        }
        let bucket = self.col.pending_buckets();
        if let Err(e) = self.col.bucket_begin(
            bucket, grads, ready.param_range.start,
            ready.param_range.end, self.total)
        {
            self.err = Some(e);
        }
    }
}

/// Result of one rank's all-reduce training run. All ranks finish with
/// bitwise-identical `weights`; `history` is populated on rank 0.
pub struct RingOutcome {
    pub report: WorkerReport,
    pub weights: ParamSet,
    pub history: History,
}

/// One rank of the masterless `Mode::AllReduce` world (every rank runs
/// this — there is no master). Per round: local gradient, ring
/// all-reduce to average gradients (the batch loss and the stop flag
/// piggyback as two extra elements, so a round costs exactly one
/// collective), then an identical replicated optimizer step. With
/// `Algo::buckets`, the single collective becomes one collective per
/// layer bucket, each launched mid-backprop as its layer's gradient
/// lands ([`BucketLauncher`]) and drained after the step — identical
/// results, communication overlapped with compute (DESIGN.md §Layer
/// DAG & bucketed overlap). Rank 0
/// additionally drives the [`Observer`] (validation schedule +
/// callbacks) and owns the returned [`History`]; when a callback
/// requests a stop, rank 0 raises the flag and every rank abandons the
/// flagged round before applying its update — lockstep, so weights stay
/// bitwise-identical.
pub struct RingWorker<'a> {
    comm: &'a Comm,
    algo: &'a Algo,
    exes: &'a ModelExecutables,
    data: &'a DataSet,
    rng: Rng,
    /// Replicated LR schedule: a pure function of the update count,
    /// applied identically on every rank (callbacks only run on rank 0,
    /// so a stateful master-side schedule would diverge the replicas).
    lr: Option<LrScheduleSpec>,
    /// Grouped topology for the gradient collectives (hierarchical
    /// all-reduce: intra-group ring + inter-group leader tree). `None`
    /// keeps the flat ring.
    groups: Option<GroupLayout>,
}

impl<'a> RingWorker<'a> {
    pub fn new(comm: &'a Comm, algo: &'a Algo,
               exes: &'a ModelExecutables, data: &'a DataSet, seed: u64,
               lr: Option<LrScheduleSpec>) -> Self {
        Self { comm, algo, exes, data, rng: Rng::new(seed), lr,
               groups: None }
    }

    /// Route the gradient all-reduces through a hierarchical
    /// [`GroupLayout`] (every rank of the world must get the identical
    /// layout). The initial weight broadcast and the round-count
    /// agreement stay on the flat raw ring either way.
    pub fn with_groups(mut self, groups: Option<GroupLayout>) -> Self {
        self.groups = groups;
        self
    }

    /// Train to completion. `init` is consumed on rank 0 and broadcast
    /// to the world; other ranks pass `None`. `observer` is consulted
    /// on rank 0 only (pass `Observer::disabled()` elsewhere).
    pub fn run(mut self, init: Option<ParamSet>,
               observer: &mut Observer<'_>)
        -> Result<RingOutcome, WorkerError> {
        let n = self.comm.size();
        let rank = self.comm.rank();
        let batch = self.algo.batch_size;
        let started = Instant::now();
        let mut col = Collective::new(self.comm);
        // Wire codec for the gradient collectives. The initial weight
        // broadcast and the round-count agreement below stay raw; the
        // two piggybacked control elements (mean loss, stop flag) are
        // exempt from lossy dropping.
        col.set_codec(self.algo.compression);
        col.set_exact_tail(2);
        // Grouped topology (hierarchical all-reduce); sum collectives
        // dispatch to ring → tree → ring, control traffic stays flat.
        col.set_groups(self.groups.take());

        // Identical start everywhere: rank 0's init circulates the ring.
        let mut params = match init {
            Some(p) if rank == 0 => p,
            _ => ParamSet::zeros(&self.exes.meta.params),
        };
        let mut weights_buf = params.flat().to_vec();
        col.broadcast(0, &mut weights_buf)?;
        if rank != 0 {
            params.set_flat(&weights_buf);
        }
        drop(weights_buf);

        // Agree on the common per-epoch round count: the minimum of the
        // ranks' local batch counts. Uneven data divisions would
        // otherwise leave the lockstep collectives waiting forever on a
        // rank that ran out of batches.
        let local_batches = self.data.batches_per_epoch(batch);
        let rounds = col
            .allreduce_scalar(local_batches as f32, ReduceOp::Min)?
            as u64;
        if (rounds as usize) < local_batches {
            log::debug!(
                "allreduce rank {rank}: trimming epoch to {rounds} \
                 common rounds (local {local_batches})"
            );
        }

        let n_params = params.num_params();
        // Bucketed overlap: one collective per layer bucket, launched
        // mid-backprop as each layer's gradient lands, plus one tail
        // bucket for the piggybacked loss + stop flag. Requires a tag
        // lane per bucket; a model with more layers than lanes falls
        // back to the monolithic collective.
        let n_buckets = params.layer_ranges().len() + 1;
        let use_buckets = self.algo.buckets && n > 1
            && n_buckets <= tags::MAX_BUCKETS as usize;
        if self.algo.buckets && !use_buckets && n > 1 && rank == 0 {
            log::warn!(
                "allreduce: {n_buckets} buckets exceed the \
                 {} tag lanes; using the monolithic all-reduce",
                tags::MAX_BUCKETS
            );
        }
        let mut opt = self.algo.build_master_optimizer(n_params);
        let lr_spec = self.lr;
        let mut history = History::default();
        let mut grad_timer = Stopwatch::new();
        let mut comm_timer = Stopwatch::new();
        let mut update_timer = Stopwatch::new();
        let mut update_count = 0u64;
        let mut last_loss = 0.0f32;
        let inv_n = 1.0 / n as f32;
        let mut epochs_done = 0u32;
        // Early-stop lockstep: rank 0 raises the flag after its
        // callbacks request a stop; the flagged round is abandoned by
        // every rank before the update, keeping weights identical.
        let mut stop_flag = 0.0f32;
        let mut stopped = false;

        let data = self.data;
        let exes = self.exes;
        let algo = self.algo;

        for epoch in 0..algo.epochs {
            let mut erng = self.rng.fork(epoch as u64);
            let mut done_rounds = 0u64;
            let mut failure: Option<WorkerError> = None;
            data.for_each_batch(batch, &mut erng, |x, y| {
                if failure.is_some() || stopped
                    || done_rounds >= rounds {
                    return;
                }
                // Bucketed mode starts each layer's collective inside
                // the gradient step (that launch time IS the overlap,
                // so it stays on the grad timer); the monolithic path
                // computes the whole gradient first.
                let (step, sink_err) = grad_timer.time(|| {
                    if use_buckets {
                        let mut sink = BucketLauncher {
                            col: &mut col,
                            total: n_params + 2,
                            err: None,
                        };
                        let res = exes.grad_step_overlapped(
                            &params, x, y, &mut sink);
                        (res, sink.err)
                    } else {
                        (exes.grad_step(&params, x, y), None)
                    }
                });
                let out = match (step, sink_err) {
                    (Ok(o), None) => o,
                    (Err(e), _) => {
                        failure = Some(e.into());
                        return;
                    }
                    (_, Some(e)) => {
                        failure = Some(e.into());
                        return;
                    }
                };
                last_loss = out.loss;
                // average gradients world-wide; the local loss and the
                // stop flag ride along as two extra elements (grad_step
                // allocates the buffer with spare slots, so these
                // pushes never reallocate the gradient on the hot path)
                let mut reduced = out.grads;
                reduced.push(out.loss);
                reduced.push(stop_flag);
                let comm_result = comm_timer.time(|| {
                    if use_buckets {
                        // tail bucket (loss + stop flag), then drain
                        // every in-flight bucket in launch order
                        let tail = col.pending_buckets();
                        col.bucket_begin(tail, &reduced, n_params,
                                         n_params + 2, n_params + 2)?;
                        col.bucket_finish_sum(&mut reduced)
                    } else {
                        col.allreduce(&mut reduced, ReduceOp::Sum)
                    }
                });
                if let Err(e) = comm_result {
                    failure = Some(e.into());
                    return;
                }
                if reduced[n_params + 1] > 0.0 {
                    // someone (rank 0) requested a stop before this
                    // round: abandon it pre-update on every rank
                    stopped = true;
                    return;
                }
                for v in reduced.iter_mut().take(n_params + 1) {
                    *v *= inv_n;
                }
                let mean_loss = reduced[n_params];
                if let Some(spec) = lr_spec {
                    opt.set_lr_scale(
                        spec.scale_for_update(update_count + 1));
                }
                update_timer.start();
                opt.update(params.flat_mut(), &reduced[..n_params]);
                update_timer.stop();
                update_count += 1;
                done_rounds += 1;
                if rank == 0 {
                    observer.after_update(
                        update_count, mean_loss, &params,
                        started.elapsed().as_secs_f64(), &mut history);
                    if observer.should_stop() {
                        stop_flag = 1.0;
                    }
                }
            });
            if let Some(e) = failure {
                return Err(e);
            }
            if stopped {
                break;
            }
            epochs_done = epoch + 1;
        }

        let report = WorkerReport {
            rank,
            epochs: epochs_done,
            batches: update_count,
            samples: update_count * batch as u64,
            last_train_loss: last_loss,
            grad_time_s: grad_timer.total_s(),
            comm_wait_s: comm_timer.total_s(),
        };

        if rank != 0 {
            self.comm.send(
                0,
                Tag::TrainStats,
                Payload::Stats(WorkerStats {
                    epoch: report.epochs,
                    batches_done: report.batches,
                    samples_done: report.samples,
                    train_loss: report.last_train_loss,
                    grad_time_s: report.grad_time_s,
                    comm_wait_s: report.comm_wait_s,
                }),
            )?;
            return Ok(RingOutcome {
                report,
                weights: params,
                history: History::default(),
            });
        }

        // Rank 0 wind-down: collect every peer's stats. Some may have
        // been stashed by the final collectives (a faster rank finishes
        // its last all-gather — and reports — before rank 0 does).
        let mut stash = col.into_stash();
        history.workers.push(report.clone());
        for _ in 1..n {
            let env = self.comm.recv_tag(Tag::TrainStats, &mut stash)?;
            if let Payload::Stats(s) = env.payload {
                history.workers.push(WorkerReport {
                    rank: env.src,
                    epochs: s.epoch,
                    batches: s.batches_done,
                    samples: s.samples_done,
                    last_train_loss: s.train_loss,
                    grad_time_s: s.grad_time_s,
                    comm_wait_s: s.comm_wait_s,
                });
            }
        }
        history.master_updates = update_count;
        history.master_update_time_s = update_timer.total_s();
        history.wallclock_s = started.elapsed().as_secs_f64();
        // final validation (every run ends with a measurement) + the
        // callbacks' on_train_end
        observer.finish(update_count, &params,
                        started.elapsed().as_secs_f64(), &mut history);
        Ok(RingOutcome { report, weights: params, history })
    }
}
