//! The master process: owns the weights, applies updates, serves workers.
//!
//! Downpour (paper §III-A): each incoming worker gradient is applied to
//! the master's weights by the optimizer, and the updated weights are sent
//! back to that worker — asynchronously one-by-one (default) or behind a
//! full barrier (synchronous mode). EASGD: the master owns the center
//! variable and answers worker exchange requests with the elastic update.
//!
//! The same state machine also serves as the *super-master* in the
//! hierarchical configuration: group masters send `AggGradients` which
//! take the ordinary gradient path (the group master pre-negates its
//! weight delta so an identity-SGD super-optimizer means "adopt delta").
//!
//! The master is the *observer* role: its [`Observer`] runs the
//! validation schedule and the callback set after every update. When a
//! callback requests a stop (early stopping), the master switches to
//! wind-down: every subsequent child request is answered with
//! `Tag::Exit`, which the existing worker protocol already treats as
//! "finish up and report" — so the stop propagates with no new message
//! types.

use std::collections::{BTreeMap, BTreeSet};
use std::time::Instant;

use crate::coordinator::algo::{Algo, Mode};
use crate::coordinator::callbacks::Observer;
use crate::metrics::{History, Stopwatch, WorkerReport};
use crate::mpi::{Comm, Envelope, Payload, Rank, Tag};
use crate::optim::Optimizer;
use crate::tensor::ParamSet;

/// Everything the master needs beyond its communicator.
pub struct MasterContext<'a> {
    pub algo: &'a Algo,
    /// Child ranks this master serves (workers, or group masters).
    pub children: Vec<Rank>,
    /// Validation + callbacks host (see `callbacks::Observer`).
    pub observer: Observer<'a>,
}

/// Result of a master run.
pub struct MasterOutcome {
    pub weights: ParamSet,
    pub history: History,
}

/// Staleness accounting (Fig 2's mechanism: workers training on outdated
/// weights).
#[derive(Debug, Default, Clone)]
pub struct StalenessStats {
    pub total: u64,
    pub count: u64,
    pub max: u64,
}

impl StalenessStats {
    fn record(&mut self, staleness: u64) {
        self.total += staleness;
        self.count += 1;
        self.max = self.max.max(staleness);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total as f64 / self.count as f64
        }
    }
}

pub struct Master<'a> {
    comm: &'a Comm,
    ctx: MasterContext<'a>,
    weights: ParamSet,
    optimizer: Box<dyn Optimizer>,
    update_count: u64,
    done: BTreeSet<Rank>,
    /// Synchronous-mode barrier stash: rank -> (loss, grads).
    pending: BTreeMap<Rank, (f32, Vec<f32>)>,
    /// Early-stop wind-down: answer everything with Exit.
    stopping: bool,
    pub staleness: StalenessStats,
    history: History,
    update_timer: Stopwatch,
    idle_timer: Stopwatch,
    started: Instant,
}

impl<'a> Master<'a> {
    pub fn new(comm: &'a Comm, ctx: MasterContext<'a>, init: ParamSet)
        -> Self {
        let n = init.num_params();
        let optimizer = ctx.algo.build_master_optimizer(n);
        Self {
            comm,
            ctx,
            weights: init,
            optimizer,
            update_count: 0,
            done: BTreeSet::new(),
            pending: BTreeMap::new(),
            stopping: false,
            staleness: StalenessStats::default(),
            history: History::default(),
            update_timer: Stopwatch::new(),
            idle_timer: Stopwatch::new(),
            started: Instant::now(),
        }
    }

    /// Partition the optimizer's update loop over the compute pool.
    /// Updates stay bitwise-identical — the pool only splits the index
    /// range, never the per-element operation order.
    pub fn with_pool(
        mut self,
        pool: std::sync::Arc<crate::util::threadpool::ThreadPool>,
    ) -> Self {
        self.optimizer.set_pool(pool);
        self
    }

    fn active_children(&self) -> usize {
        self.ctx.children.len() - self.done.len()
    }

    /// Current weights as a wire payload — fp16-compressed when the
    /// configured codec is fp16 (top-k never touches weight replicas;
    /// see `Codec::pack_replica`). Both variants hold an `Arc`, so
    /// cloning for a fan-out re-sends one snapshot.
    fn weights_payload(&self) -> Payload {
        self.ctx.algo.compression
            .weights_payload(self.update_count, self.weights.flat())
    }

    fn send_weights(&self, to: Rank) {
        if let Err(e) =
            self.comm.send(to, Tag::Weights, self.weights_payload())
        {
            log::warn!("master: weight send to {to} failed: {e}");
        }
    }

    fn send_exit(&self, to: Rank) {
        if let Err(e) = self.comm.send(to, Tag::Exit, Payload::Empty) {
            log::warn!("master: exit send to {to} failed: {e}");
        }
    }

    /// Snapshot (and compress) once, fan out to many recipients (sync
    /// barrier) — the Arc inside the payload keeps the broadcast a
    /// single allocation.
    fn broadcast_weights(&self, to: impl Iterator<Item = Rank>) {
        let payload = self.weights_payload();
        for rank in to {
            if let Err(e) =
                self.comm.send(rank, Tag::Weights, payload.clone())
            {
                log::warn!("master: weight send to {rank} failed: {e}");
            }
        }
    }

    /// One optimizer step + the observer hook (train-loss sampling, due
    /// validation, callbacks). May flip `stopping`.
    fn apply_gradient(&mut self, loss: f32, grads: &[f32]) {
        if let Some(scale) = self.ctx.observer.take_lr_scale() {
            self.optimizer.set_lr_scale(scale);
        }
        self.update_timer.start();
        self.optimizer.update(self.weights.flat_mut(), grads);
        self.update_timer.stop();
        self.update_count += 1;
        self.ctx.observer.after_update(
            self.update_count, loss, &self.weights,
            self.started.elapsed().as_secs_f64(), &mut self.history);
        if self.ctx.observer.should_stop() && !self.stopping {
            self.stopping = true;
            log::info!("master: callbacks requested stop after \
                        update {}", self.update_count);
        }
    }

    fn handle_grad(&mut self, src: Rank, step: u64, loss: f32,
                   grads: Vec<f32>, sync: bool) {
        // A rogue/buggy child could keep sending gradients after its
        // Exit: applying them would move weights on behalf of a dead
        // rank (and, in sync mode, let its stale gradient stand in for
        // an active child's barrier contribution).
        if self.done.contains(&src) {
            log::warn!("master: dropping gradient from departed {src}");
            return;
        }
        if self.stopping {
            self.send_exit(src);
            return;
        }
        self.staleness.record(self.update_count.saturating_sub(step));
        if !sync {
            self.apply_gradient(loss, &grads);
            if self.stopping {
                self.send_exit(src);
            } else {
                self.send_weights(src);
            }
            return;
        }
        self.pending.insert(src, (loss, grads));
        self.try_sync_round();
    }

    /// In synchronous mode, fire the barrier when every active child has
    /// contributed. (The `Tag::Exit` handler removes a departed child's
    /// pending gradient before re-checking the barrier, so `pending`
    /// only ever holds active ranks here.)
    fn try_sync_round(&mut self) {
        if self.pending.is_empty()
            || self.pending.len() < self.active_children() {
            return;
        }
        let pending = std::mem::take(&mut self.pending);
        let n = pending.len() as f32;
        let dim = self.weights.num_params();
        let mut avg = vec![0.0f32; dim];
        let mut avg_loss = 0.0f32;
        for (_, (loss, g)) in &pending {
            avg_loss += loss / n;
            for (a, gi) in avg.iter_mut().zip(g) {
                *a += gi / n;
            }
        }
        self.apply_gradient(avg_loss, &avg);
        if self.stopping {
            for rank in pending.into_keys() {
                self.send_exit(rank);
            }
        } else {
            self.broadcast_weights(pending.into_keys());
        }
    }

    /// EASGD center update: reply with the current center, then move the
    /// center toward the worker's weights by alpha.
    fn handle_exchange(&mut self, src: Rank,
                       worker_w: std::sync::Arc<Vec<f32>>, alpha: f32) {
        if self.stopping {
            self.send_exit(src);
            return;
        }
        // the reply carries the pre-update center (the worker pulls
        // toward where the center was when it asked)
        let reply = self.weights_payload();
        self.update_timer.start();
        let center = self.weights.flat_mut();
        for (c, w) in center.iter_mut().zip(worker_w.iter()) {
            *c += alpha * (*w - *c);
        }
        self.update_timer.stop();
        self.update_count += 1;
        // EASGD exchanges carry no gradient loss: NaN marks "no sample"
        self.ctx.observer.after_update(
            self.update_count, f32::NAN, &self.weights,
            self.started.elapsed().as_secs_f64(), &mut self.history);
        if self.ctx.observer.should_stop() {
            self.stopping = true;
            self.send_exit(src);
            return;
        }
        if let Err(e) = self.comm.send(src, Tag::Center, reply) {
            log::warn!("master: center send to {src} failed: {e}");
        }
    }

    fn handle_stats(&mut self, src: Rank,
                    s: crate::mpi::WorkerStats) {
        self.history.workers.push(WorkerReport {
            rank: src,
            epochs: s.epoch,
            batches: s.batches_done,
            samples: s.samples_done,
            last_train_loss: s.train_loss,
            grad_time_s: s.grad_time_s,
            comm_wait_s: s.comm_wait_s,
        });
    }

    /// Run the serve loop until every child has exited.
    pub fn run(mut self) -> MasterOutcome {
        let easgd_alpha = match self.ctx.algo.mode {
            Mode::Easgd { alpha, .. } => Some(alpha),
            _ => None,
        };
        let sync = matches!(self.ctx.algo.mode,
                            Mode::Downpour { sync: true });
        while !self.ctx.children.is_empty()
            && self.done.len() < self.ctx.children.len() {
            self.idle_timer.start();
            let env = match self.comm.recv() {
                Ok(env) => env,
                Err(e) => {
                    log::error!("master recv failed: {e}");
                    break;
                }
            };
            self.idle_timer.stop();
            let Envelope { src, tag, payload } = env;
            match (tag, payload) {
                (Tag::Ready, _) => {
                    if self.stopping {
                        self.send_exit(src);
                    } else {
                        self.send_weights(src);
                    }
                }
                (tag @ (Tag::Gradients | Tag::AggGradients), payload) =>
                {
                    // raw Grad or a codec-compressed Packed gradient
                    match payload.grad_like() {
                        Some((step, loss, data)) => {
                            self.handle_grad(src, step, loss, data,
                                             sync);
                        }
                        None => log::warn!(
                            "master: {tag:?} from {src} without a \
                             gradient payload"),
                    }
                }
                (Tag::ExchangeWeights, payload) => {
                    match payload.weights_like() {
                        Some((_, data)) => {
                            let alpha = easgd_alpha.unwrap_or(0.5);
                            self.handle_exchange(src, data, alpha);
                        }
                        None => log::warn!(
                            "master: ExchangeWeights from {src} \
                             without a weight payload"),
                    }
                }
                (Tag::TrainStats, Payload::Stats(s)) => {
                    self.handle_stats(src, s)
                }
                (Tag::Exit, _) => {
                    self.done.insert(src);
                    // drop any gradient the departed child left behind
                    self.pending.remove(&src);
                    log::debug!("master: child {src} done \
                                 ({}/{})", self.done.len(),
                                self.ctx.children.len());
                    if sync {
                        // a departing child shrinks the barrier
                        self.try_sync_round();
                    }
                }
                (tag, payload) => {
                    log::warn!("master: unexpected {tag:?} from {src} \
                                ({payload:?})");
                }
            }
        }
        self.history.staleness_mean = self.staleness.mean();
        self.history.staleness_max = self.staleness.max;
        self.history.master_updates = self.update_count;
        self.history.master_update_time_s = self.update_timer.total_s();
        self.history.master_idle_time_s = self.idle_timer.total_s();
        self.history.wallclock_s = self.started.elapsed().as_secs_f64();
        // final validation (every run ends with a measurement) + the
        // callbacks' on_train_end
        self.ctx.observer.finish(self.update_count, &self.weights,
                                 self.started.elapsed().as_secs_f64(),
                                 &mut self.history);
        MasterOutcome { weights: self.weights, history: self.history }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sync_algo() -> Algo {
        Algo {
            mode: Mode::Downpour { sync: true },
            optimizer: crate::optim::OptimizerConfig::Sgd { lr: 1.0 },
            ..Algo::default()
        }
    }

    fn small_init() -> ParamSet {
        ParamSet::zeros(&[("w".into(), vec![4])])
    }

    /// Regression for the sync-barrier edge: a child that contributed a
    /// pending gradient and then exited (crash-style, without awaiting
    /// its weight reply) must be dropped from the barrier — its stale
    /// gradient must neither fire a round in place of an active child's
    /// contribution nor be applied to the weights.
    #[test]
    fn departed_child_is_dropped_from_sync_barrier() {
        let mut world = crate::mpi::inproc_world(3);
        let c2 = world.pop().unwrap();
        let c1 = world.pop().unwrap();
        let mcomm = world.pop().unwrap();
        let algo = sync_algo();

        std::thread::scope(|s| {
            let master = s.spawn(|| {
                let ctx = MasterContext {
                    algo: &algo,
                    children: vec![1, 2],
                    observer: Observer::disabled(),
                };
                Master::new(&mcomm, ctx, small_init()).run()
            });

            // child 1: gradient, then immediate exit (no reply awaited)
            c1.send(0, Tag::Gradients,
                    Payload::grad(0, 1.0, vec![1.0; 4])).unwrap();
            c1.send(0, Tag::Exit, Payload::Empty).unwrap();
            // child 2: gradient — the barrier is now just {2}
            c2.send(0, Tag::Gradients,
                    Payload::grad(0, 2.0, vec![3.0; 4])).unwrap();
            // child 2 must get weights reflecting ONLY its own gradient
            let env = c2.recv().unwrap();
            match env.payload {
                Payload::Floats { data, .. } => {
                    assert_eq!(*data, vec![-3.0; 4],
                               "round must exclude the departed \
                                child's gradient");
                }
                p => panic!("unexpected {p:?}"),
            }
            c2.send(0, Tag::Exit, Payload::Empty).unwrap();

            let outcome = master.join().unwrap();
            assert_eq!(outcome.history.master_updates, 1,
                       "exactly one round: the departed child's \
                        gradient is dropped");
            assert!(outcome.weights.flat().iter().all(|&w| w == -3.0));
        });
    }

    /// The barrier still shrinks correctly when the exit arrives after a
    /// full round: remaining children keep making progress.
    #[test]
    fn barrier_shrinks_after_clean_exit() {
        let mut world = crate::mpi::inproc_world(3);
        let c2 = world.pop().unwrap();
        let c1 = world.pop().unwrap();
        let mcomm = world.pop().unwrap();
        let algo = sync_algo();

        std::thread::scope(|s| {
            let master = s.spawn(|| {
                let ctx = MasterContext {
                    algo: &algo,
                    children: vec![1, 2],
                    observer: Observer::disabled(),
                };
                Master::new(&mcomm, ctx, small_init()).run()
            });

            // round 1: both contribute, both get the broadcast
            c1.send(0, Tag::Gradients,
                    Payload::grad(0, 1.0, vec![1.0; 4])).unwrap();
            c2.send(0, Tag::Gradients,
                    Payload::grad(0, 1.0, vec![1.0; 4])).unwrap();
            assert_eq!(c1.recv().unwrap().tag, Tag::Weights);
            assert_eq!(c2.recv().unwrap().tag, Tag::Weights);
            // child 1 leaves cleanly; child 2 trains one more round alone
            c1.send(0, Tag::Exit, Payload::Empty).unwrap();
            c2.send(0, Tag::Gradients,
                    Payload::grad(1, 1.0, vec![1.0; 4])).unwrap();
            assert_eq!(c2.recv().unwrap().tag, Tag::Weights);
            c2.send(0, Tag::Exit, Payload::Empty).unwrap();

            let outcome = master.join().unwrap();
            assert_eq!(outcome.history.master_updates, 2);
        });
    }

    /// Early-stop propagation: a callback that requests stop makes the
    /// master answer the NEXT child request with Exit instead of
    /// weights, and the run winds down cleanly.
    #[test]
    fn stop_request_propagates_as_exit_replies() {
        struct StopAfter(u64);
        impl crate::coordinator::callbacks::Callback for StopAfter {
            fn on_round(
                &mut self,
                info: &crate::coordinator::callbacks::RoundInfo<'_>,
                ctl: &mut crate::coordinator::callbacks::Control) {
                if info.update >= self.0 {
                    ctl.stop();
                }
            }
        }
        let mut world = crate::mpi::inproc_world(2);
        let c1 = world.pop().unwrap();
        let mcomm = world.pop().unwrap();
        let algo = Algo {
            optimizer: crate::optim::OptimizerConfig::Sgd { lr: 1.0 },
            ..Algo::default()
        };

        std::thread::scope(|s| {
            let master = s.spawn(|| {
                let mut callbacks =
                    crate::coordinator::callbacks::CallbackSet::new();
                callbacks.push(Box::new(StopAfter(2)));
                let ctx = MasterContext {
                    algo: &algo,
                    children: vec![1],
                    observer: Observer::new(&algo, None, callbacks),
                };
                Master::new(&mcomm, ctx, small_init()).run()
            });

            c1.send(0, Tag::Gradients,
                    Payload::grad(0, 1.0, vec![1.0; 4])).unwrap();
            assert_eq!(c1.recv().unwrap().tag, Tag::Weights);
            c1.send(0, Tag::Gradients,
                    Payload::grad(1, 1.0, vec![1.0; 4])).unwrap();
            // update 2 trips the callback: the reply is Exit
            assert_eq!(c1.recv().unwrap().tag, Tag::Exit);
            // worker wind-down: stats + exit
            c1.send(0, Tag::TrainStats, Payload::Stats(
                crate::mpi::WorkerStats::default())).unwrap();
            c1.send(0, Tag::Exit, Payload::Empty).unwrap();

            let outcome = master.join().unwrap();
            assert_eq!(outcome.history.master_updates, 2,
                       "no updates after the stop");
        });
    }
}
