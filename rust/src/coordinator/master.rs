//! The master process: owns the weights, applies updates, serves workers.
//!
//! Downpour (paper §III-A): each incoming worker gradient is applied to
//! the master's weights by the optimizer, and the updated weights are sent
//! back to that worker — asynchronously one-by-one (default) or behind a
//! full barrier (synchronous mode). EASGD: the master owns the center
//! variable and answers worker exchange requests with the elastic update.
//!
//! The same state machine also serves as the *super-master* in the
//! hierarchical configuration: group masters send `AggGradients` which
//! take the ordinary gradient path (the group master pre-negates its
//! weight delta so an identity-SGD super-optimizer means "adopt delta").

use std::collections::{BTreeMap, BTreeSet};
use std::time::Instant;

use crate::coordinator::algo::{Algo, Mode};
use crate::coordinator::validation::{run_validation, ValidationSchedule};
use crate::data::DataSet;
use crate::metrics::{History, Stopwatch, ValRecord, WorkerReport};
use crate::mpi::{Comm, Envelope, Payload, Rank, Tag};
use crate::optim::Optimizer;
use crate::runtime::ModelExecutables;
use crate::tensor::ParamSet;

/// Everything the master needs beyond its communicator.
pub struct MasterContext<'a> {
    pub algo: &'a Algo,
    /// Child ranks this master serves (workers, or group masters).
    pub children: Vec<Rank>,
    /// Validation executables + held-out set (None = no validation).
    pub eval: Option<(&'a ModelExecutables, &'a DataSet)>,
}

/// Result of a master run.
pub struct MasterOutcome {
    pub weights: ParamSet,
    pub history: History,
}

/// Staleness accounting (Fig 2's mechanism: workers training on outdated
/// weights).
#[derive(Debug, Default, Clone)]
pub struct StalenessStats {
    pub total: u64,
    pub count: u64,
    pub max: u64,
}

impl StalenessStats {
    fn record(&mut self, staleness: u64) {
        self.total += staleness;
        self.count += 1;
        self.max = self.max.max(staleness);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total as f64 / self.count as f64
        }
    }
}

pub struct Master<'a> {
    comm: &'a Comm,
    ctx: MasterContext<'a>,
    weights: ParamSet,
    optimizer: Box<dyn Optimizer>,
    update_count: u64,
    schedule: ValidationSchedule,
    lr_schedule: Option<crate::optim::StepDecay>,
    done: BTreeSet<Rank>,
    /// Synchronous-mode barrier stash: rank -> (loss, grads).
    pending: BTreeMap<Rank, (f32, Vec<f32>)>,
    pub staleness: StalenessStats,
    history: History,
    update_timer: Stopwatch,
    idle_timer: Stopwatch,
    started: Instant,
}

impl<'a> Master<'a> {
    pub fn new(comm: &'a Comm, ctx: MasterContext<'a>, init: ParamSet)
        -> Self {
        let n = init.num_params();
        let optimizer = ctx.algo.build_master_optimizer(n);
        let schedule = ValidationSchedule::new(ctx.algo.validate_every);
        let lr_schedule = if ctx.algo.lr_decay > 0.0
            && ctx.algo.lr_decay_every > 0 {
            Some(crate::optim::StepDecay::new(ctx.algo.lr_decay,
                                              ctx.algo.lr_decay_every))
        } else {
            None
        };
        Self {
            comm,
            ctx,
            weights: init,
            optimizer,
            update_count: 0,
            schedule,
            lr_schedule,
            done: BTreeSet::new(),
            pending: BTreeMap::new(),
            staleness: StalenessStats::default(),
            history: History::default(),
            update_timer: Stopwatch::new(),
            idle_timer: Stopwatch::new(),
            started: Instant::now(),
        }
    }

    fn active_children(&self) -> usize {
        self.ctx.children.len() - self.done.len()
    }

    fn send_weights(&self, to: Rank) {
        let payload = Payload::floats(self.update_count,
                                      self.weights.flat().to_vec());
        if let Err(e) = self.comm.send(to, Tag::Weights, payload) {
            log::warn!("master: weight send to {to} failed: {e}");
        }
    }

    /// Snapshot once, fan out to many recipients (sync barrier) — the
    /// Arc payload keeps the broadcast a single allocation.
    fn broadcast_weights(&self, to: impl Iterator<Item = Rank>) {
        let snapshot =
            std::sync::Arc::new(self.weights.flat().to_vec());
        for rank in to {
            let payload = Payload::floats_shared(self.update_count,
                                                 snapshot.clone());
            if let Err(e) = self.comm.send(rank, Tag::Weights, payload) {
                log::warn!("master: weight send to {rank} failed: {e}");
            }
        }
    }

    fn maybe_validate(&mut self, force: bool) {
        let due = force || self.schedule.due(self.update_count);
        if !due {
            return;
        }
        if let Some((exes, val)) = self.ctx.eval {
            match run_validation(exes, &self.weights, val,
                                 self.ctx.algo.max_val_batches) {
                Ok((loss, acc)) => {
                    log::info!(
                        "validation @ update {}: loss={loss:.4} \
                         acc={acc:.4}",
                        self.update_count
                    );
                    self.history.validations.push(ValRecord {
                        t_s: self.started.elapsed().as_secs_f64(),
                        update: self.update_count,
                        val_loss: loss,
                        val_acc: acc,
                    });
                }
                Err(e) => log::error!("validation failed: {e}"),
            }
        }
    }

    fn apply_gradient(&mut self, loss: f32, grads: &[f32]) {
        if let Some(sched) = &mut self.lr_schedule {
            let scale = sched.tick();
            self.optimizer.set_lr_scale(scale);
        }
        self.update_timer.start();
        self.optimizer.update(self.weights.flat_mut(), grads);
        self.update_timer.stop();
        self.update_count += 1;
        if self.update_count % 16 == 0 || self.update_count == 1 {
            self.history.train_losses.push((self.update_count, loss));
        }
        self.maybe_validate(false);
    }

    fn handle_grad(&mut self, src: Rank, step: u64, loss: f32,
                   grads: Vec<f32>, sync: bool) {
        // A rogue/buggy child could keep sending gradients after its
        // Exit: applying them would move weights on behalf of a dead
        // rank (and, in sync mode, let its stale gradient stand in for
        // an active child's barrier contribution).
        if self.done.contains(&src) {
            log::warn!("master: dropping gradient from departed {src}");
            return;
        }
        self.staleness.record(self.update_count.saturating_sub(step));
        if !sync {
            self.apply_gradient(loss, &grads);
            self.send_weights(src);
            return;
        }
        self.pending.insert(src, (loss, grads));
        self.try_sync_round();
    }

    /// In synchronous mode, fire the barrier when every active child has
    /// contributed. (The `Tag::Exit` handler removes a departed child's
    /// pending gradient before re-checking the barrier, so `pending`
    /// only ever holds active ranks here.)
    fn try_sync_round(&mut self) {
        if self.pending.is_empty()
            || self.pending.len() < self.active_children() {
            return;
        }
        let pending = std::mem::take(&mut self.pending);
        let n = pending.len() as f32;
        let dim = self.weights.num_params();
        let mut avg = vec![0.0f32; dim];
        let mut avg_loss = 0.0f32;
        for (_, (loss, g)) in &pending {
            avg_loss += loss / n;
            for (a, gi) in avg.iter_mut().zip(g) {
                *a += gi / n;
            }
        }
        self.apply_gradient(avg_loss, &avg);
        self.broadcast_weights(pending.into_keys());
    }

    /// EASGD center update: reply with the current center, then move the
    /// center toward the worker's weights by alpha.
    fn handle_exchange(&mut self, src: Rank,
                       worker_w: std::sync::Arc<Vec<f32>>, alpha: f32) {
        let reply = Payload::floats(self.update_count,
                                    self.weights.flat().to_vec());
        if let Err(e) = self.comm.send(src, Tag::Center, reply) {
            log::warn!("master: center send to {src} failed: {e}");
        }
        self.update_timer.start();
        let center = self.weights.flat_mut();
        for (c, w) in center.iter_mut().zip(worker_w.iter()) {
            *c += alpha * (*w - *c);
        }
        self.update_timer.stop();
        self.update_count += 1;
        self.maybe_validate(false);
    }

    fn handle_stats(&mut self, src: Rank,
                    s: crate::mpi::WorkerStats) {
        self.history.workers.push(WorkerReport {
            rank: src,
            epochs: s.epoch,
            batches: s.batches_done,
            samples: s.samples_done,
            last_train_loss: s.train_loss,
            grad_time_s: s.grad_time_s,
            comm_wait_s: s.comm_wait_s,
        });
    }

    /// Run the serve loop until every child has exited.
    pub fn run(mut self) -> MasterOutcome {
        let easgd_alpha = match self.ctx.algo.mode {
            Mode::Easgd { alpha, .. } => Some(alpha),
            _ => None,
        };
        let sync = matches!(self.ctx.algo.mode,
                            Mode::Downpour { sync: true });
        while !self.ctx.children.is_empty()
            && self.done.len() < self.ctx.children.len() {
            self.idle_timer.start();
            let env = match self.comm.recv() {
                Ok(env) => env,
                Err(e) => {
                    log::error!("master recv failed: {e}");
                    break;
                }
            };
            self.idle_timer.stop();
            let Envelope { src, tag, payload } = env;
            match (tag, payload) {
                (Tag::Ready, _) => self.send_weights(src),
                (Tag::Gradients, Payload::Grad { step, loss, data })
                | (Tag::AggGradients, Payload::Grad { step, loss, data }) =>
                {
                    self.handle_grad(src, step, loss, data, sync);
                }
                (Tag::ExchangeWeights, Payload::Floats { data, .. }) => {
                    let alpha = easgd_alpha.unwrap_or(0.5);
                    self.handle_exchange(src, data, alpha);
                }
                (Tag::TrainStats, Payload::Stats(s)) => {
                    self.handle_stats(src, s)
                }
                (Tag::Exit, _) => {
                    self.done.insert(src);
                    // drop any gradient the departed child left behind
                    self.pending.remove(&src);
                    log::debug!("master: child {src} done \
                                 ({}/{})", self.done.len(),
                                self.ctx.children.len());
                    if sync {
                        // a departing child shrinks the barrier
                        self.try_sync_round();
                    }
                }
                (tag, payload) => {
                    log::warn!("master: unexpected {tag:?} from {src} \
                                ({payload:?})");
                }
            }
        }
        // final validation so every run ends with a measurement
        self.maybe_validate(true);
        self.history.staleness_mean = self.staleness.mean();
        self.history.staleness_max = self.staleness.max;
        self.history.master_updates = self.update_count;
        self.history.master_update_time_s = self.update_timer.total_s();
        self.history.master_idle_time_s = self.idle_timer.total_s();
        self.history.wallclock_s = self.started.elapsed().as_secs_f64();
        MasterOutcome { weights: self.weights, history: self.history }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sync_algo() -> Algo {
        Algo {
            mode: Mode::Downpour { sync: true },
            optimizer: crate::optim::OptimizerConfig::Sgd { lr: 1.0 },
            ..Algo::default()
        }
    }

    fn small_init() -> ParamSet {
        ParamSet::zeros(&[("w".into(), vec![4])])
    }

    /// Regression for the sync-barrier edge: a child that contributed a
    /// pending gradient and then exited (crash-style, without awaiting
    /// its weight reply) must be dropped from the barrier — its stale
    /// gradient must neither fire a round in place of an active child's
    /// contribution nor be applied to the weights.
    #[test]
    fn departed_child_is_dropped_from_sync_barrier() {
        let mut world = crate::mpi::inproc_world(3);
        let c2 = world.pop().unwrap();
        let c1 = world.pop().unwrap();
        let mcomm = world.pop().unwrap();
        let algo = sync_algo();

        std::thread::scope(|s| {
            let master = s.spawn(|| {
                let ctx = MasterContext {
                    algo: &algo,
                    children: vec![1, 2],
                    eval: None,
                };
                Master::new(&mcomm, ctx, small_init()).run()
            });

            // child 1: gradient, then immediate exit (no reply awaited)
            c1.send(0, Tag::Gradients,
                    Payload::grad(0, 1.0, vec![1.0; 4])).unwrap();
            c1.send(0, Tag::Exit, Payload::Empty).unwrap();
            // child 2: gradient — the barrier is now just {2}
            c2.send(0, Tag::Gradients,
                    Payload::grad(0, 2.0, vec![3.0; 4])).unwrap();
            // child 2 must get weights reflecting ONLY its own gradient
            let env = c2.recv().unwrap();
            match env.payload {
                Payload::Floats { data, .. } => {
                    assert_eq!(*data, vec![-3.0; 4],
                               "round must exclude the departed \
                                child's gradient");
                }
                p => panic!("unexpected {p:?}"),
            }
            c2.send(0, Tag::Exit, Payload::Empty).unwrap();

            let outcome = master.join().unwrap();
            assert_eq!(outcome.history.master_updates, 1,
                       "exactly one round: the departed child's \
                        gradient is dropped");
            assert!(outcome.weights.flat().iter().all(|&w| w == -3.0));
        });
    }

    /// The barrier still shrinks correctly when the exit arrives after a
    /// full round: remaining children keep making progress.
    #[test]
    fn barrier_shrinks_after_clean_exit() {
        let mut world = crate::mpi::inproc_world(3);
        let c2 = world.pop().unwrap();
        let c1 = world.pop().unwrap();
        let mcomm = world.pop().unwrap();
        let algo = sync_algo();

        std::thread::scope(|s| {
            let master = s.spawn(|| {
                let ctx = MasterContext {
                    algo: &algo,
                    children: vec![1, 2],
                    eval: None,
                };
                Master::new(&mcomm, ctx, small_init()).run()
            });

            // round 1: both contribute, both get the broadcast
            c1.send(0, Tag::Gradients,
                    Payload::grad(0, 1.0, vec![1.0; 4])).unwrap();
            c2.send(0, Tag::Gradients,
                    Payload::grad(0, 1.0, vec![1.0; 4])).unwrap();
            assert_eq!(c1.recv().unwrap().tag, Tag::Weights);
            assert_eq!(c2.recv().unwrap().tag, Tag::Weights);
            // child 1 leaves cleanly; child 2 trains one more round alone
            c1.send(0, Tag::Exit, Payload::Empty).unwrap();
            c2.send(0, Tag::Gradients,
                    Payload::grad(1, 1.0, vec![1.0; 4])).unwrap();
            assert_eq!(c2.recv().unwrap().tag, Tag::Weights);
            c2.send(0, Tag::Exit, Payload::Empty).unwrap();

            let outcome = master.join().unwrap();
            assert_eq!(outcome.history.master_updates, 2);
        });
    }
}
