//! `WorldPlan` — the single source of truth for world layout.
//!
//! Every training deployment, in-process threads or an `mpirun`-style
//! TCP mesh, is described by one plan: `(mode, hierarchy, n_workers)`
//! determines the world size and, for every rank, its [`RankRole`], its
//! data-shard index, and its derived RNG seed. The driver then has
//! exactly one orchestration job — "run `rank`'s role of the plan over a
//! communicator" — instead of one hand-rolled launch path per topology
//! (Theano-MPI's launcher/algorithm split; HyPar-Flow's one-call API).
//!
//! Invariants (property-tested in `tests/callbacks_e2e.rs`):
//! - rank 0 is always the *observer*: the role that owns validation,
//!   callbacks, and the returned `History` (Master, or ring rank 0);
//! - roles partition the world: every rank has exactly one role;
//! - shard indices of the gradient-computing ranks are a permutation of
//!   `0..n_shards()` (each shard trained exactly once);
//! - the plan is transport-independent: inproc and TCP deployments of
//!   the same config get the identical plan.

use crate::coordinator::algo::Mode;
use crate::coordinator::driver::TrainConfig;
use crate::coordinator::hierarchy::{HierarchySpec, Role};
use crate::mpi::collective::GroupLayout;
use crate::mpi::Rank;

/// What one rank does in the world.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RankRole {
    /// Parameter-server master: flat Downpour/EASGD master, or the
    /// super-master of a two-level hierarchy. Owns the weights,
    /// validation, and callbacks.
    Master,
    /// Mid-tier master serving group `group` (hierarchy only).
    GroupMaster { group: usize },
    /// Gradient-computing worker reporting to `master`, training data
    /// shard `shard`.
    Worker { master: Rank, shard: usize },
    /// One peer of the masterless all-reduce world, training data
    /// shard `shard` and belonging to collective group `group` (always
    /// 0 in a flat ring; under `hierarchy + allreduce` the group's
    /// first rank is its tree leader). Rank 0 doubles as the observer.
    RingRank { shard: usize, group: usize },
}

/// Static description of a training world: size, per-rank roles, shard
/// assignment, and seed derivation.
///
/// Since PR 8 the plan is **versioned**: [`WorldPlan::epoch`] counts
/// replans, and [`WorldPlan::replan`] / [`WorldPlan::replan_grown`]
/// produce the next generation's plan when ranks depart or join — the
/// ring/group layout and shard assignment are re-derived from the
/// surviving member list while the underlying `Comm` world (and the
/// original rank IDs) stay fixed.
///
/// ```
/// use mpi_learn::coordinator::{Mode, WorldPlan};
///
/// let plan = WorldPlan::from_parts(&Mode::AllReduce, None, 4, 7)
///     .unwrap();
/// assert_eq!((plan.epoch(), plan.world_size()), (0, 4));
///
/// // rank 2 departs: the survivors re-form a 3-rank ring and the
/// // dataset is re-sharded over the three member positions
/// let next = plan.replan(&[0, 1, 3]).unwrap();
/// assert_eq!((next.epoch(), next.world_size()), (1, 3));
/// assert_eq!(next.members(), Some(&[0, 1, 3][..]));
///
/// // a later scale-up re-admits rank 2 through the same path
/// let grown = next.replan_grown(&[2]).unwrap();
/// assert_eq!((grown.epoch(), grown.world_size()), (2, 4));
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct WorldPlan {
    ring: bool,
    hierarchy: Option<HierarchySpec>,
    n_shards: usize,
    seed: u64,
    /// Plan generation: 0 at launch, +1 per replan. Stamped into the
    /// high bits of collective payload steps so stragglers from a
    /// replaced world are rejected.
    epoch: u64,
    /// Surviving members over the ORIGINAL rank space, ascending
    /// (`None` = the full original world).
    members: Option<Vec<Rank>>,
}

impl WorldPlan {
    /// Plan the world for a [`TrainConfig`]. Fails on contradictory
    /// configurations (the same checks `JobConfig` applies at parse
    /// time, so programmatic callers get them too).
    pub fn new(cfg: &TrainConfig) -> Result<WorldPlan, String> {
        Self::from_parts(&cfg.algo.mode, cfg.hierarchy, cfg.n_workers,
                         cfg.seed)
    }

    /// Plan from raw parts (used by config parsing before a full
    /// `TrainConfig` exists).
    pub fn from_parts(mode: &Mode, hierarchy: Option<HierarchySpec>,
                      n_workers: usize, seed: u64)
        -> Result<WorldPlan, String> {
        let ring = matches!(mode, Mode::AllReduce);
        if let Some(h) = &hierarchy {
            // Key-naming validation: these messages surface verbatim
            // from `JobConfig` parse errors, so they must say WHICH
            // keys to fix, not just which mode was rejected.
            if h.n_groups < 2 {
                return Err(format!(
                    "\"hierarchy\" requires \"groups\" >= 2 (got {}); \
                     drop \"hierarchy\" for a flat world",
                    h.n_groups));
            }
            if !matches!(mode, Mode::Downpour { .. } | Mode::AllReduce) {
                return Err("\"hierarchy\" requires \"mode\" \
                            \"downpour\" (grouped parameter servers) \
                            or \"allreduce\" (grouped ring + leader \
                            tree); \"easgd\" has no hierarchical form"
                    .into());
            }
        }
        // Grouped rings accept workers_per_group == 0 as "derive from
        // the worker count at plan time" — this is what keeps
        // `Experiment::allreduce_grouped` order-independent of
        // `Experiment::workers`.
        let hierarchy = match hierarchy {
            Some(h) if ring && h.workers_per_group == 0 => {
                if n_workers == 0 || n_workers % h.n_groups != 0 {
                    return Err(format!(
                        "\"workers\" ({n_workers}) must divide evenly \
                         into \"groups\" ({}) ring groups of >= 1 \
                         rank each",
                        h.n_groups));
                }
                Some(HierarchySpec {
                    workers_per_group: n_workers / h.n_groups,
                    ..h
                })
            }
            Some(h) if h.workers_per_group == 0 => {
                return Err("\"hierarchy\" requires \
                            \"workers_per_group\" >= 1 (got 0)"
                    .into());
            }
            h => h,
        };
        let n_shards = match &hierarchy {
            Some(h) => h.n_groups * h.workers_per_group,
            None => n_workers,
        };
        if n_shards == 0 {
            return Err("need at least one worker (\"workers\" >= 1)"
                .into());
        }
        Ok(WorldPlan { ring, hierarchy, n_shards, seed, epoch: 0,
                       members: None })
    }

    /// Plan generation (0 until the first replan).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Current member list over the original rank space (`None` = the
    /// full original world, i.e. ranks `0..world_size()`).
    pub fn members(&self) -> Option<&[Rank]> {
        self.members.as_deref()
    }

    /// The member list in the form `Collective::adopt_world` takes.
    pub fn collective_members(&self) -> Option<Vec<Rank>> {
        self.members.clone()
    }

    /// Does `rank` (an original rank ID) participate in this plan?
    pub fn is_member(&self, rank: Rank) -> bool {
        match &self.members {
            Some(m) => m.contains(&rank),
            None => rank < self.world_size(),
        }
    }

    /// Re-form the world from the surviving ranks (original rank IDs):
    /// the new ring order is the ascending survivor list, the dataset
    /// is re-sharded one shard per member position, and the epoch is
    /// bumped. Only masterless ring worlds are re-plannable — PS modes
    /// tolerate departed children natively (and the serving pool has
    /// its own replica mark-dead path, see DESIGN.md §Serving). Rank 0
    /// must survive: it coordinates membership agreement, so its death
    /// ends the job exactly like a PS master's.
    ///
    /// A single survivor is a valid world: it degrades to local
    /// training (collectives become no-ops), not an error.
    pub fn replan(&self, survivors: &[Rank])
        -> Result<WorldPlan, String> {
        let members = self.normalize_members(survivors.to_vec())?;
        for &r in &members {
            if !self.is_member(r) {
                return Err(format!(
                    "replan: rank {r} is not a member of the current \
                     world (epoch {})", self.epoch));
            }
        }
        Ok(self.with_members(self.epoch + 1, members))
    }

    /// Scale-up replan: admit `joiners` (original rank IDs that must
    /// exist in the launched `Comm` world) alongside every current
    /// member. Joins ride the exact same epoch-bump path as departures;
    /// the new members' weights are replicated by the resume broadcast.
    pub fn replan_grown(&self, joiners: &[Rank])
        -> Result<WorldPlan, String> {
        let mut members: Vec<Rank> = match &self.members {
            Some(m) => m.clone(),
            None => (0..self.world_size()).collect(),
        };
        members.extend_from_slice(joiners);
        let members = self.normalize_members(members)?;
        Ok(self.with_members(self.epoch + 1, members))
    }

    fn normalize_members(&self, mut members: Vec<Rank>)
        -> Result<Vec<Rank>, String> {
        if !self.ring {
            return Err("only masterless ring worlds are re-plannable; \
                        PS modes tolerate departed children natively"
                .into());
        }
        members.sort_unstable();
        members.dedup();
        if members.is_empty() {
            return Err("replan needs at least one survivor".into());
        }
        if members[0] != 0 {
            return Err("rank 0 coordinates membership agreement and \
                        cannot be replaced; its departure ends the job"
                .into());
        }
        Ok(members)
    }

    /// Build the plan a member adopts when the coordinator distributes
    /// `(epoch, members)` — the worker-side counterpart of
    /// [`WorldPlan::replan`] (the wire carries only the member list, so
    /// every rank reconstructs an identical plan from its launch copy).
    pub fn with_members(&self, epoch: u64, members: Vec<Rank>)
        -> WorldPlan {
        WorldPlan {
            ring: self.ring,
            hierarchy: self.hierarchy,
            n_shards: members.len(),
            seed: self.seed,
            epoch,
            members: Some(members),
        }
    }

    /// The CURRENT grouped-ring schedule, if any: `(n_groups,
    /// members_per_group)`. The hierarchy spec is immutable launch
    /// intent; this derives the generation's actual grouping from the
    /// live member count, falling back to a flat ring whenever the
    /// members no longer divide evenly into the requested groups (a
    /// later grow-replan that restores divisibility restores the
    /// grouped schedule).
    fn grouping(&self) -> Option<(usize, usize)> {
        match (&self.hierarchy, self.ring) {
            (Some(h), true)
                if h.n_groups >= 2
                    && self.n_shards % h.n_groups == 0
                    && self.n_shards / h.n_groups >= 1 =>
            {
                Some((h.n_groups, self.n_shards / h.n_groups))
            }
            _ => None,
        }
    }

    /// Every grouped-ring group count a world of `n_workers` ranks can
    /// legally form, ascending: `g >= 2` groups (the planner and
    /// [`WorldPlan::from_parts`] both reject single-group hierarchies),
    /// dividing the workers evenly, with at least 2 members per group
    /// (a 1-member group has no intra ring and degrades to the pure
    /// tree the flat candidates already cover). This is the sweep
    /// space of the self-tuning planner — keeping it here means the
    /// planner can never propose a grouping the plan itself rejects.
    pub fn candidate_groupings(n_workers: usize) -> Vec<usize> {
        (2..=n_workers / 2)
            .filter(|g| n_workers % g == 0)
            .collect()
    }

    /// Total ranks in the world.
    pub fn world_size(&self) -> usize {
        if self.ring {
            self.n_shards // masterless: the world IS the worker set
        } else {
            match &self.hierarchy {
                Some(h) => h.world_size(),
                None => self.n_shards + 1,
            }
        }
    }

    /// Number of data shards == number of gradient-computing ranks.
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// The rank that owns validation/callbacks and returns the
    /// `History`: always rank 0 (Master, or the ring's rank 0).
    pub fn observer(&self) -> Rank {
        0
    }

    pub fn is_hierarchical(&self) -> bool {
        self.hierarchy.is_some()
    }

    /// Masterless all-reduce world (lockstep collectives)?
    pub fn is_ring(&self) -> bool {
        self.ring
    }

    pub fn hierarchy(&self) -> Option<&HierarchySpec> {
        self.hierarchy.as_ref()
    }

    /// Collective-layer group layout of a grouped (hierarchical) ring
    /// world: `groups` contiguous blocks of the CURRENT member list,
    /// each block's first member its tree leader. `None` for flat
    /// rings, parameter-server worlds, and replanned generations whose
    /// member count no longer divides into the requested groups (they
    /// fall back to the flat ring schedule until a grow-replan restores
    /// divisibility).
    pub fn ring_layout(&self) -> Option<GroupLayout> {
        let (n_groups, per) = self.grouping()?;
        let members: Vec<Rank> = match &self.members {
            Some(m) => m.clone(),
            None => (0..self.n_shards).collect(),
        };
        Some(GroupLayout::new(
            (0..n_groups)
                .map(|g| members[g * per..(g + 1) * per].to_vec())
                .collect(),
        )
        .expect("member chunks are non-empty and disjoint"))
    }

    /// Which role does `rank` play? `rank` is an ORIGINAL rank ID and
    /// must be a member of the current generation.
    pub fn role_of(&self, rank: Rank) -> RankRole {
        if self.ring {
            // member-positional: a replanned plan's shard/group come
            // from the rank's position in the survivor list, so shards
            // always cover `0..world_size()` exactly once
            let pos = match &self.members {
                Some(m) => m
                    .iter()
                    .position(|&r| r == rank)
                    .unwrap_or_else(|| {
                        panic!("rank {rank} is not a member of the \
                                epoch-{} world {m:?}", self.epoch)
                    }),
                None => {
                    debug_assert!(rank < self.world_size(),
                                  "rank {rank} outside world of {}",
                                  self.world_size());
                    rank
                }
            };
            let group = match self.grouping() {
                Some((_, per)) => pos / per,
                None => 0,
            };
            return RankRole::RingRank { shard: pos, group };
        }
        debug_assert!(rank < self.world_size(),
                      "rank {rank} outside world of {}",
                      self.world_size());
        match &self.hierarchy {
            None => {
                if rank == 0 {
                    RankRole::Master
                } else {
                    RankRole::Worker { master: 0, shard: rank - 1 }
                }
            }
            Some(spec) => match spec.role_of(rank) {
                Role::SuperMaster => RankRole::Master,
                Role::GroupMaster { group } => {
                    RankRole::GroupMaster { group }
                }
                Role::Worker { group, master } => RankRole::Worker {
                    master,
                    // contiguous shard index: group-major, then position
                    // within the group's rank block
                    shard: group * spec.workers_per_group
                        + (rank - master - 1),
                },
            },
        }
    }

    /// Child ranks the (super-)master serves: group masters under a
    /// hierarchy, otherwise every worker.
    pub fn master_children(&self) -> Vec<Rank> {
        assert!(!self.ring, "ring worlds have no master");
        match &self.hierarchy {
            Some(spec) => spec.group_masters(),
            None => (1..=self.n_shards).collect(),
        }
    }

    /// Derived per-rank RNG seed. Gradient-computing ranks fork by shard
    /// (so the same shard sees the same batch order in-process and over
    /// TCP); master ranks use the base seed (weight init).
    pub fn seed_of(&self, rank: Rank) -> u64 {
        match self.role_of(rank) {
            RankRole::Worker { shard, .. }
            | RankRole::RingRank { shard, .. } => {
                self.seed ^ (shard as u64 + 1).wrapping_mul(0x9E37)
            }
            RankRole::Master | RankRole::GroupMaster { .. } => self.seed,
        }
    }

    /// Log-line tag for a rank (matches the historical tags).
    pub fn rank_tag(&self, rank: Rank) -> String {
        match self.role_of(rank) {
            RankRole::Master => {
                if self.hierarchy.is_some() {
                    "super-master".into()
                } else {
                    "master".into()
                }
            }
            RankRole::GroupMaster { group } => format!("gmaster-{group}"),
            RankRole::Worker { .. } => format!("worker-{rank}"),
            RankRole::RingRank { group, .. } => {
                if self.hierarchy.is_some() {
                    format!("rank-{rank}/g{group}")
                } else {
                    format!("rank-{rank}")
                }
            }
        }
    }
}

/// What one rank does in a serving world.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeRole {
    /// Rank 0: owns the HTTP listener, the micro-batcher, and the
    /// checkpoint watcher; dispatches batches to replicas and
    /// broadcasts reloaded weights.
    Frontend,
    /// Inference replica `index` (0-based): holds one model executable
    /// + the current `ParamSet`, answers `ServeRequest` batches.
    Replica { index: usize },
}

/// Static description of an inference-serving world: the `WorldPlan`
/// analogue for the `serve` subcommand, so replica worlds are built
/// over the exact same `Comm` substrate (inproc threads or a TCP mesh)
/// as training worlds.
///
/// Layout is fixed: rank 0 is the frontend, ranks `1..=replicas` are
/// replicas. With `replicas == 0` there is no RPC world at all — the
/// frontend runs inference in-process (the single-node fast path).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServePlan {
    replicas: usize,
}

impl ServePlan {
    pub fn new(replicas: usize) -> Result<ServePlan, String> {
        // Cap far above any sane deployment, but low enough that a
        // mis-typed flag can't fork thousands of threads.
        if replicas > 256 {
            return Err(format!(
                "\"replicas\" ({replicas}) exceeds the supported \
                 maximum (256)"));
        }
        Ok(ServePlan { replicas })
    }

    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Total ranks: the frontend plus every replica. 1 when the world
    /// is in-process only (`replicas == 0`).
    pub fn world_size(&self) -> usize {
        self.replicas + 1
    }

    pub fn frontend(&self) -> Rank {
        0
    }

    /// The replica ranks, in dispatch order.
    pub fn replica_ranks(&self) -> Vec<Rank> {
        (1..=self.replicas).collect()
    }

    pub fn role_of(&self, rank: Rank) -> ServeRole {
        debug_assert!(rank < self.world_size(),
                      "rank {rank} outside serve world of {}",
                      self.world_size());
        if rank == 0 {
            ServeRole::Frontend
        } else {
            ServeRole::Replica { index: rank - 1 }
        }
    }

    /// Log-line tag for a rank (mirrors `WorldPlan::rank_tag`).
    pub fn rank_tag(&self, rank: Rank) -> String {
        match self.role_of(rank) {
            ServeRole::Frontend => "frontend".into(),
            ServeRole::Replica { index } => format!("replica-{index}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::algo::Algo;
    use crate::coordinator::driver::Transport;

    fn plan(mode: Mode, hierarchy: Option<HierarchySpec>, n: usize)
        -> WorldPlan {
        WorldPlan::from_parts(&mode, hierarchy, n, 2017).unwrap()
    }

    #[test]
    fn flat_plan_layout() {
        let p = plan(Mode::Downpour { sync: false }, None, 4);
        assert_eq!(p.world_size(), 5);
        assert_eq!(p.n_shards(), 4);
        assert_eq!(p.role_of(0), RankRole::Master);
        assert_eq!(p.role_of(3),
                   RankRole::Worker { master: 0, shard: 2 });
        assert_eq!(p.master_children(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn ring_plan_is_masterless() {
        let p = plan(Mode::AllReduce, None, 4);
        assert_eq!(p.world_size(), 4);
        for r in 0..4 {
            assert_eq!(p.role_of(r),
                       RankRole::RingRank { shard: r, group: 0 });
        }
        assert_eq!(p.rank_tag(2), "rank-2");
        assert!(p.ring_layout().is_none(), "flat rings have no layout");
    }

    #[test]
    fn hierarchical_plan_matches_spec() {
        let spec = HierarchySpec { n_groups: 2, workers_per_group: 3,
                                   sync_every: 5 };
        let p = plan(Mode::Downpour { sync: false }, Some(spec), 0);
        assert_eq!(p.world_size(), 9);
        assert_eq!(p.n_shards(), 6);
        assert_eq!(p.role_of(0), RankRole::Master);
        assert_eq!(p.role_of(1), RankRole::GroupMaster { group: 0 });
        assert_eq!(p.role_of(2),
                   RankRole::Worker { master: 1, shard: 0 });
        assert_eq!(p.role_of(4),
                   RankRole::Worker { master: 1, shard: 2 });
        assert_eq!(p.role_of(5), RankRole::GroupMaster { group: 1 });
        assert_eq!(p.role_of(8),
                   RankRole::Worker { master: 5, shard: 5 });
        assert_eq!(p.master_children(), vec![1, 5]);
        assert_eq!(p.rank_tag(0), "super-master");
        assert_eq!(p.rank_tag(1), "gmaster-0");
    }

    #[test]
    fn grouped_allreduce_plans_a_masterless_grouped_world() {
        // ISSUE 4 tentpole: hierarchy + allreduce is a PLAN now, not a
        // rejection — G contiguous groups, no master ranks.
        let spec = HierarchySpec { n_groups: 2, workers_per_group: 4,
                                   sync_every: 1 };
        let p = plan(Mode::AllReduce, Some(spec), 0);
        assert_eq!(p.world_size(), 8, "masterless: world == shard set");
        assert_eq!(p.n_shards(), 8);
        assert!(p.is_ring() && p.is_hierarchical());
        assert_eq!(p.role_of(3),
                   RankRole::RingRank { shard: 3, group: 0 });
        assert_eq!(p.role_of(4),
                   RankRole::RingRank { shard: 4, group: 1 });
        assert_eq!(p.observer(), 0);
        let layout = p.ring_layout().expect("grouped ring has a layout");
        assert_eq!(layout.groups(),
                   &[vec![0, 1, 2, 3], vec![4, 5, 6, 7]]);
        assert_eq!(layout.leaders(), vec![0, 4]);
        assert_eq!(p.rank_tag(5), "rank-5/g1");
    }

    #[test]
    fn single_group_hierarchy_rejected_naming_the_key() {
        // Satellite: rejection messages must name the offending KEYS.
        for mode in [Mode::AllReduce, Mode::Downpour { sync: false }] {
            let spec = HierarchySpec { n_groups: 1,
                                       workers_per_group: 2,
                                       sync_every: 5 };
            let err = WorldPlan::from_parts(&mode, Some(spec), 4, 0)
                .unwrap_err();
            assert!(err.contains("\"groups\" >= 2"), "{err}");
            assert!(err.contains("\"hierarchy\""), "{err}");
        }
    }

    #[test]
    fn easgd_hierarchy_rejected_naming_the_keys() {
        let spec = HierarchySpec { n_groups: 2, workers_per_group: 2,
                                   sync_every: 5 };
        let err = WorldPlan::from_parts(
            &Mode::Easgd {
                tau: 4,
                alpha: 0.5,
                worker_optimizer:
                    crate::optim::OptimizerConfig::Sgd { lr: 0.05 },
            },
            Some(spec), 4, 0)
            .unwrap_err();
        assert!(err.contains("\"hierarchy\"") && err.contains("easgd"),
                "{err}");
    }

    #[test]
    fn empty_worlds_rejected() {
        assert!(WorldPlan::from_parts(&Mode::AllReduce, None, 0, 0)
            .is_err());
        assert!(WorldPlan::from_parts(
            &Mode::Downpour { sync: false },
            Some(HierarchySpec { n_groups: 0, workers_per_group: 2,
                                 sync_every: 1 }),
            0, 0)
            .is_err());
    }

    #[test]
    fn plan_is_transport_independent() {
        let mut cfg = TrainConfig::new("mlp", 10, 3);
        cfg.algo = Algo::allreduce();
        let inproc = WorldPlan::new(&cfg).unwrap();
        cfg.transport = Transport::Tcp { base_port: 47555 };
        let tcp = WorldPlan::new(&cfg).unwrap();
        assert_eq!(inproc, tcp);
    }

    #[test]
    fn seeds_match_historical_derivation() {
        let p = plan(Mode::Downpour { sync: false }, None, 2);
        assert_eq!(p.seed_of(0), 2017);
        assert_eq!(p.seed_of(1), 2017 ^ 0x9E37u64);
        assert_eq!(p.seed_of(2), 2017 ^ 2u64.wrapping_mul(0x9E37));
    }

    #[test]
    fn serve_plan_layout() {
        let p = ServePlan::new(4).unwrap();
        assert_eq!(p.world_size(), 5);
        assert_eq!(p.frontend(), 0);
        assert_eq!(p.replicas(), 4);
        assert_eq!(p.replica_ranks(), vec![1, 2, 3, 4]);
        assert_eq!(p.role_of(0), ServeRole::Frontend);
        assert_eq!(p.role_of(1), ServeRole::Replica { index: 0 });
        assert_eq!(p.role_of(4), ServeRole::Replica { index: 3 });
        assert_eq!(p.rank_tag(0), "frontend");
        assert_eq!(p.rank_tag(2), "replica-1");
    }

    #[test]
    fn serve_plan_zero_replicas_is_in_process() {
        let p = ServePlan::new(0).unwrap();
        assert_eq!(p.world_size(), 1);
        assert!(p.replica_ranks().is_empty());
        assert_eq!(p.role_of(0), ServeRole::Frontend);
    }

    #[test]
    fn serve_plan_caps_replicas() {
        let err = ServePlan::new(10_000).unwrap_err();
        assert!(err.contains("replicas"), "{err}");
    }

    #[test]
    fn candidate_groupings_are_exactly_the_legal_ones() {
        assert!(WorldPlan::candidate_groupings(1).is_empty());
        assert!(WorldPlan::candidate_groupings(2).is_empty());
        assert!(WorldPlan::candidate_groupings(3).is_empty());
        assert_eq!(WorldPlan::candidate_groupings(4), vec![2]);
        assert_eq!(WorldPlan::candidate_groupings(6), vec![2, 3]);
        assert!(WorldPlan::candidate_groupings(7).is_empty());
        assert_eq!(WorldPlan::candidate_groupings(8), vec![2, 4]);
        assert_eq!(WorldPlan::candidate_groupings(64),
                   vec![2, 4, 8, 16, 32]);
        // every candidate builds a valid grouped plan of the same size
        for n in [4usize, 6, 8, 12, 64] {
            for g in WorldPlan::candidate_groupings(n) {
                let spec = HierarchySpec { n_groups: g,
                                           workers_per_group: 0,
                                           sync_every: 1 };
                let p = WorldPlan::from_parts(&Mode::AllReduce,
                                              Some(spec), n, 0)
                    .unwrap();
                assert_eq!(p.world_size(), n);
                assert_eq!(p.ring_layout().unwrap().groups().len(), g);
            }
        }
    }

    // --- elastic replans --------------------------------------------

    #[test]
    fn replan_reshards_over_survivors() {
        let p = plan(Mode::AllReduce, None, 5);
        assert_eq!(p.epoch(), 0);
        assert!(p.members().is_none());
        let q = p.replan(&[3, 0, 1, 3]).unwrap(); // unsorted + dup ok
        assert_eq!(q.epoch(), 1);
        assert_eq!(q.world_size(), 3);
        assert_eq!(q.n_shards(), 3);
        assert_eq!(q.members(), Some(&[0, 1, 3][..]));
        // shards are member positions: a permutation of 0..3
        assert_eq!(q.role_of(0), RankRole::RingRank { shard: 0,
                                                      group: 0 });
        assert_eq!(q.role_of(1), RankRole::RingRank { shard: 1,
                                                      group: 0 });
        assert_eq!(q.role_of(3), RankRole::RingRank { shard: 2,
                                                      group: 0 });
        assert!(q.is_member(3) && !q.is_member(2));
        // the departed rank cannot re-enter via replan (only via
        // replan_grown)
        assert!(q.replan(&[0, 2]).is_err());
        // ...but can via the join path, restoring a 4-rank world
        let g = q.replan_grown(&[2]).unwrap();
        assert_eq!(g.epoch(), 2);
        assert_eq!(g.members(), Some(&[0, 1, 2, 3][..]));
    }

    #[test]
    fn replan_requires_rank_zero_and_ring_mode() {
        let p = plan(Mode::AllReduce, None, 4);
        let err = p.replan(&[1, 2, 3]).unwrap_err();
        assert!(err.contains("rank 0"), "{err}");
        let ps = plan(Mode::Downpour { sync: false }, None, 4);
        assert!(ps.replan(&[0, 1]).is_err());
        assert!(p.replan(&[]).is_err());
    }

    #[test]
    fn replan_single_survivor_degrades_to_local() {
        let p = plan(Mode::AllReduce, None, 4);
        let q = p.replan(&[0]).unwrap();
        assert_eq!(q.world_size(), 1);
        assert_eq!(q.role_of(0), RankRole::RingRank { shard: 0,
                                                      group: 0 });
        assert!(q.ring_layout().is_none());
    }

    #[test]
    fn grouped_replan_falls_back_to_flat_until_divisible() {
        let spec = HierarchySpec { n_groups: 2, workers_per_group: 4,
                                   sync_every: 1 };
        let p = plan(Mode::AllReduce, Some(spec), 0);
        // kill rank 5: 7 survivors don't divide into 2 groups
        let q = p.replan(&[0, 1, 2, 3, 4, 6, 7]).unwrap();
        assert!(q.ring_layout().is_none(), "7 ∤ 2 → flat ring");
        assert_eq!(q.role_of(6), RankRole::RingRank { shard: 5,
                                                      group: 0 });
        // kill rank 7 too: 6 survivors re-form 2 groups of 3 members
        let r = q.replan(&[0, 1, 2, 3, 4, 6]).unwrap();
        assert_eq!(r.epoch(), 2);
        let layout = r.ring_layout().expect("6 members → 2 groups");
        assert_eq!(layout.groups(), &[vec![0, 1, 2], vec![3, 4, 6]]);
        assert_eq!(layout.leaders(), vec![0, 3]);
        assert_eq!(r.role_of(6), RankRole::RingRank { shard: 5,
                                                      group: 1 });
        // re-admit both: the original grouped layout is restored
        let s = r.replan_grown(&[5, 7]).unwrap();
        assert_eq!(s.ring_layout().unwrap().groups(),
                   &[vec![0, 1, 2, 3], vec![4, 5, 6, 7]]);
    }

    #[test]
    fn with_members_reconstructs_the_coordinator_plan() {
        let p = plan(Mode::AllReduce, None, 6);
        let replanned = p.replan(&[0, 2, 4, 5]).unwrap();
        let adopted = p.with_members(1, vec![0, 2, 4, 5]);
        assert_eq!(adopted, replanned);
    }
}
