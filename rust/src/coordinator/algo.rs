//! `Algo` — the paper's training-procedure descriptor (§III-B).
//!
//! Stores "the batch size, choice of optimization algorithm, loss
//! function, and any tunable training parameters", plus which distributed
//! algorithm runs (Downpour SGD default, Elastic Averaging SGD optional)
//! and whether gradient exchange is asynchronous (default) or synchronous.

use crate::coordinator::planner::RetuneConfig;
use crate::mpi::codec::Codec;
use crate::optim::OptimizerConfig;
use crate::util::json::Json;

/// Distributed training algorithm selection.
#[derive(Clone, Debug, PartialEq)]
pub enum Mode {
    /// Workers send gradients; the master owns weights and the optimizer.
    Downpour {
        /// true: the master applies one averaged update per round after
        /// hearing from every active worker (barrier). false (paper
        /// default): updates apply one-by-one as gradients arrive.
        sync: bool,
    },
    /// Workers train locally; an elastic force pulls worker weights and
    /// the master's center variable together every `tau` batches.
    Easgd {
        /// Exchange period in batches (the paper's "periodically pulls").
        tau: u32,
        /// Elastic force coefficient alpha.
        alpha: f32,
        /// The worker-local optimizer.
        worker_optimizer: OptimizerConfig,
    },
    /// Masterless synchronous data-parallel: every rank computes a
    /// gradient, the world averages them with a chunked ring all-reduce,
    /// and every rank applies an identical optimizer step — no
    /// parameter-server bottleneck (Vishnu et al., HyPar-Flow). Uses
    /// `Algo::optimizer` as the replicated per-rank optimizer.
    AllReduce,
}

/// Full training-procedure configuration.
#[derive(Clone, Debug)]
pub struct Algo {
    pub mode: Mode,
    /// Master-side optimizer (Downpour) — paper default: momentum SGD,
    /// the stale-gradient mitigation of ref [9].
    pub optimizer: OptimizerConfig,
    pub batch_size: usize,
    pub epochs: u32,
    /// Run master-side validation every N master updates (0 = only at the
    /// end). The paper: "the frequency of validation can be adjusted as
    /// needed to minimize its impact on the total training time".
    pub validate_every: u64,
    /// Cap on validation batches per round (0 = whole held-out set).
    pub max_val_batches: usize,
    /// Clip gradients to this global L2 norm (0 = off).
    pub grad_clip: f32,
    /// LR step decay: multiply by `lr_decay` every `lr_decay_every`
    /// master updates (0 = off).
    pub lr_decay: f32,
    pub lr_decay_every: u64,
    /// Wire codec for gradient exchange (`Codec::Fp32` = off). Lossy
    /// codecs compress gradient hops with error feedback; fp16 also
    /// compresses weight replication hops. See `mpi::codec`.
    pub compression: Codec,
    /// All-reduce mode only: launch one all-reduce per layer bucket as
    /// its gradient lands during backprop, overlapping communication
    /// with the rest of the backward pass (DESIGN.md §Layer DAG &
    /// bucketed overlap). Off = one monolithic all-reduce per round.
    pub buckets: bool,
    /// All-reduce mode only: survive rank churn. On a dead neighbor the
    /// surviving ranks agree on the member set, replan the ring, and
    /// resume from replicated weights (DESIGN.md §Elasticity,
    /// docs/RUNBOOK.md).
    pub elastic: bool,
    /// Elastic mode: how long a collective receive may stall before the
    /// peer is suspected dead, and how long membership agreement waits
    /// for survivors to answer probes. Default 30 000 ms.
    pub elastic_timeout_ms: u64,
    /// All-reduce mode only: self-tune the topology at startup. Rank 0
    /// probes the links, calibrates the cost model, and the planner
    /// sweep picks flat-vs-hier, group count, codec, and bucketing
    /// (DESIGN.md §Autotuning). Mutually exclusive with an explicit
    /// hierarchy.
    pub auto: bool,
    /// Auto mode: the online re-tuner triggers when a window's measured
    /// round time exceeds `retune_factor` x the planner's prediction
    /// (plus the probe's noise floor). Default 2.0.
    pub retune_factor: f64,
    /// Auto mode: rounds per re-tuner measurement window. Default 50.
    pub retune_window: u64,
    /// Filled in by the driver's auto phase (never from JSON): the
    /// chosen plan's prediction + trigger knobs the worker-side online
    /// re-tuner runs against. `None` = re-tuner off.
    pub retune: Option<RetuneConfig>,
    /// Compute threads per rank for the native engine's kernel pool
    /// (GEMMs, gate activations, optimizer steps, fp16 codec). `0`
    /// (default) = auto-detect from `available_parallelism`; `1` = the
    /// serial path. Any value trains bitwise-identically (DESIGN.md
    /// §Compute kernels).
    pub threads: usize,
}

impl Default for Algo {
    fn default() -> Self {
        Algo {
            mode: Mode::Downpour { sync: false },
            optimizer: OptimizerConfig::default_momentum(),
            batch_size: 100, // the paper's benchmark batch size
            epochs: 10,      // the paper trains for 10 epochs
            validate_every: 0,
            max_val_batches: 0,
            grad_clip: 0.0,
            lr_decay: 0.0,
            lr_decay_every: 0,
            compression: Codec::Fp32,
            buckets: false,
            elastic: false,
            elastic_timeout_ms: 30_000,
            auto: false,
            retune_factor: 2.0,
            retune_window: 50,
            retune: None,
            threads: 0,
        }
    }
}

impl Algo {
    pub fn downpour_async() -> Self {
        Algo::default()
    }

    pub fn downpour_sync() -> Self {
        Algo { mode: Mode::Downpour { sync: true }, ..Algo::default() }
    }

    pub fn easgd(tau: u32, alpha: f32) -> Self {
        Algo {
            mode: Mode::Easgd {
                tau,
                alpha,
                worker_optimizer: OptimizerConfig::Sgd { lr: 0.05 },
            },
            ..Algo::default()
        }
    }

    pub fn allreduce() -> Self {
        Algo { mode: Mode::AllReduce, ..Algo::default() }
    }

    /// Parse from a config-file JSON object. Unknown `mode` errors.
    pub fn from_json(j: &Json) -> Result<Algo, String> {
        let mut algo = Algo::default();
        if let Some(opt) = j.get("optimizer") {
            algo.optimizer = OptimizerConfig::from_json(opt)
                .ok_or("bad optimizer config")?;
        }
        if let Some(b) = j.get("batch_size").and_then(|v| v.as_usize()) {
            algo.batch_size = b;
        }
        if let Some(e) = j.get("epochs").and_then(|v| v.as_usize()) {
            algo.epochs = e as u32;
        }
        if let Some(v) = j.get("validate_every").and_then(|v| v.as_usize()) {
            algo.validate_every = v as u64;
        }
        if let Some(v) = j.get("max_val_batches").and_then(|v| v.as_usize())
        {
            algo.max_val_batches = v;
        }
        if let Some(c) = j.get("grad_clip").and_then(|v| v.as_f64()) {
            algo.grad_clip = c as f32;
        }
        if let Some(c) = j.get("compression").and_then(|v| v.as_str()) {
            algo.compression = Codec::parse(c)
                .map_err(|e| format!("compression: {e}"))?;
        }
        if let Some(b) = j.get("buckets").and_then(|v| v.as_bool()) {
            algo.buckets = b;
        }
        if let Some(b) = j.get("elastic").and_then(|v| v.as_bool()) {
            algo.elastic = b;
        }
        if let Some(t) = j.get("elastic_timeout_ms")
            .and_then(|v| v.as_usize())
        {
            algo.elastic_timeout_ms = t as u64;
        }
        if let Some(b) = j.get("auto").and_then(|v| v.as_bool()) {
            algo.auto = b;
        }
        if let Some(f) = j.get("retune_factor").and_then(|v| v.as_f64())
        {
            if f <= 1.0 {
                return Err(format!(
                    "\"retune_factor\" must be > 1.0 (got {f}); the \
                     re-tuner triggers on measured > factor x predicted"
                ));
            }
            algo.retune_factor = f;
        }
        if let Some(w) = j.get("retune_window")
            .and_then(|v| v.as_usize())
        {
            if w == 0 {
                return Err("\"retune_window\" must be >= 1 round"
                    .into());
            }
            algo.retune_window = w as u64;
        }
        if let Some(t) = j.get("threads").and_then(|v| v.as_usize()) {
            algo.threads = t; // 0 = auto-detect
        }
        match j.get("mode").and_then(|v| v.as_str()).unwrap_or("downpour") {
            "downpour" => {
                let sync = j.get("sync").and_then(|v| v.as_bool())
                    .unwrap_or(false);
                algo.mode = Mode::Downpour { sync };
            }
            "easgd" => {
                let tau = j.get("tau").and_then(|v| v.as_usize())
                    .unwrap_or(10) as u32;
                let alpha = j.get("alpha").and_then(|v| v.as_f64())
                    .unwrap_or(0.5) as f32;
                let worker_optimizer = j
                    .get("worker_optimizer")
                    .and_then(OptimizerConfig::from_json)
                    .unwrap_or(OptimizerConfig::Sgd { lr: 0.05 });
                algo.mode = Mode::Easgd { tau, alpha, worker_optimizer };
            }
            "allreduce" => algo.mode = Mode::AllReduce,
            other => return Err(format!("unknown mode '{other}'")),
        }
        Ok(algo)
    }

    /// Build the master optimizer (with optional clipping) for `n` params.
    pub fn build_master_optimizer(&self, n: usize)
        -> Box<dyn crate::optim::Optimizer> {
        let base = self.optimizer.build(n);
        if self.grad_clip > 0.0 {
            Box::new(crate::optim::GradClip::new(base, self.grad_clip))
        } else {
            base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let a = Algo::default();
        assert_eq!(a.batch_size, 100);
        assert_eq!(a.epochs, 10);
        assert_eq!(a.mode, Mode::Downpour { sync: false });
    }

    #[test]
    fn json_roundtrip_downpour_sync() {
        let j = Json::parse(
            r#"{"mode": "downpour", "sync": true, "batch_size": 500,
                "optimizer": {"kind": "sgd", "lr": 0.1}}"#).unwrap();
        let a = Algo::from_json(&j).unwrap();
        assert_eq!(a.mode, Mode::Downpour { sync: true });
        assert_eq!(a.batch_size, 500);
        assert_eq!(a.optimizer,
                   crate::optim::OptimizerConfig::Sgd { lr: 0.1 });
    }

    #[test]
    fn json_easgd() {
        let j = Json::parse(
            r#"{"mode": "easgd", "tau": 5, "alpha": 0.25}"#).unwrap();
        let a = Algo::from_json(&j).unwrap();
        match a.mode {
            Mode::Easgd { tau, alpha, .. } => {
                assert_eq!(tau, 5);
                assert!((alpha - 0.25).abs() < 1e-6);
            }
            m => panic!("{m:?}"),
        }
    }

    #[test]
    fn bad_mode_rejected() {
        let j = Json::parse(r#"{"mode": "hogwild"}"#).unwrap();
        assert!(Algo::from_json(&j).is_err());
    }

    #[test]
    fn json_allreduce() {
        let j = Json::parse(
            r#"{"mode": "allreduce",
                "optimizer": {"kind": "sgd", "lr": 0.02}}"#).unwrap();
        let a = Algo::from_json(&j).unwrap();
        assert_eq!(a.mode, Mode::AllReduce);
        assert_eq!(a.optimizer,
                   crate::optim::OptimizerConfig::Sgd { lr: 0.02 });
        assert_eq!(Algo::allreduce().mode, Mode::AllReduce);
    }

    #[test]
    fn clip_wraps_optimizer() {
        let a = Algo { grad_clip: 1.0, ..Algo::default() };
        let opt = a.build_master_optimizer(4);
        assert_eq!(opt.name(), "grad-clip");
    }

    #[test]
    fn json_buckets() {
        assert!(!Algo::default().buckets);
        let j = Json::parse(
            r#"{"mode": "allreduce", "buckets": true}"#).unwrap();
        assert!(Algo::from_json(&j).unwrap().buckets);
        let j = Json::parse(r#"{"mode": "allreduce"}"#).unwrap();
        assert!(!Algo::from_json(&j).unwrap().buckets);
    }

    #[test]
    fn json_threads() {
        assert_eq!(Algo::default().threads, 0); // 0 = auto-detect
        let j = Json::parse(
            r#"{"mode": "allreduce", "threads": 4}"#).unwrap();
        assert_eq!(Algo::from_json(&j).unwrap().threads, 4);
        let j = Json::parse(r#"{"mode": "allreduce"}"#).unwrap();
        assert_eq!(Algo::from_json(&j).unwrap().threads, 0);
    }

    #[test]
    fn json_elastic() {
        let d = Algo::default();
        assert!(!d.elastic);
        assert_eq!(d.elastic_timeout_ms, 30_000);
        let j = Json::parse(
            r#"{"mode": "allreduce", "elastic": true,
                "elastic_timeout_ms": 1500}"#).unwrap();
        let a = Algo::from_json(&j).unwrap();
        assert!(a.elastic);
        assert_eq!(a.elastic_timeout_ms, 1500);
    }

    #[test]
    fn json_auto_and_retune_knobs() {
        let d = Algo::default();
        assert!(!d.auto);
        assert_eq!(d.retune_factor, 2.0);
        assert_eq!(d.retune_window, 50);
        let j = Json::parse(
            r#"{"mode": "allreduce", "auto": true,
                "retune_factor": 3.5, "retune_window": 20}"#).unwrap();
        let a = Algo::from_json(&j).unwrap();
        assert!(a.auto);
        assert_eq!(a.retune_factor, 3.5);
        assert_eq!(a.retune_window, 20);
        // a trigger factor at or below 1.0 would fire on every window
        let j = Json::parse(
            r#"{"mode": "allreduce", "retune_factor": 0.9}"#).unwrap();
        let err = Algo::from_json(&j).unwrap_err();
        assert!(err.contains("retune_factor"), "{err}");
        let j = Json::parse(
            r#"{"mode": "allreduce", "retune_window": 0}"#).unwrap();
        let err = Algo::from_json(&j).unwrap_err();
        assert!(err.contains("retune_window"), "{err}");
    }

    #[test]
    fn json_compression() {
        assert_eq!(Algo::default().compression, Codec::Fp32);
        let j = Json::parse(
            r#"{"mode": "allreduce", "compression": "fp16"}"#).unwrap();
        assert_eq!(Algo::from_json(&j).unwrap().compression, Codec::Fp16);
        let j = Json::parse(r#"{"compression": "topk:0.05"}"#).unwrap();
        assert_eq!(Algo::from_json(&j).unwrap().compression,
                   Codec::TopK { k: 0.05 });
        let j = Json::parse(r#"{"compression": "zip"}"#).unwrap();
        assert!(Algo::from_json(&j).is_err());
    }
}
