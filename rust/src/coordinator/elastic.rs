//! Elastic membership agreement: re-forming the world on rank churn.
//!
//! PR 8 tentpole. When a ring collective aborts (a neighbor timed out,
//! a send failed, or an elastic control message interrupted the round —
//! [`CommError::Interrupted`]), the survivors run the protocol in this
//! module at the next round boundary:
//!
//! 1. **suspect** — any member that detected the failure announces it
//!    to rank 0 (`ElasticSuspect`, stamped with its current epoch).
//! 2. **agree** — rank 0, the membership coordinator, probes every
//!    member of the current plan (`ElasticProbe`) and collects
//!    `ElasticAlive` answers (each carrying the member's completed
//!    update count) within the elastic timeout. Non-responders are
//!    declared dead; pending `ElasticJoin` requests are merged in.
//! 3. **replan** — the survivor set (plus joiners) becomes the next
//!    [`WorldPlan`] generation via [`WorldPlan::replan`] /
//!    [`WorldPlan::replan_grown`]; rank 0 distributes it as an
//!    `ElasticPlan` message stamped with the new epoch.
//! 4. **resume** — every member adopts the plan
//!    ([`Collective::adopt_world`]), the most-advanced survivor
//!    (`sync_root`, ties broken toward the lowest rank) broadcasts its
//!    weights so all replicas restart bitwise-identical, and training
//!    resumes from `resume_update`.
//!
//! Rank 0 is the fixed coordinator: its death ends the job, exactly
//! like a parameter-server master's (documented limitation — see
//! DESIGN.md §Elasticity). The serving pool has a separate, simpler
//! mark-dead path for replicas (DESIGN.md §Serving): replicas are
//! stateless so the pool only stops dispatching to them, while
//! training members share optimizer state and must re-agree on one
//! world.
//!
//! The full state machine (steady → suspect → agree → replan → resume)
//! and the in-flight bucket / error-feedback-residual handling are
//! specified in DESIGN.md §Elasticity; operational guidance (flags,
//! log lines, metrics) is in docs/RUNBOOK.md.

use std::collections::{BTreeMap, BTreeSet};
use std::time::{Duration, Instant};

use crate::coordinator::topology::WorldPlan;
use crate::mpi::collective::Collective;
use crate::mpi::comm::CommError;
use crate::mpi::message::{Envelope, Payload, Rank, Tag};

/// Default window rank 0 waits for `ElasticAlive` answers before
/// declaring non-responders dead (`--elastic-timeout-ms` overrides).
/// Members wait twice this long for the coordinator's plan (one window
/// of collection plus one of distribution slack).
pub const DEFAULT_ELASTIC_TIMEOUT: Duration = Duration::from_secs(30);

/// The agreed next world, as distributed by the coordinator.
#[derive(Clone, Debug, PartialEq)]
pub struct NewWorld {
    /// Generation of the new plan (strictly greater than the old).
    pub epoch: u64,
    /// Surviving members (original rank IDs, ascending, `members[0] ==
    /// 0`).
    pub members: Vec<Rank>,
    /// The member whose weights seed the new world: the most-advanced
    /// survivor, ties broken toward the lowest rank.
    pub sync_root: Rank,
    /// Update count training resumes from (the sync root's).
    pub resume_update: u64,
}

/// What the agreement decided for one member.
#[derive(Clone, Debug, PartialEq)]
pub enum MemberOutcome {
    /// This rank is a member of the new world: adopt it and resume.
    Continue(NewWorld),
    /// This rank was declared dead (e.g. it stalled past the timeout
    /// and answered late). It must stop training cleanly — it may
    /// re-enter later via [`request_join`].
    Evicted,
}

/// Split a u64 into two exactly-representable f32 halves (16 bits
/// each per limb keeps every value < 2^40 exact — far beyond any
/// update count).
fn split_u64(v: u64) -> [f32; 2] {
    [((v >> 16) & 0xFF_FFFF) as f32, (v & 0xFFFF) as f32]
}

fn join_u64(hi: f32, lo: f32) -> u64 {
    ((hi as u64) << 16) | (lo as u64 & 0xFFFF)
}

/// Progress report carried by `ElasticSuspect` / `ElasticAlive`:
/// `[updates_hi, updates_lo]`, generation in the step's high bits.
fn progress_payload(step: u64, completed: u64) -> Payload {
    Payload::floats(step, split_u64(completed).to_vec())
}

fn progress_of(payload: &Payload) -> Option<(u64, u64)> {
    match payload {
        Payload::Floats { step, data } if data.len() == 2 => {
            Some((step >> 32, join_u64(data[0], data[1])))
        }
        _ => None,
    }
}

/// Encode a [`NewWorld`] for the wire: `[n_members, members...,
/// sync_root, resume_hi, resume_lo]`, epoch in the step's high bits.
pub fn encode_plan(w: &NewWorld) -> Payload {
    let mut data = Vec::with_capacity(w.members.len() + 4);
    data.push(w.members.len() as f32);
    data.extend(w.members.iter().map(|&r| r as f32));
    data.push(w.sync_root as f32);
    data.extend_from_slice(&split_u64(w.resume_update));
    Payload::floats(w.epoch << 32, data)
}

pub fn decode_plan(payload: &Payload) -> Result<NewWorld, String> {
    let (step, data) = match payload {
        Payload::Floats { step, data } => (*step, data),
        p => return Err(format!("elastic plan: unexpected payload {p:?}")),
    };
    let n = *data.first().ok_or("elastic plan: empty payload")? as usize;
    if data.len() != n + 4 {
        return Err(format!(
            "elastic plan: expected {} elements for {n} members, got {}",
            n + 4,
            data.len()));
    }
    Ok(NewWorld {
        epoch: step >> 32,
        members: data[1..=n].iter().map(|&f| f as Rank).collect(),
        sync_root: data[n + 1] as Rank,
        resume_update: join_u64(data[n + 2], data[n + 3]),
    })
}

/// Rank 0's half of the agreement: probe the current members, collect
/// answers for up to `timeout`, fold in pending joiners, replan, and
/// distribute the result. Returns the agreed [`NewWorld`] (rank 0 then
/// adopts it like every other member).
///
/// `completed` is rank 0's own completed-update count; it participates
/// in the `sync_root` election like any survivor's.
pub fn coordinate(col: &mut Collective, plan: &WorldPlan,
                  completed: u64, timeout: Duration)
    -> Result<NewWorld, String> {
    let me = col.comm().rank();
    if me != 0 {
        return Err(format!(
            "rank {me} cannot coordinate membership (rank 0 does)"));
    }
    let epoch = col.epoch();
    let members: Vec<Rank> = match col.members() {
        Some(m) => m.to_vec(),
        None => (0..col.comm().size()).collect(),
    };

    // Progress per live member; joiners (incl. evicted ranks that
    // resurfaced) are re-admitted with zero credit for the election.
    let mut alive: BTreeMap<Rank, u64> = BTreeMap::new();
    alive.insert(me, completed);
    let mut joiners: BTreeSet<Rank> =
        col.pending_joiners().into_iter().collect();
    let mut record = |alive: &mut BTreeMap<Rank, u64>,
                      joiners: &mut BTreeSet<Rank>,
                      env: &Envelope| {
        if let Some((gen, updates)) = progress_of(&env.payload) {
            if gen >= epoch && members.contains(&env.src) {
                alive.insert(env.src, updates);
            } else if gen >= epoch {
                joiners.insert(env.src); // evicted straggler re-admits
            }
        }
    };

    // Suspect announcements that interrupted rank 0's own collective
    // are already in the stash — they count as answers.
    let stashed: Vec<Envelope> = {
        let stash = col.stash_mut();
        let mut taken = Vec::new();
        stash.retain(|e| {
            if matches!(e.tag, Tag::ElasticSuspect | Tag::ElasticAlive) {
                taken.push(e.clone());
                false
            } else {
                true
            }
        });
        taken
    };
    for env in &stashed {
        record(&mut alive, &mut joiners, env);
    }

    for &r in &members {
        if r == me {
            continue;
        }
        let probe = Payload::floats(epoch << 32, vec![]);
        if col.comm().send(r, Tag::ElasticProbe, probe).is_err() {
            // endpoint already dead: no point waiting for its answer
            col.comm().close_peer(r);
        }
    }

    let deadline = Instant::now() + timeout;
    while alive.len() < members.len() {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match col.comm().recv_timeout(deadline - now) {
            Ok(env) => match env.tag {
                Tag::ElasticSuspect | Tag::ElasticAlive => {
                    record(&mut alive, &mut joiners, &env);
                }
                Tag::ElasticJoin => {
                    joiners.insert(env.src);
                }
                Tag::ElasticProbe | Tag::ElasticPlan => {
                    // only rank 0 emits these; a stray copy is stale
                }
                _ => col.stash_mut().push(env),
            },
            Err(CommError::Timeout(_)) => break,
            Err(e) => {
                return Err(format!("membership agreement: {e}"));
            }
        }
    }

    let survivors: Vec<Rank> = alive.keys().copied().collect();
    let joiners: Vec<Rank> = joiners
        .into_iter()
        .filter(|&r| r < col.comm().size() && !alive.contains_key(&r))
        .collect();
    let mut next = plan
        .replan(&survivors)
        .map_err(|e| format!("replan after churn: {e}"))?;
    if !joiners.is_empty() {
        next = next
            .replan_grown(&joiners)
            .map_err(|e| format!("replan (scale-up): {e}"))?;
    }

    let (&sync_root, &resume_update) = alive
        .iter()
        .max_by_key(|&(&r, &u)| (u, std::cmp::Reverse(r)))
        .expect("alive always contains rank 0");
    let new_members = next
        .members()
        .expect("replanned plans always carry a member list")
        .to_vec();
    log::info!(
        "elastic: epoch {} -> {}: members {:?} (of {:?}), joiners \
         {:?}, sync root {} at update {}",
        epoch, next.epoch(), new_members, members, joiners, sync_root,
        resume_update);

    let world = NewWorld {
        epoch: next.epoch(),
        members: new_members,
        sync_root,
        resume_update,
    };
    let payload = encode_plan(&world);
    for &r in &world.members {
        if r != me
            && col.comm().send(r, Tag::ElasticPlan, payload.clone())
                .is_err()
        {
            // died between probe and plan: the next round's failure
            // detection replans again from this generation
            log::warn!("elastic: plan delivery to rank {r} failed");
        }
    }
    for &r in &members {
        if !world.members.contains(&r) {
            col.comm().close_peer(r); // drop the dead peer's endpoint
        }
    }
    Ok(world)
}

/// A member's half of the agreement: optionally announce the suspected
/// failure (`announce` — set when this rank detected it itself, rather
/// than being interrupted by a control message), answer probes, and
/// wait up to `2 * timeout` for the coordinator's plan.
///
/// Probe answers echo the PROBE's generation stamp, not this rank's —
/// a member still catching up on a previous replan must not have its
/// answer discarded as stale.
pub fn await_plan(col: &mut Collective, completed: u64,
                  timeout: Duration, announce: bool)
    -> Result<MemberOutcome, String> {
    let me = col.comm().rank();
    let epoch = col.epoch();
    if announce {
        // best-effort: if rank 0 is the dead one, the job is over and
        // the deadline below surfaces that
        let _ = col.comm().send(
            0,
            Tag::ElasticSuspect,
            progress_payload(epoch << 32, completed),
        );
    }
    let deadline = Instant::now() + timeout.saturating_mul(2);
    loop {
        let env = next_control(col, deadline)?;
        match env.tag {
            Tag::ElasticProbe => {
                if let Payload::Floats { step, .. } = env.payload {
                    let _ = col.comm().send(
                        env.src,
                        Tag::ElasticAlive,
                        progress_payload(step, completed),
                    );
                }
            }
            Tag::ElasticPlan => {
                let world = decode_plan(&env.payload)?;
                if world.epoch <= epoch {
                    continue; // stale plan from a superseded agreement
                }
                return Ok(if world.members.contains(&me) {
                    MemberOutcome::Continue(world)
                } else {
                    MemberOutcome::Evicted
                });
            }
            _ => unreachable!("next_control filters tags"),
        }
    }
}

/// A joiner's entry point: announce to rank 0 and wait for a plan that
/// admits this rank. The join is only folded in at rank 0's next
/// agreement (a round boundary with pending joiners, or the next
/// churn), so `timeout` here should cover several training rounds —
/// not the per-agreement elastic timeout.
pub fn request_join(col: &mut Collective, timeout: Duration)
    -> Result<NewWorld, String> {
    let me = col.comm().rank();
    col.comm()
        .send(0, Tag::ElasticJoin, Payload::floats(0, vec![]))
        .map_err(|e| format!("join request: {e}"))?;
    let deadline = Instant::now() + timeout;
    loop {
        let env = next_control(col, deadline)?;
        match env.tag {
            Tag::ElasticProbe => {
                // being probed means a concurrent agreement is running;
                // answering admits us as a zero-credit survivor
                if let Payload::Floats { step, .. } = env.payload {
                    let _ = col.comm().send(
                        env.src,
                        Tag::ElasticAlive,
                        progress_payload(step, 0),
                    );
                }
            }
            Tag::ElasticPlan => {
                let world = decode_plan(&env.payload)?;
                if world.members.contains(&me) {
                    return Ok(world);
                }
                // a churn-only replan that predates our join: wait on
            }
            _ => unreachable!("next_control filters tags"),
        }
    }
}

/// Next membership-control envelope: the collective stash first (a
/// control message that interrupted a round was parked there), then
/// the wire. Everything else is stashed for the post-recovery
/// generation screen.
fn next_control(col: &mut Collective, deadline: Instant)
    -> Result<Envelope, String> {
    let timed_out = || -> String {
        "membership agreement timed out waiting for the \
         coordinator's plan (is rank 0 alive? rank 0's death ends \
         the job — see docs/RUNBOOK.md)"
            .into()
    };
    if let Some(i) = col.stash_mut().iter().position(|e| {
        matches!(e.tag, Tag::ElasticProbe | Tag::ElasticPlan)
    }) {
        return Ok(col.stash_mut().remove(i));
    }
    loop {
        let now = Instant::now();
        if now >= deadline {
            return Err(timed_out());
        }
        match col.comm().recv_timeout(deadline - now) {
            Ok(env) => match env.tag {
                Tag::ElasticProbe | Tag::ElasticPlan => return Ok(env),
                Tag::ElasticSuspect | Tag::ElasticAlive
                | Tag::ElasticJoin => {
                    // coordinator-bound traffic; not ours to keep
                }
                _ => col.stash_mut().push(env),
            },
            Err(CommError::Timeout(_)) => return Err(timed_out()),
            Err(e) => return Err(format!("membership agreement: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::algo::Mode;
    use crate::mpi::transport::inproc;

    const T: Duration = Duration::from_millis(400);

    fn ring_plan(n: usize) -> WorldPlan {
        WorldPlan::from_parts(&Mode::AllReduce, None, n, 7).unwrap()
    }

    #[test]
    fn plan_payload_roundtrip() {
        let w = NewWorld {
            epoch: 3,
            members: vec![0, 2, 5],
            sync_root: 2,
            resume_update: 123_456_789,
        };
        let p = encode_plan(&w);
        match &p {
            Payload::Floats { step, .. } => assert_eq!(step >> 32, 3),
            p => panic!("unexpected {p:?}"),
        }
        assert_eq!(decode_plan(&p).unwrap(), w);
        assert!(decode_plan(&Payload::Empty).is_err());
        assert!(decode_plan(&Payload::floats(0, vec![9.0])).is_err());
    }

    #[test]
    fn progress_roundtrip_is_exact_beyond_f32_integers() {
        let updates = (1u64 << 25) + 3; // not exactly representable
        let p = progress_payload(5 << 32, updates);
        assert_eq!(progress_of(&p), Some((5, updates)));
    }

    #[test]
    fn agreement_declares_silent_rank_dead() {
        let mut world = inproc::world(4);
        let c3 = world.pop().unwrap();
        let c2 = world.pop().unwrap();
        let c1 = world.pop().unwrap();
        let c0 = world.pop().unwrap();
        drop(c3); // rank 3 "crashed" before the agreement

        let members = std::thread::scope(|s| {
            let h1 = s.spawn(|| {
                let mut col = Collective::new(&c1);
                col.set_elastic(true);
                // rank 1 detected the failure itself: it announces
                await_plan(&mut col, 11, T, true).unwrap()
            });
            let h2 = s.spawn(|| {
                let mut col = Collective::new(&c2);
                col.set_elastic(true);
                await_plan(&mut col, 12, T, false).unwrap()
            });
            let mut col = Collective::new(&c0);
            col.set_elastic(true);
            let plan = ring_plan(4);
            let world =
                coordinate(&mut col, &plan, 5, T).unwrap();
            (world, h1.join().unwrap(), h2.join().unwrap())
        });

        let (world, m1, m2) = members;
        assert_eq!(world.epoch, 1);
        assert_eq!(world.members, vec![0, 1, 2]);
        // rank 2 is the most advanced survivor
        assert_eq!(world.sync_root, 2);
        assert_eq!(world.resume_update, 12);
        assert_eq!(m1, MemberOutcome::Continue(world.clone()));
        assert_eq!(m2, MemberOutcome::Continue(world));
        // the dead peer's endpoint is gone on the coordinator
        assert!(!c0.has_peer(3));
    }

    #[test]
    fn joiner_is_admitted_at_the_next_agreement() {
        let mut world = inproc::world(3);
        let c2 = world.pop().unwrap();
        let c1 = world.pop().unwrap();
        let c0 = world.pop().unwrap();

        // current generation: only {0, 1} train, rank 2 idles
        let base = ring_plan(3);
        let plan = base.replan(&[0, 1]).unwrap();
        assert_eq!(plan.epoch(), 1);

        // the join request is already queued before the agreement
        // starts, so the test is deterministic
        c2.send(0, Tag::ElasticJoin, Payload::floats(0, vec![]))
            .unwrap();

        let (world, m1) = std::thread::scope(|s| {
            let h1 = s.spawn(|| {
                let mut col = Collective::new(&c1);
                col.adopt_world(1, Some(vec![0, 1]));
                await_plan(&mut col, 20, T, false).unwrap()
            });
            let mut col = Collective::new(&c0);
            col.adopt_world(1, Some(vec![0, 1]));
            let w = coordinate(&mut col, &plan, 20, T).unwrap();
            (w, h1.join().unwrap())
        });

        // replan (epoch 2) then replan_grown (epoch 3)
        assert_eq!(world.epoch, 3);
        assert_eq!(world.members, vec![0, 1, 2]);
        // tie at 20 updates -> lowest rank wins the election
        assert_eq!(world.sync_root, 0);
        assert_eq!(world.resume_update, 20);
        assert_eq!(m1, MemberOutcome::Continue(world.clone()));

        // the joiner's plan is already in its queue: request_join
        // re-announces (harmless) and picks it up
        let mut col = Collective::new(&c2);
        assert_eq!(request_join(&mut col, T).unwrap(), world);
    }

    #[test]
    fn member_excluded_from_the_plan_is_evicted() {
        let mut world = inproc::world(2);
        let c1 = world.pop().unwrap();
        let c0 = world.pop().unwrap();
        let w = NewWorld {
            epoch: 1,
            members: vec![0],
            sync_root: 0,
            resume_update: 9,
        };
        c0.send(1, Tag::ElasticPlan, encode_plan(&w)).unwrap();
        let mut col = Collective::new(&c1);
        assert_eq!(await_plan(&mut col, 4, T, false).unwrap(),
                   MemberOutcome::Evicted);
    }

    #[test]
    fn stale_plans_are_ignored() {
        let mut world = inproc::world(2);
        let c1 = world.pop().unwrap();
        let c0 = world.pop().unwrap();
        // rank 1 already sits at epoch 2: an epoch-1 plan is stale,
        // the later epoch-3 plan wins
        let old = NewWorld { epoch: 1, members: vec![0, 1],
                             sync_root: 0, resume_update: 1 };
        let new = NewWorld { epoch: 3, members: vec![0, 1],
                             sync_root: 1, resume_update: 8 };
        c0.send(1, Tag::ElasticPlan, encode_plan(&old)).unwrap();
        c0.send(1, Tag::ElasticPlan, encode_plan(&new)).unwrap();
        let mut col = Collective::new(&c1);
        col.adopt_world(2, Some(vec![0, 1]));
        assert_eq!(await_plan(&mut col, 4, T, false).unwrap(),
                   MemberOutcome::Continue(new));
    }
}
