//! Config-file driven training: the full [`TrainConfig`] (+ data source)
//! from a JSON document, so cluster jobs are launched from versioned
//! config files rather than flag soup — `mpi-learn train --config
//! configs/hep_lstm.json`.
//!
//! Schema (all keys optional unless marked):
//! ```json
//! {
//!   "model": "lstm",            // REQUIRED artifact family
//!   "batch": 100,
//!   "workers": 4,
//!   "seed": 2017,
//!   "transport": "inproc" | {"tcp": {"base_port": 47000}},
//!   "hierarchy": {"groups": 2, "workers_per_group": 2,
//!                 "sync_every": 5},
//!                 // groups >= 2. With "mode": "downpour" this is the
//!                 // two-level master tree; with "mode": "allreduce"
//!                 // it selects the hierarchical all-reduce (intra-
//!                 // group ring + inter-group leader tree;
//!                 // "sync_every" is ignored there)
//!   "algo": { ... see Algo::from_json; "mode" may be "downpour",
//!             "easgd", or "allreduce" (masterless ring) ... },
//!   "compression": "fp32" | "fp16" | "topk:<k>",  // wire codec for
//!                               // gradient exchange (see mpi::codec;
//!                               // also accepted inside "algo")
//!   "buckets": true,            // allreduce mode: per-layer bucketed
//!                               // all-reduce overlapped with backprop
//!                               // (also accepted inside "algo")
//!   "elastic": true,            // allreduce mode: survive rank churn —
//!                               // replan the ring over survivors and
//!                               // resume (DESIGN.md §Elasticity,
//!                               // docs/RUNBOOK.md; also inside "algo")
//!   "elastic_timeout_ms": 30000, // suspicion + agreement window
//!   "threads": 0,               // compute threads per rank for the
//!                               // native kernel pool; 0 = auto-detect
//!                               // (bitwise-identical at any value;
//!                               // also accepted inside "algo")
//!   "callbacks": [              // observer-side training callbacks
//!     {"kind": "early_stopping", "patience": 3, "min_delta": 0.0},
//!     {"kind": "checkpoint", "dir": "runs/ckpt", "every": 100,
//!      "best_only": true},
//!     {"kind": "lr_schedule", "schedule": "step"|"exponential",
//!      "gamma": 0.5, "every": 200},
//!     {"kind": "jsonl", "path": "runs/metrics.jsonl"}
//!   ],
//!   "data": {"dir": "data/hep"}                    // file-sharded
//!         | {"synthetic": {"samples_per_worker": 2000,
//!                          "val_samples": 1000,
//!                          "separation": 0.6, "noise": 1.0,
//!                          "seed": 2017}}
//! }
//! ```
//!
//! Contradictory configurations (e.g. `"hierarchy"` with one group, or
//! with `"mode": "easgd"`) are rejected here, at parse time, with a
//! `ConfigError::Invalid` that names the offending KEYS — not deep
//! inside `train()` after data materialization. The checks are
//! `WorldPlan`'s, so programmatic `TrainConfig` users get the identical
//! validation.

use std::path::{Path, PathBuf};

use crate::coordinator::algo::Algo;
use crate::coordinator::builder::{Data, ModelBuilder};
use crate::coordinator::callbacks::CallbackSpec;
use crate::coordinator::driver::{TrainConfig, Transport};
use crate::coordinator::hierarchy::HierarchySpec;
use crate::coordinator::topology::WorldPlan;
use crate::data::{list_train_files, GeneratorConfig};
use crate::util::json::Json;

#[derive(Debug)]
pub enum ConfigError {
    Io(PathBuf, std::io::Error),
    Parse(String),
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Io(path, err) => {
                write!(f, "io reading {}: {err}", path.display())
            }
            ConfigError::Parse(msg) => write!(f, "parse: {msg}"),
            ConfigError::Invalid(msg) => write!(f, "config: {msg}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Read and parse a JSON config file with uniform `ConfigError`
/// classification — the shared front half of every `from_file`
/// (training jobs here, [`crate::serving::ServeConfig`] for the
/// inference front-end).
pub fn load_json(path: &Path) -> Result<Json, ConfigError> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| ConfigError::Io(path.to_path_buf(), e))?;
    Json::parse(&text).map_err(|e| ConfigError::Parse(e.to_string()))
}

/// A fully-resolved training job description.
pub struct JobConfig {
    pub train: TrainConfig,
    pub data: Data,
}

impl JobConfig {
    pub fn from_file(path: &Path) -> Result<JobConfig, ConfigError> {
        Self::from_json(&load_json(path)?)
    }

    pub fn from_json_text(text: &str) -> Result<JobConfig, ConfigError> {
        let j = Json::parse(text)
            .map_err(|e| ConfigError::Parse(e.to_string()))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<JobConfig, ConfigError> {
        let invalid = |m: String| ConfigError::Invalid(m);

        let model = j
            .get("model")
            .and_then(|v| v.as_str())
            .ok_or_else(|| invalid("'model' is required".into()))?
            .to_string();
        let batch = j.get("batch").and_then(|v| v.as_usize())
            .unwrap_or(100);
        let workers = j.get("workers").and_then(|v| v.as_usize())
            .unwrap_or(1);
        let seed = j.get("seed").and_then(|v| v.as_i64()).unwrap_or(2017)
            as u64;

        let mut algo = match j.get("algo") {
            Some(a) => Algo::from_json(a).map_err(
                |e| invalid(format!("algo: {e}")))?,
            None => Algo::default(),
        };
        // batch lives at top level (it selects the artifact); keep the
        // algo consistent
        algo.batch_size = batch;

        // compression may sit at top level (alongside model/workers)
        // or inside "algo"; top level wins when both are given
        if let Some(c) = j.get("compression").and_then(|v| v.as_str()) {
            algo.compression = crate::mpi::codec::Codec::parse(c)
                .map_err(|e| invalid(format!("compression: {e}")))?;
        }

        // buckets mirrors compression: top level or inside "algo"
        if let Some(b) = j.get("buckets").and_then(|v| v.as_bool()) {
            algo.buckets = b;
        }

        // elastic knobs mirror buckets: top level or inside "algo"
        if let Some(b) = j.get("elastic").and_then(|v| v.as_bool()) {
            algo.elastic = b;
        }
        if let Some(t) = j.get("elastic_timeout_ms")
            .and_then(|v| v.as_usize())
        {
            algo.elastic_timeout_ms = t as u64;
        }
        if algo.elastic
            && !matches!(algo.mode,
                         crate::coordinator::algo::Mode::AllReduce)
        {
            return Err(invalid(
                "\"elastic\" requires \"mode\": \"allreduce\" (PS \
                 masters tolerate departing workers natively)"
                    .into()));
        }

        // compute threads mirror buckets: top level or inside "algo".
        // 0 = auto-detect; any value trains bitwise-identically, so
        // there is no mode restriction.
        if let Some(t) = j.get("threads").and_then(|v| v.as_usize()) {
            algo.threads = t;
        }

        // "auto" mirrors elastic: top level or inside "algo", only
        // meaningful for the lockstep collective the planner tunes
        if let Some(b) = j.get("auto").and_then(|v| v.as_bool()) {
            algo.auto = b;
        }
        if algo.auto
            && !matches!(algo.mode,
                         crate::coordinator::algo::Mode::AllReduce)
        {
            return Err(invalid(
                "\"auto\" requires \"mode\": \"allreduce\" — the \
                 planner tunes ring topologies (flat vs grouped, \
                 buckets, codec); PS modes have no topology sweep"
                    .into()));
        }
        if algo.auto && j.get("hierarchy").is_some() {
            return Err(invalid(
                "\"auto\" and \"hierarchy\" are mutually exclusive: \
                 drop \"hierarchy\" to let the planner pick the \
                 grouping, or drop \"auto\" to pin it"
                    .into()));
        }

        let transport = match j.get("transport") {
            None => Transport::Inproc,
            Some(t) if t.as_str() == Some("inproc") => Transport::Inproc,
            Some(t) => match t.get("tcp") {
                Some(tcp) => Transport::Tcp {
                    base_port: tcp
                        .get("base_port")
                        .and_then(|v| v.as_usize())
                        .unwrap_or(47000) as u16,
                },
                None => {
                    return Err(invalid(format!(
                        "unknown transport {t}")))
                }
            },
        };

        let hierarchy = match j.get("hierarchy") {
            None => None,
            Some(h) => {
                let groups = h.get("groups").and_then(|v| v.as_usize())
                    .ok_or_else(|| invalid(
                        "\"hierarchy\" requires \"groups\" (>= 2)"
                            .into()))?;
                // Absent "workers_per_group": in allreduce mode pass 0
                // so WorldPlan derives the split from "workers" AND
                // validates divisibility — the integer-division default
                // below would silently shrink a non-divisible world.
                // (Downpour keeps the historical floor default; an
                // explicit workers_per_group always wins over
                // "workers", as documented on TrainConfig.)
                let derive = matches!(
                    algo.mode, crate::coordinator::algo::Mode::AllReduce);
                let wpg = h
                    .get("workers_per_group")
                    .and_then(|v| v.as_usize())
                    .unwrap_or_else(|| if derive { 0 }
                                    else { workers / groups.max(1) });
                Some(HierarchySpec {
                    n_groups: groups,
                    workers_per_group: wpg,
                    sync_every: h.get("sync_every")
                        .and_then(|v| v.as_usize()).unwrap_or(10) as u64,
                })
            }
        };

        // reject contradictory topology/mode combinations NOW, with
        // the same checks the driver's WorldPlan applies
        WorldPlan::from_parts(&algo.mode, hierarchy, workers, seed)
            .map_err(invalid)?;

        let callbacks = match j.get("callbacks") {
            None => Vec::new(),
            Some(c) => CallbackSpec::parse_list(c)
                .map_err(|e| invalid(format!("callbacks: {e}")))?,
        };

        let data = match j.get("data") {
            None => Data::Synthetic {
                gen: GeneratorConfig::default(),
                samples_per_worker: 2000,
                val_samples: 1000,
            },
            Some(d) => {
                if let Some(dir) = d.get("dir").and_then(|v| v.as_str()) {
                    let dir = PathBuf::from(dir);
                    let train = list_train_files(&dir).map_err(
                        |e| ConfigError::Io(dir.clone(), e))?;
                    if train.is_empty() {
                        return Err(invalid(format!(
                            "no train_*.mpil shards in {}",
                            dir.display())));
                    }
                    Data::Files { train, val: dir.join("val.mpil") }
                } else if let Some(s) = d.get("synthetic") {
                    let f32_of = |key: &str, dflt: f32| {
                        s.get(key).and_then(|v| v.as_f64())
                            .map(|v| v as f32).unwrap_or(dflt)
                    };
                    Data::Synthetic {
                        gen: GeneratorConfig {
                            separation: f32_of("separation", 0.6),
                            noise: f32_of("noise", 1.0),
                            seed: s.get("seed").and_then(|v| v.as_i64())
                                .unwrap_or(2017) as u64,
                            ..Default::default()
                        },
                        samples_per_worker: s
                            .get("samples_per_worker")
                            .and_then(|v| v.as_usize())
                            .unwrap_or(2000),
                        val_samples: s.get("val_samples")
                            .and_then(|v| v.as_usize()).unwrap_or(1000),
                    }
                } else {
                    return Err(invalid(
                        "data needs 'dir' or 'synthetic'".into()));
                }
            }
        };

        Ok(JobConfig {
            train: TrainConfig {
                builder: ModelBuilder::new(&model, batch),
                algo,
                n_workers: workers,
                seed,
                transport,
                hierarchy,
                callbacks,
            },
            data,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::algo::Mode;

    #[test]
    fn minimal_config() {
        let job = JobConfig::from_json_text(r#"{"model": "lstm"}"#)
            .unwrap();
        assert_eq!(job.train.builder.variant_key(), "lstm_b100");
        assert_eq!(job.train.n_workers, 1);
        assert_eq!(job.train.transport, Transport::Inproc);
        assert!(matches!(job.data, Data::Synthetic { .. }));
    }

    #[test]
    fn full_config() {
        let text = r#"{
            "model": "lstm", "batch": 500, "workers": 6, "seed": 9,
            "transport": {"tcp": {"base_port": 48123}},
            "hierarchy": {"groups": 2, "sync_every": 7},
            "algo": {"mode": "downpour", "sync": true, "epochs": 3,
                     "optimizer": {"kind": "adam", "lr": 0.002}},
            "callbacks": [{"kind": "early_stopping", "patience": 2},
                          {"kind": "jsonl", "path": "m.jsonl"}],
            "data": {"synthetic": {"samples_per_worker": 500,
                                   "val_samples": 100,
                                   "separation": 0.3}}
        }"#;
        let job = JobConfig::from_json_text(text).unwrap();
        assert_eq!(job.train.builder.variant_key(), "lstm_b500");
        assert_eq!(job.train.algo.batch_size, 500);
        assert_eq!(job.train.algo.epochs, 3);
        assert_eq!(job.train.algo.mode, Mode::Downpour { sync: true });
        assert_eq!(job.train.transport,
                   Transport::Tcp { base_port: 48123 });
        let h = job.train.hierarchy.unwrap();
        assert_eq!(h.n_groups, 2);
        assert_eq!(h.workers_per_group, 3);
        assert_eq!(h.sync_every, 7);
        assert_eq!(job.train.callbacks.len(), 2);
        assert!(matches!(
            job.train.callbacks[0],
            crate::coordinator::callbacks::CallbackSpec::EarlyStopping {
                patience: 2, .. }));
        match job.data {
            Data::Synthetic { gen, samples_per_worker, val_samples } => {
                assert_eq!(samples_per_worker, 500);
                assert_eq!(val_samples, 100);
                assert!((gen.separation - 0.3).abs() < 1e-6);
            }
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn easgd_config() {
        let job = JobConfig::from_json_text(
            r#"{"model": "lstm", "workers": 4,
                "algo": {"mode": "easgd", "tau": 4, "alpha": 0.25}}"#)
            .unwrap();
        assert!(matches!(job.train.algo.mode,
                         Mode::Easgd { tau: 4, .. }));
    }

    /// ISSUE 4 tentpole: allreduce + hierarchy is now a valid config —
    /// it selects the hierarchical all-reduce topology.
    #[test]
    fn allreduce_with_hierarchy_parses_to_grouped_plan() {
        let text = r#"{
            "model": "mlp", "workers": 4,
            "algo": {"mode": "allreduce"},
            "hierarchy": {"groups": 2, "workers_per_group": 2}
        }"#;
        let job = JobConfig::from_json_text(text).unwrap();
        assert_eq!(job.train.algo.mode, Mode::AllReduce);
        let plan = WorldPlan::new(&job.train).unwrap();
        assert_eq!(plan.world_size(), 4, "masterless grouped world");
        let layout = plan.ring_layout().unwrap();
        assert_eq!(layout.leaders(), vec![0, 2]);
    }

    /// Satellite (ISSUE 4): rejected topology combos must name the
    /// offending KEYS, not just the mode.
    #[test]
    fn bad_hierarchy_errors_name_the_keys() {
        // one group
        let text = r#"{
            "model": "mlp", "workers": 4,
            "algo": {"mode": "allreduce"},
            "hierarchy": {"groups": 1, "workers_per_group": 4}
        }"#;
        match JobConfig::from_json_text(text) {
            Err(super::ConfigError::Invalid(msg)) => {
                assert!(msg.contains("\"groups\" >= 2"), "{msg}");
                assert!(msg.contains("\"hierarchy\""), "{msg}");
            }
            other => panic!("expected Invalid, got {:?}",
                            other.err().map(|e| e.to_string())),
        }
        // missing groups key
        let text = r#"{"model": "mlp", "hierarchy": {}}"#;
        match JobConfig::from_json_text(text) {
            Err(super::ConfigError::Invalid(msg)) => {
                assert!(msg.contains("\"groups\""), "{msg}");
            }
            other => panic!("expected Invalid, got {:?}",
                            other.err().map(|e| e.to_string())),
        }
        // zero workers per group
        let text = r#"{
            "model": "mlp",
            "hierarchy": {"groups": 2, "workers_per_group": 0}
        }"#;
        match JobConfig::from_json_text(text) {
            Err(super::ConfigError::Invalid(msg)) => {
                assert!(msg.contains("\"workers_per_group\""), "{msg}");
            }
            other => panic!("expected Invalid, got {:?}",
                            other.err().map(|e| e.to_string())),
        }
        // grouped allreduce with an absent workers_per_group and a
        // non-divisible worker count must ERROR (naming the keys), not
        // silently train a smaller world
        let text = r#"{
            "model": "mlp", "workers": 7,
            "algo": {"mode": "allreduce"},
            "hierarchy": {"groups": 2}
        }"#;
        match JobConfig::from_json_text(text) {
            Err(super::ConfigError::Invalid(msg)) => {
                assert!(msg.contains("\"workers\"")
                            && msg.contains("\"groups\""),
                        "{msg}");
            }
            other => panic!("expected Invalid, got {:?}",
                            other.err().map(|e| e.to_string())),
        }
        // ...while a divisible count derives the split cleanly
        let job = JobConfig::from_json_text(r#"{
            "model": "mlp", "workers": 8,
            "algo": {"mode": "allreduce"},
            "hierarchy": {"groups": 2}
        }"#).unwrap();
        let plan = WorldPlan::new(&job.train).unwrap();
        assert_eq!(plan.world_size(), 8);
    }

    #[test]
    fn easgd_with_hierarchy_rejected_at_parse_time() {
        // the group-master loop only speaks Downpour; reject early
        let text = r#"{
            "model": "mlp",
            "algo": {"mode": "easgd"},
            "hierarchy": {"groups": 2, "workers_per_group": 2}
        }"#;
        assert!(matches!(JobConfig::from_json_text(text),
                         Err(super::ConfigError::Invalid(_))));
    }

    #[test]
    fn bad_callbacks_rejected() {
        let text = r#"{"model": "mlp",
                       "callbacks": [{"kind": "bogus"}]}"#;
        assert!(JobConfig::from_json_text(text).is_err());
        let text = r#"{"model": "mlp", "callbacks": {"kind": "jsonl"}}"#;
        assert!(JobConfig::from_json_text(text).is_err(),
                "callbacks must be an array");
    }

    #[test]
    fn allreduce_mode_config() {
        let job = JobConfig::from_json_text(
            r#"{"model": "mlp", "workers": 4,
                "algo": {"mode": "allreduce"}}"#).unwrap();
        assert_eq!(job.train.algo.mode, Mode::AllReduce);
        assert_eq!(job.train.n_workers, 4);
    }

    #[test]
    fn compression_config() {
        use crate::mpi::codec::Codec;
        // top-level key
        let job = JobConfig::from_json_text(
            r#"{"model": "mlp", "compression": "fp16"}"#).unwrap();
        assert_eq!(job.train.algo.compression, Codec::Fp16);
        // inside "algo"
        let job = JobConfig::from_json_text(
            r#"{"model": "mlp",
                "algo": {"mode": "allreduce",
                         "compression": "topk:0.1"}}"#).unwrap();
        assert_eq!(job.train.algo.compression, Codec::TopK { k: 0.1 });
        // top level wins over "algo"
        let job = JobConfig::from_json_text(
            r#"{"model": "mlp", "compression": "fp16",
                "algo": {"compression": "topk:0.5"}}"#).unwrap();
        assert_eq!(job.train.algo.compression, Codec::Fp16);
        // default + bad values
        let job = JobConfig::from_json_text(r#"{"model": "mlp"}"#)
            .unwrap();
        assert_eq!(job.train.algo.compression, Codec::Fp32);
        assert!(matches!(
            JobConfig::from_json_text(
                r#"{"model": "mlp", "compression": "gzip"}"#),
            Err(ConfigError::Invalid(_))));
    }

    #[test]
    fn buckets_config() {
        // top-level key
        let job = JobConfig::from_json_text(
            r#"{"model": "mlp", "workers": 4, "buckets": true,
                "algo": {"mode": "allreduce"}}"#).unwrap();
        assert!(job.train.algo.buckets);
        // inside "algo"
        let job = JobConfig::from_json_text(
            r#"{"model": "mlp", "workers": 4,
                "algo": {"mode": "allreduce", "buckets": true}}"#)
            .unwrap();
        assert!(job.train.algo.buckets);
        // default off
        let job = JobConfig::from_json_text(r#"{"model": "mlp"}"#)
            .unwrap();
        assert!(!job.train.algo.buckets);
    }

    #[test]
    fn elastic_config() {
        // top-level keys
        let job = JobConfig::from_json_text(
            r#"{"model": "mlp", "workers": 4, "elastic": true,
                "elastic_timeout_ms": 2000,
                "algo": {"mode": "allreduce"}}"#).unwrap();
        assert!(job.train.algo.elastic);
        assert_eq!(job.train.algo.elastic_timeout_ms, 2000);
        // inside "algo"
        let job = JobConfig::from_json_text(
            r#"{"model": "mlp", "workers": 4,
                "algo": {"mode": "allreduce", "elastic": true}}"#)
            .unwrap();
        assert!(job.train.algo.elastic);
        assert_eq!(job.train.algo.elastic_timeout_ms, 30_000);
        // default off
        let job = JobConfig::from_json_text(r#"{"model": "mlp"}"#)
            .unwrap();
        assert!(!job.train.algo.elastic);
        // contradictory: elastic only makes sense for the lockstep
        // collective — PS masters shrink their barriers natively
        match JobConfig::from_json_text(
            r#"{"model": "mlp", "workers": 4, "elastic": true,
                "algo": {"mode": "downpour"}}"#)
        {
            Err(super::ConfigError::Invalid(msg)) => {
                assert!(msg.contains("elastic")
                        && msg.contains("allreduce"),
                        "error must name the keys: {msg}");
            }
            other => panic!("expected Invalid, got {:?}",
                            other.map(|_| ())),
        }
    }

    #[test]
    fn threads_config() {
        // top-level key
        let job = JobConfig::from_json_text(
            r#"{"model": "mlp", "threads": 4}"#).unwrap();
        assert_eq!(job.train.algo.threads, 4);
        // inside "algo"
        let job = JobConfig::from_json_text(
            r#"{"model": "mlp",
                "algo": {"mode": "allreduce", "threads": 2}}"#)
            .unwrap();
        assert_eq!(job.train.algo.threads, 2);
        // top level wins over "algo"
        let job = JobConfig::from_json_text(
            r#"{"model": "mlp", "threads": 1,
                "algo": {"threads": 8}}"#).unwrap();
        assert_eq!(job.train.algo.threads, 1);
        // default: 0 = auto-detect
        let job = JobConfig::from_json_text(r#"{"model": "mlp"}"#)
            .unwrap();
        assert_eq!(job.train.algo.threads, 0);
    }

    #[test]
    fn auto_config() {
        // top-level key
        let job = JobConfig::from_json_text(
            r#"{"model": "mlp", "workers": 8, "auto": true,
                "algo": {"mode": "allreduce"}}"#).unwrap();
        assert!(job.train.algo.auto);
        // inside "algo"
        let job = JobConfig::from_json_text(
            r#"{"model": "mlp", "workers": 8,
                "algo": {"mode": "allreduce", "auto": true}}"#)
            .unwrap();
        assert!(job.train.algo.auto);
        // default off
        let job = JobConfig::from_json_text(r#"{"model": "mlp"}"#)
            .unwrap();
        assert!(!job.train.algo.auto);
        // contradictory: auto is a ring-topology sweep
        match JobConfig::from_json_text(
            r#"{"model": "mlp", "workers": 4, "auto": true,
                "algo": {"mode": "downpour"}}"#)
        {
            Err(super::ConfigError::Invalid(msg)) => {
                assert!(msg.contains("auto")
                            && msg.contains("allreduce"),
                        "error must name the keys: {msg}");
            }
            other => panic!("expected Invalid, got {:?}",
                            other.map(|_| ())),
        }
        // contradictory: a pinned hierarchy leaves nothing to tune
        match JobConfig::from_json_text(
            r#"{"model": "mlp", "workers": 8, "auto": true,
                "algo": {"mode": "allreduce"},
                "hierarchy": {"groups": 2}}"#)
        {
            Err(super::ConfigError::Invalid(msg)) => {
                assert!(msg.contains("\"auto\"")
                            && msg.contains("\"hierarchy\""),
                        "error must name the keys: {msg}");
            }
            other => panic!("expected Invalid, got {:?}",
                            other.map(|_| ())),
        }
    }

    #[test]
    fn missing_model_rejected() {
        assert!(JobConfig::from_json_text(r#"{"batch": 10}"#).is_err());
    }

    #[test]
    fn bad_transport_rejected() {
        let text = r#"{"model": "lstm", "transport": {"carrier": 1}}"#;
        assert!(JobConfig::from_json_text(text).is_err());
    }

    #[test]
    fn files_data_requires_shards() {
        let text = r#"{"model": "lstm",
                       "data": {"dir": "/nonexistent_xyz"}}"#;
        assert!(JobConfig::from_json_text(text).is_err());
    }
}
