//! The paper's system contribution: MPI-style distributed training of the
//! AOT-compiled model zoo.
//!
//! - [`algo`] — the `Algo` training-procedure descriptor (Downpour / EASGD,
//!   sync/async, optimizer, validation frequency).
//! - [`builder`] — the `ModelBuilder` and `Data` user-interface classes.
//! - [`master`] / [`worker`] — the two process roles.
//! - [`hierarchy`] — two-level master topology.
//! - [`validation`] — master-side held-out evaluation.
//! - [`driver`] — the launcher (`train`, `train_direct`).

pub mod algo;
pub mod builder;
pub mod config;
pub mod driver;
pub mod hierarchy;
pub mod master;
pub mod validation;
pub mod worker;

pub use algo::{Algo, Mode};
pub use builder::{Data, ModelBuilder};
pub use config::JobConfig;
pub use driver::{run_rank, train, train_direct, TrainConfig, TrainError,
                 TrainResult, Transport};
pub use hierarchy::HierarchySpec;
