//! The paper's system contribution: MPI-style distributed training of the
//! AOT-compiled model zoo.
//!
//! - [`experiment`] — the `Experiment` facade: the one-call, fluent
//!   front door (`Experiment::new("lstm").workers(8).allreduce()
//!   .early_stopping(3).run(&session)`).
//! - [`topology`] — `WorldPlan`: (mode, hierarchy, workers) -> world
//!   size + per-rank roles/shards/seeds. One source of truth for every
//!   deployment.
//! - [`callbacks`] — Keras-style `Callback` trait + built-ins
//!   (`ModelCheckpoint`, `EarlyStopping`, `LrSchedule`, `JsonlLogger`).
//! - [`algo`] — the `Algo` training-procedure descriptor (Downpour /
//!   EASGD / AllReduce, sync/async, optimizer, validation frequency).
//! - [`builder`] — the `ModelBuilder` and `Data` user-interface classes.
//! - [`master`] / [`worker`] — the process roles (incl. `RingWorker`).
//! - [`elastic`] — membership agreement on rank churn: versioned
//!   `WorldPlan` epochs, suspect/agree/replan/resume (DESIGN.md
//!   §Elasticity).
//! - [`planner`] — the self-tuning topology planner: probe the links,
//!   calibrate the `CostModel`, sweep the closed-form round times, and
//!   emit the argmin as a normal `WorldPlan` (DESIGN.md §Autotuning).
//! - [`hierarchy`] — two-level master topology.
//! - [`validation`] — held-out evaluation + schedule.
//! - [`driver`] — the launcher: `train` / `run_rank` both execute roles
//!   through one `run_role` path; `train_direct` is the no-framework
//!   baseline.

pub mod algo;
pub mod builder;
pub mod callbacks;
pub mod config;
pub mod driver;
pub mod elastic;
pub mod experiment;
pub mod hierarchy;
pub mod master;
pub mod planner;
pub mod topology;
pub mod validation;
pub mod worker;

pub use algo::{Algo, Mode};
pub use builder::{Data, ModelBuilder};
pub use callbacks::{Callback, CallbackSpec, Control, EarlyStopping,
                    JsonlLogger, LrScheduleSpec, ModelCheckpoint,
                    RoundInfo, ValInfo};
pub use config::JobConfig;
pub use driver::{run_rank, train, train_direct, train_with_callbacks,
                 TrainConfig, TrainError, TrainResult, Transport};
pub use experiment::Experiment;
pub use hierarchy::HierarchySpec;
pub use planner::{Candidate, PlanChoice, RetuneConfig, Topology};
pub use topology::{RankRole, ServePlan, ServeRole, WorldPlan};
