//! Self-tuning topology planner (DESIGN.md §Autotuning).
//!
//! With `--auto` (JSON `"auto": true`, [`crate::coordinator::Experiment::
//! auto_tune`]) the operator stops hand-picking a topology: at startup
//! rank 0 probes the real links over the `Comm` layer — empty-payload
//! ping-pongs for latency, ramped-size float payloads for bandwidth,
//! classified intra- vs inter-group by a provisional [`WorldPlan`]
//! layout — injects the measurements into a [`CostModel`] next to the
//! [`Calibration`] compute costs, and sweeps the closed-form round-time
//! models to pick flat-vs-hierarchical, the group count, the wire
//! codec, and bucketing. The choice is emitted as a normal `WorldPlan`,
//! so the driver/worker path is unchanged; an online re-tuner
//! ([`RetuneConfig`], `RingWorker`) compares measured round times
//! against the plan's prediction each window and triggers a bounded
//! re-plan through the elastic path when they diverge.
//!
//! The probe rides its own tag lane (`ProbePing`/`ProbePong`, pinned in
//! [`crate::mpi::tags`]) so a straggling echo can never be mistaken for
//! training or serving traffic.

use std::time::Instant;

use crate::coordinator::algo::Mode;
use crate::coordinator::hierarchy::HierarchySpec;
use crate::coordinator::topology::WorldPlan;
use crate::mpi::codec::Codec;
use crate::mpi::{Comm, CommError, Envelope, Payload, Tag};
use crate::simulator::{median_and_spread, CostModel, LinkCost};

/// Sentinel probe sequence number: "probe phase over, stop echoing".
pub const PROBE_DONE: u64 = u64::MAX;

/// Empty ping-pongs used for the latency estimate (after warm-up).
const LATENCY_REPS: usize = 24;
/// Warm-up ping-pongs discarded before timing starts (allocator,
/// page-fault, and socket slow-start costs land here).
const LATENCY_WARMUP: usize = 4;
/// Ramped payload sizes (f32 counts) for the bandwidth estimate.
const BANDWIDTH_SIZES: [usize; 3] = [1024, 4096, 16384];
/// Timed repetitions per bandwidth payload size.
const BANDWIDTH_REPS: usize = 4;
/// Buckets assumed by the sweep's overlapped-flat candidate (the
/// worker's bucketed path picks its own count from the layer DAG; 4 is
/// the bench-validated nominal).
pub const SWEEP_BUCKETS: usize = 4;
/// Upper bound on re-plans the online re-tuner may trigger per run —
/// a mis-calibrated prediction must not flap the world forever.
pub const MAX_RETUNE_REPLANS: u32 = 2;

// ---------------------------------------------------------------------------
// probe protocol
// ---------------------------------------------------------------------------

/// Answer probe pings until the coordinator sends the [`PROBE_DONE`]
/// sentinel. Every non-coordinator rank runs this for the duration of
/// the probe phase; the echo carries the ping's payload (and sequence
/// number) back verbatim so the prober can both reject stale echoes and
/// measure the full round-trip volume.
pub fn respond_probe(comm: &Comm) -> Result<(), CommError> {
    let mut stash: Vec<Envelope> = Vec::new();
    loop {
        let env = comm.recv_tag(Tag::ProbePing, &mut stash)?;
        match env.payload.weights_like() {
            Some((step, _)) if step == PROBE_DONE => return Ok(()),
            Some((step, data)) => {
                comm.send(env.src, Tag::ProbePong,
                          Payload::floats_shared(step, data))?;
            }
            None => {
                return Err(CommError::Protocol(
                    "probe ping without a float payload".into()));
            }
        }
    }
}

/// One timed ping-pong of `floats` f32s to `peer`. The sequence number
/// travels in the payload `step` and the pong is matched against it —
/// a straggling echo from an earlier exchange is drained, not timed.
fn ping_once(comm: &Comm, peer: usize, seq: u64, floats: usize,
             stash: &mut Vec<Envelope>) -> Result<f64, CommError> {
    let t0 = Instant::now();
    comm.send(peer, Tag::ProbePing,
              Payload::floats(seq, vec![0.0f32; floats]))?;
    loop {
        let env = comm.recv_tag(Tag::ProbePong, stash)?;
        match env.payload.weights_like() {
            Some((step, _)) if step == seq => {
                return Ok(t0.elapsed().as_secs_f64());
            }
            Some(_) => continue, // stale echo: drop, keep waiting
            None => {
                return Err(CommError::Protocol(
                    "probe pong without a float payload".into()));
            }
        }
    }
}

/// Probe one link: median-of-reps ping-pong latency, then ramped-size
/// transfers for bandwidth. `seq` is the shared probe sequence counter
/// (monotone across links so no two exchanges ever share a number).
pub fn probe_link(comm: &Comm, peer: usize, seq: &mut u64)
    -> Result<LinkCost, CommError> {
    let mut stash: Vec<Envelope> = Vec::new();
    let mut timed = |floats: usize, stash: &mut Vec<Envelope>|
        -> Result<f64, CommError> {
        *seq += 1;
        ping_once(comm, peer, *seq, floats, stash)
    };

    for _ in 0..LATENCY_WARMUP {
        timed(0, &mut stash)?;
    }
    let rtt_samples: Vec<f64> = (0..LATENCY_REPS)
        .map(|_| timed(0, &mut stash))
        .collect::<Result<_, _>>()?;
    let (rtt_median, rtt_spread) = median_and_spread(&rtt_samples);
    let latency_s = 0.5 * rtt_median;

    // Bandwidth: subtract the latency floor from each loaded round
    // trip; what remains is the two-way serialization time of
    // 2 * wire_bytes. The epsilon guards degenerate hosts where a
    // loaded RTT lands under the empty-ping median.
    let mut bw_samples = Vec::new();
    for floats in BANDWIDTH_SIZES {
        let wire_bytes =
            Payload::floats(0, vec![0.0f32; floats]).nbytes() as f64;
        for _ in 0..BANDWIDTH_REPS {
            let rtt = timed(floats, &mut stash)?;
            let serialize = (rtt - rtt_median).max(1e-9);
            bw_samples.push(2.0 * wire_bytes / serialize);
        }
    }
    let (bandwidth_bytes_per_s, bw_spread) =
        median_and_spread(&bw_samples);
    Ok(LinkCost { latency_s, bandwidth_bytes_per_s,
                  rel_spread: rtt_spread.max(bw_spread) })
}

/// End the probe phase: every peer gets the [`PROBE_DONE`] sentinel and
/// returns from [`respond_probe`]. Best-effort on error paths too — a
/// peer that never hears the sentinel would block its join forever, so
/// the driver calls this even when the probe itself failed.
pub fn finish_probe(comm: &Comm, world_size: usize)
    -> Result<(), CommError> {
    for peer in 0..world_size {
        if peer == comm.rank() {
            continue;
        }
        comm.send(peer, Tag::ProbePing,
                  Payload::floats(PROBE_DONE, Vec::new()))?;
    }
    Ok(())
}

/// Which peers rank 0 probes, classified by a provisional grouped
/// [`WorldPlan`] layout: the intra peer is rank 0's own group
/// neighbor, the inter peer is the next group's leader. Worlds too
/// small (or too ragged) to group probe peer 1 for both classes —
/// `(intra, None)` means "one link class only".
pub fn probe_peers(n: usize) -> (usize, Option<usize>) {
    if n >= 4 {
        let g = (n / 4).max(2);
        let spec = HierarchySpec { n_groups: g, workers_per_group: 0,
                                   sync_every: 1 };
        if let Ok(plan) =
            WorldPlan::from_parts(&Mode::AllReduce, Some(spec), n, 0)
        {
            if let Some(layout) = plan.ring_layout() {
                let groups = layout.groups();
                if groups[0].len() >= 2 {
                    return (groups[0][1], Some(groups[1][0]));
                }
            }
        }
    }
    (1, None)
}

// ---------------------------------------------------------------------------
// the sweep
// ---------------------------------------------------------------------------

/// One topology shape the sweep can choose.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Monolithic flat ring all-reduce.
    Flat,
    /// Flat ring, split into `buckets` compute-overlapped buckets.
    FlatBucketed { buckets: usize },
    /// Grouped ring + leader tree with `groups` groups.
    Hier { groups: usize },
}

impl Topology {
    /// Stable log/JSON label (`flat`, `flat+buckets4`, `hier-g8`) —
    /// parsed by the CI autotune gate, so the format is frozen.
    pub fn label(&self) -> String {
        match self {
            Topology::Flat => "flat".into(),
            Topology::FlatBucketed { buckets } => {
                format!("flat+buckets{buckets}")
            }
            Topology::Hier { groups } => format!("hier-g{groups}"),
        }
    }
}

/// One swept (topology, codec) point and its predicted round time.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub topology: Topology,
    pub codec: Codec,
    /// Predicted wall time of one full training round: gradient
    /// compute + wire + optimizer update, seconds.
    pub predicted_s: f64,
}

impl Candidate {
    /// `<topology>|<codec>` — the key the CI gates match on.
    pub fn key(&self) -> String {
        format!("{}|{}", self.topology.label(), self.codec.name())
    }
}

/// The sweep's full output: the argmin plus every candidate, so logs
/// and benches can show the whole decision surface.
#[derive(Clone, Debug)]
pub struct PlanChoice {
    pub chosen: Candidate,
    pub candidates: Vec<Candidate>,
}

/// Predicted wall time of one round under `topology` — the common
/// currency every candidate is compared in.
pub fn predict_round(cost: &CostModel, n: usize, batch: usize,
                     topology: Topology) -> f64 {
    match topology {
        Topology::Flat => {
            cost.grad_time_nominal(batch)
                + cost.ring_allreduce_time(n)
                + cost.t_update
        }
        Topology::FlatBucketed { buckets } => {
            // bucketed_allreduce_time already includes the overlapped
            // gradient compute
            cost.bucketed_allreduce_time(n, batch, buckets)
                + cost.t_update
        }
        Topology::Hier { groups } => {
            cost.grad_time_nominal(batch)
                + cost.hierarchical_allreduce_time(n, groups)
                + cost.t_update
        }
    }
}

/// Sweep the closed-form round-time models over every candidate
/// (topology × codec) and return the argmin.
///
/// Candidate order is deterministic — codecs in the given order; within
/// a codec: flat, flat+buckets, then hierarchical groupings ascending —
/// and the argmin uses strict `<`, so ties resolve to the simplest
/// candidate. `pin_buckets` restricts the space to bucketed candidates
/// (the operator explicitly asked for overlap; auto then only tunes the
/// rest).
pub fn sweep(cost: &CostModel, n: usize, batch: usize,
             codecs: &[Codec], pin_buckets: bool) -> PlanChoice {
    assert!(!codecs.is_empty(), "sweep needs at least one codec");
    let mut topologies: Vec<Topology> = Vec::new();
    if !pin_buckets {
        topologies.push(Topology::Flat);
    }
    topologies.push(Topology::FlatBucketed { buckets: SWEEP_BUCKETS });
    if !pin_buckets {
        for g in WorldPlan::candidate_groupings(n) {
            topologies.push(Topology::Hier { groups: g });
        }
    }

    let mut candidates = Vec::new();
    for &codec in codecs {
        let c = cost.clone().with_compression(codec);
        for &topology in &topologies {
            candidates.push(Candidate {
                topology,
                codec,
                predicted_s: predict_round(&c, n, batch, topology),
            });
        }
    }
    let mut chosen = candidates[0].clone();
    for cand in &candidates[1..] {
        if cand.predicted_s < chosen.predicted_s {
            chosen = cand.clone();
        }
    }
    PlanChoice { chosen, candidates }
}

impl PlanChoice {
    /// The frozen-format log lines the autotune CI gate parses: one
    /// `candidate` line per swept point, then the `chose` line.
    pub fn log_lines(&self) -> Vec<String> {
        let mut lines: Vec<String> = self
            .candidates
            .iter()
            .map(|c| {
                format!("[planner] candidate {} predicted {:.3e}s/round",
                        c.key(), c.predicted_s)
            })
            .collect();
        let c = &self.chosen;
        lines.push(format!(
            "[planner] chose {} codec={} buckets={} predicted \
             {:.3e}s/round",
            c.topology.label(),
            c.codec.name(),
            match c.topology {
                Topology::FlatBucketed { .. } => "on",
                _ => "off",
            },
            c.predicted_s,
        ));
        lines
    }
}

// ---------------------------------------------------------------------------
// online re-tuner
// ---------------------------------------------------------------------------

/// What the worker's online re-tuner needs from the planner: the
/// predicted round time to hold the measured windows against, the
/// divergence trigger, and the probe's noise floor (a jittery host must
/// not be mistaken for a mis-planned topology).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetuneConfig {
    /// The chosen plan's predicted round time, seconds.
    pub predicted_round_s: f64,
    /// Trigger when `measured > factor * predicted` (default 2.0,
    /// `retune_factor`).
    pub factor: f64,
    /// Rounds per measurement window (default 50, `retune_window`).
    pub window: u64,
    /// Re-plans this run may still trigger ([`MAX_RETUNE_REPLANS`] at
    /// launch, decremented by the worker).
    pub max_replans: u32,
    /// Relative measurement noise from the probe/calibration phase; the
    /// divergence test must clear `factor * (1 + noise_floor)`.
    pub noise_floor: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster_cost() -> CostModel {
        CostModel::cluster(3_023)
    }

    /// Scaling every cost uniformly rescales every prediction by the
    /// same factor, so the argmin cannot move — the planner's choice
    /// depends on cost *ratios*, not units.
    #[test]
    fn sweep_argmin_stable_under_cost_scaling() {
        let base = cluster_cost();
        for n in [2usize, 4, 8, 16, 64] {
            let picked = sweep(&base, n, 100,
                               &[Codec::Fp32, Codec::Fp16], false);
            for scale in [0.25f64, 3.0, 1000.0] {
                let scaled = CostModel {
                    t_grad_fixed: base.t_grad_fixed * scale,
                    t_grad_per_sample: base.t_grad_per_sample * scale,
                    t_update: base.t_update * scale,
                    t_val: base.t_val * scale,
                    latency: base.latency * scale,
                    bandwidth_bytes_per_s: base.bandwidth_bytes_per_s
                        / scale,
                    intra_latency: base.intra_latency * scale,
                    intra_bandwidth_bytes_per_s:
                        base.intra_bandwidth_bytes_per_s / scale,
                    ..base.clone()
                };
                let again = sweep(&scaled, n, 100,
                                  &[Codec::Fp32, Codec::Fp16], false);
                assert_eq!(again.chosen.key(), picked.chosen.key(),
                           "n={n} scale={scale}");
                // and every prediction scaled by exactly `scale`
                for (a, b) in picked.candidates.iter()
                    .zip(&again.candidates)
                {
                    assert!((b.predicted_s - a.predicted_s * scale)
                        .abs() <= 1e-9 * b.predicted_s.abs(),
                        "{} at n={n}", a.key());
                }
            }
        }
    }

    /// Every hierarchical candidate the sweep enumerates must be a
    /// grouping `WorldPlan` itself accepts — divisibility and the >= 2
    /// groups / >= 2 members-per-group constraints included.
    #[test]
    fn sweep_respects_world_plan_grouping_constraints() {
        for n in [2usize, 3, 4, 6, 7, 8, 12, 16, 64] {
            let choice = sweep(&cluster_cost(), n, 100,
                               &[Codec::Fp32], false);
            for cand in &choice.candidates {
                if let Topology::Hier { groups } = cand.topology {
                    assert!(groups >= 2 && n % groups == 0
                                && n / groups >= 2,
                            "n={n} g={groups}");
                    let spec = HierarchySpec { n_groups: groups,
                                               workers_per_group: 0,
                                               sync_every: 1 };
                    let plan = WorldPlan::from_parts(
                        &Mode::AllReduce, Some(spec), n, 0)
                        .expect("sweep emitted an invalid grouping");
                    assert_eq!(plan.world_size(), n);
                    assert!(plan.ring_layout().is_some());
                }
            }
            // prime/small worlds sweep flat-only
            if n < 4 || (n > 2 && n % 2 == 1 && n % 3 != 0) {
                assert!(choice.candidates.iter().all(|c| !matches!(
                    c.topology, Topology::Hier { .. })), "n={n}");
            }
        }
    }

    /// On the cluster preset the sweep reproduces the bench gates:
    /// flat wins the 2-rank world, hierarchy wins at 16+ — and the
    /// chosen candidate is exactly the argmin of its own listing.
    #[test]
    fn sweep_crossover_matches_the_cost_model() {
        let cost = cluster_cost();
        for (n, want_flat) in
            [(2usize, true), (16usize, false), (64usize, false)]
        {
            let choice = sweep(&cost, n, 100, &[Codec::Fp32], false);
            let min = choice.candidates.iter()
                .map(|c| c.predicted_s)
                .fold(f64::INFINITY, f64::min);
            assert_eq!(choice.chosen.predicted_s, min, "n={n}");
            match (want_flat, choice.chosen.topology) {
                (true, Topology::Hier { .. }) => {
                    panic!("n={n}: wanted flat-ish, got hier")
                }
                (false, Topology::Hier { .. }) => {}
                (false, t) => panic!("n={n}: wanted hier, got {t:?}"),
                (true, _) => {}
            }
        }
        // an unpinned codec sweep always prefers fp16: the wire terms
        // are monotone in wire_ratio and the latency floor is shared
        let both = sweep(&cost, 16, 100,
                         &[Codec::Fp32, Codec::Fp16], false);
        assert_eq!(both.chosen.codec, Codec::Fp16);
    }

    /// Calibration + LinkCalibration inject into a CostModel whose
    /// closed forms then reproduce the measured numbers exactly — the
    /// probe → model → sweep pipeline loses nothing in translation.
    #[test]
    fn calibration_roundtrips_into_identical_closed_form_times() {
        use crate::simulator::{Calibration, LinkCalibration};
        let cal = Calibration { t_grad: 8.0e-3, batch: 100,
                                t_update: 3.0e-5, t_eval_batch: 1.0e-3,
                                grad_rel_spread: 0.02,
                                gemm_gflops_t1: 3.0,
                                gemm_gflops_pool: 9.0,
                                pool_threads: 4 };
        let links = LinkCalibration {
            intra: LinkCost { latency_s: 2.5e-6,
                              bandwidth_bytes_per_s: 1.8e10,
                              rel_spread: 0.01 },
            inter: LinkCost { latency_s: 3.5e-5,
                              bandwidth_bytes_per_s: 4.0e9,
                              rel_spread: 0.05 },
        };
        let mut cost = cluster_cost();
        cal.apply(&mut cost);
        links.apply(&mut cost);
        // the measured numbers come back out of the model verbatim
        assert!((cost.grad_time_nominal(100) - cal.t_grad).abs()
                    < 1e-12);
        assert_eq!(cost.t_update, cal.t_update);
        assert_eq!(cost.latency, links.inter.latency_s);
        assert_eq!(cost.intra_latency, links.intra.latency_s);
        // and the closed forms are pure functions of the injected
        // model: a second injection predicts identical times
        let mut cost2 = cluster_cost();
        cal.apply(&mut cost2);
        links.apply(&mut cost2);
        for n in [2usize, 8, 32] {
            assert_eq!(cost.ring_allreduce_time(n),
                       cost2.ring_allreduce_time(n));
            assert_eq!(cost.hierarchical_allreduce_time(n, 2),
                       cost2.hierarchical_allreduce_time(n, 2));
            assert_eq!(cost.bucketed_allreduce_time(n, 100, 4),
                       cost2.bucketed_allreduce_time(n, 100, 4));
            let a = sweep(&cost, n, 100, &[Codec::Fp32, Codec::Fp16],
                          false);
            let b = sweep(&cost2, n, 100, &[Codec::Fp32, Codec::Fp16],
                          false);
            assert_eq!(a.chosen.key(), b.chosen.key());
            assert_eq!(a.chosen.predicted_s, b.chosen.predicted_s);
        }
    }

    /// Pinning buckets restricts the space to bucketed candidates;
    /// pinning a codec (passing exactly one) restricts the codec axis.
    #[test]
    fn sweep_honors_pins() {
        let cost = cluster_cost();
        let pinned = sweep(&cost, 8, 100, &[Codec::Fp16], true);
        assert!(pinned.candidates.iter().all(|c| {
            c.codec == Codec::Fp16
                && matches!(c.topology, Topology::FlatBucketed { .. })
        }));
        assert_eq!(pinned.candidates.len(), 1);
    }

    /// The log-line format is frozen (the CI gate greps it): every
    /// candidate line carries the key, the chose line carries label +
    /// codec + buckets + prediction.
    #[test]
    fn log_lines_have_the_frozen_format() {
        let choice = sweep(&cluster_cost(), 8, 100,
                           &[Codec::Fp32, Codec::Fp16], false);
        let lines = choice.log_lines();
        assert_eq!(lines.len(), choice.candidates.len() + 1);
        for (line, cand) in lines.iter().zip(&choice.candidates) {
            assert!(line.starts_with("[planner] candidate "), "{line}");
            assert!(line.contains(&cand.key()), "{line}");
            assert!(line.ends_with("s/round"), "{line}");
        }
        let chose = lines.last().unwrap();
        assert!(chose.starts_with("[planner] chose "), "{chose}");
        assert!(chose.contains(&choice.chosen.topology.label()));
        assert!(chose.contains(&format!(
            "codec={}", choice.chosen.codec.name())));
        assert!(chose.contains("buckets="));
    }

    /// Probe peers come from the provisional plan's layout: group 0's
    /// second member intra, group 1's leader inter; degenerate worlds
    /// fall back to peer 1 with a single link class.
    #[test]
    fn probe_peers_follow_the_provisional_layout() {
        assert_eq!(probe_peers(2), (1, None));
        assert_eq!(probe_peers(3), (1, None));
        assert_eq!(probe_peers(4), (1, Some(2)));
        assert_eq!(probe_peers(8), (1, Some(4)));
        // 5 ranks don't divide into 2 groups: single class
        assert_eq!(probe_peers(5), (1, None));
        // 16 ranks, 4 groups of 4: inter peer is group 1's leader
        assert_eq!(probe_peers(16), (1, Some(4)));
    }

    /// End-to-end over a real in-process world: rank 0 probes both
    /// link classes while the peers echo, and everyone unwinds on the
    /// sentinel with the comms still usable.
    #[test]
    fn probe_round_trip_over_an_inproc_world() {
        let mut world = crate::mpi::inproc_world(4);
        let responders: Vec<Comm> = world.drain(1..).collect();
        let c0 = world.pop().unwrap();
        std::thread::scope(|s| {
            let handles: Vec<_> = responders
                .iter()
                .map(|c| s.spawn(move || respond_probe(c)))
                .collect();
            let (intra_peer, inter_peer) = probe_peers(4);
            let mut seq = 0u64;
            let intra = probe_link(&c0, intra_peer, &mut seq).unwrap();
            let inter =
                probe_link(&c0, inter_peer.unwrap(), &mut seq).unwrap();
            finish_probe(&c0, 4).unwrap();
            for h in handles {
                h.join().unwrap().unwrap();
            }
            assert!(intra.latency_s >= 0.0 && inter.latency_s >= 0.0);
            assert!(intra.bandwidth_bytes_per_s > 0.0);
            assert!(inter.bandwidth_bytes_per_s > 0.0);
            assert!(intra.rel_spread >= 0.0);
        });
    }

    /// A stale echo (earlier sequence number) is drained, never timed:
    /// the prober matches pongs by the payload step.
    #[test]
    fn stale_echoes_are_rejected_by_sequence() {
        let mut world = crate::mpi::inproc_world(2);
        let c1 = world.pop().unwrap();
        let c0 = world.pop().unwrap();
        // rank 1 sends a stale pong first, then echoes properly
        let h = std::thread::spawn(move || {
            c1.send(0, Tag::ProbePong,
                    Payload::floats(7, vec![1.0]))
                .unwrap();
            respond_probe(&c1).unwrap();
        });
        let mut seq = 100u64;
        let cost = probe_link(&c0, 1, &mut seq).unwrap();
        finish_probe(&c0, 2).unwrap();
        h.join().unwrap();
        assert!(cost.latency_s >= 0.0);
    }
}
