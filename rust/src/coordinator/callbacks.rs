//! Keras-style training callbacks (HyPar-Flow's adoption argument: the
//! usual conveniences — checkpointing, early stopping, LR schedules,
//! metric streaming — attach to a one-call training API).
//!
//! Two layers:
//!
//! - [`Callback`] — the observer trait (`on_train_begin` / `on_round` /
//!   `on_validation` / `on_train_end`) with a [`Control`] surface for
//!   stop requests and LR rescaling. Implement it for custom behavior
//!   and attach via `Experiment::callback` or
//!   `driver::train_with_callbacks`.
//! - [`CallbackSpec`] — the declarative, cloneable description that
//!   lives in `TrainConfig`, the JSON config (`"callbacks": [...]`),
//!   and CLI flags. Specs `build()` into boxed callbacks at launch.
//!
//! Callbacks run on the *observer* rank only (the master, or ring rank
//! 0 — see `WorldPlan::observer`). A stop request propagates through
//! the existing Exit protocol: the master answers subsequent traffic
//! with `Tag::Exit` (workers wind down and report), and the ring
//! piggybacks a stop flag on the next collective so every rank breaks
//! in lockstep with bitwise-identical weights.
//!
//! [`Observer`] bundles eval data + validation schedule + callbacks for
//! the observing role — replacing the `Option<(&ModelExecutables,
//! &DataSet)>` threading that every role constructor used to carry.

use std::io::Write;
use std::path::PathBuf;

use crate::coordinator::algo::Algo;
use crate::coordinator::validation::{run_validation, ValidationSchedule};
use crate::data::DataSet;
use crate::metrics::{History, ValRecord};
use crate::runtime::ModelExecutables;
use crate::tensor::ParamSet;
use crate::util::json::Json;

/// Mutable control surface a callback writes its requests into.
#[derive(Debug, Default)]
pub struct Control {
    stop: bool,
    lr_scale: Option<f32>,
}

impl Control {
    /// Request a clean end of training (propagated via Exit / the ring
    /// stop flag).
    pub fn stop(&mut self) {
        self.stop = true;
    }

    /// Rescale the base learning rate from the next update on.
    pub fn set_lr_scale(&mut self, scale: f32) {
        self.lr_scale = Some(scale);
    }
}

/// What a callback sees after each master/replicated update.
pub struct RoundInfo<'a> {
    /// Master update count (1-based; the update just applied).
    pub update: u64,
    /// Training loss of the gradient(s) behind this update (NaN when
    /// the mode has no per-update loss, e.g. EASGD exchanges).
    pub train_loss: f32,
    pub weights: &'a ParamSet,
    /// Seconds since training start.
    pub t_s: f64,
}

/// What a callback sees after each validation sweep.
pub struct ValInfo<'a> {
    pub update: u64,
    pub val_loss: f32,
    pub val_acc: f32,
    pub weights: &'a ParamSet,
    pub t_s: f64,
}

/// Training observer, Keras-callback shaped. All methods default to
/// no-ops so implementations override only what they need.
pub trait Callback: Send {
    fn on_train_begin(&mut self, _n_params: usize) {}
    fn on_round(&mut self, _info: &RoundInfo<'_>, _ctl: &mut Control) {}
    fn on_validation(&mut self, _info: &ValInfo<'_>,
                     _ctl: &mut Control) {}
    fn on_train_end(&mut self, _history: &History) {}
}

/// Declarative LR schedule (pure function of the update count, so the
/// all-reduce mode can replicate it bitwise on every rank).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LrScheduleSpec {
    /// Multiply the base LR by `gamma` every `every` updates.
    Step { gamma: f32, every: u64 },
    /// Multiply the base LR by `gamma` per update (gamma^(u-1)).
    Exponential { gamma: f32 },
}

impl LrScheduleSpec {
    /// Scale to apply to the optimizer for (1-based) update `u`.
    pub fn scale_for_update(&self, u: u64) -> f32 {
        match *self {
            LrScheduleSpec::Step { gamma, every } => {
                if every == 0 {
                    1.0
                } else {
                    gamma.powi((u / every).min(i32::MAX as u64) as i32)
                }
            }
            LrScheduleSpec::Exponential { gamma } => {
                gamma.powf(u.saturating_sub(1) as f32)
            }
        }
    }
}

/// Cloneable callback description — what `TrainConfig`, the JSON config
/// schema, and CLI flags store. See module docs for the JSON shape.
#[derive(Clone, Debug, PartialEq)]
pub enum CallbackSpec {
    /// Stop when val loss hasn't improved by > `min_delta` for
    /// `patience` consecutive validations.
    EarlyStopping { patience: u32, min_delta: f32 },
    /// Write LE `ParamSet` checkpoints: `best.mplw` on every val-loss
    /// improvement, plus (unless `best_only`) `checkpoint-{u}.mplw`
    /// every `every` updates.
    ModelCheckpoint { dir: PathBuf, every: u64, best_only: bool },
    LrSchedule(LrScheduleSpec),
    /// Stream one JSON object per round/validation to a `.jsonl` file.
    JsonlLogger { path: PathBuf },
}

impl CallbackSpec {
    pub fn build(&self) -> Box<dyn Callback> {
        match self {
            CallbackSpec::EarlyStopping { patience, min_delta } => {
                Box::new(EarlyStopping::new(*patience, *min_delta))
            }
            CallbackSpec::ModelCheckpoint { dir, every, best_only } => {
                Box::new(ModelCheckpoint::new(dir.clone(), *every,
                                              *best_only))
            }
            CallbackSpec::LrSchedule(spec) => {
                Box::new(LrSchedule { spec: *spec })
            }
            CallbackSpec::JsonlLogger { path } => {
                Box::new(JsonlLogger::new(path.clone()))
            }
        }
    }

    /// Parse one spec from a config object:
    /// `{"kind": "early_stopping", "patience": 3, "min_delta": 0.0}`,
    /// `{"kind": "checkpoint", "dir": "...", "every": 100,
    ///   "best_only": true}`,
    /// `{"kind": "lr_schedule", "schedule": "step"|"exponential",
    ///   "gamma": 0.5, "every": 200}`,
    /// `{"kind": "jsonl", "path": "metrics.jsonl"}`.
    pub fn from_json(j: &Json) -> Result<CallbackSpec, String> {
        let kind = j
            .get("kind")
            .and_then(|v| v.as_str())
            .ok_or("callback needs a 'kind'")?;
        // A present-but-mistyped value is a config bug the user must
        // hear about, not a silent fallback to the default.
        let f32_of = |key: &str, dflt: f32| -> Result<f32, String> {
            match j.get(key) {
                None => Ok(dflt),
                Some(v) => {
                    v.as_f64().map(|v| v as f32).ok_or_else(|| format!(
                        "callback '{kind}': '{key}' must be a number"))
                }
            }
        };
        let u64_of = |key: &str, dflt: u64| -> Result<u64, String> {
            match j.get(key) {
                None => Ok(dflt),
                Some(v) => {
                    v.as_usize().map(|v| v as u64).ok_or_else(|| {
                        format!("callback '{kind}': '{key}' must be a \
                                 non-negative integer")
                    })
                }
            }
        };
        let bool_of = |key: &str, dflt: bool| -> Result<bool, String> {
            match j.get(key) {
                None => Ok(dflt),
                Some(v) => v.as_bool().ok_or_else(|| format!(
                    "callback '{kind}': '{key}' must be a boolean")),
            }
        };
        Ok(match kind {
            "early_stopping" => CallbackSpec::EarlyStopping {
                patience: u64_of("patience", 3)? as u32,
                min_delta: f32_of("min_delta", 0.0)?,
            },
            "checkpoint" => CallbackSpec::ModelCheckpoint {
                dir: PathBuf::from(
                    j.get("dir").and_then(|v| v.as_str())
                        .ok_or("checkpoint callback needs 'dir'")?),
                every: u64_of("every", 0)?,
                best_only: bool_of("best_only", true)?,
            },
            "lr_schedule" => {
                let gamma = f32_of("gamma", 0.5)?;
                match j.get("schedule").and_then(|v| v.as_str())
                    .unwrap_or("step") {
                    "step" => CallbackSpec::LrSchedule(
                        LrScheduleSpec::Step {
                            gamma,
                            every: u64_of("every", 100)?,
                        }),
                    "exponential" => CallbackSpec::LrSchedule(
                        LrScheduleSpec::Exponential { gamma }),
                    other => {
                        return Err(format!(
                            "unknown lr schedule '{other}' \
                             (step|exponential)"))
                    }
                }
            }
            "jsonl" => CallbackSpec::JsonlLogger {
                path: PathBuf::from(
                    j.get("path").and_then(|v| v.as_str())
                        .ok_or("jsonl callback needs 'path'")?),
            },
            other => {
                return Err(format!("unknown callback kind '{other}'"))
            }
        })
    }

    /// Parse the config's `"callbacks"` array.
    pub fn parse_list(j: &Json) -> Result<Vec<CallbackSpec>, String> {
        match j {
            Json::Arr(items) => items.iter().map(Self::from_json)
                .collect(),
            _ => Err("'callbacks' must be an array".into()),
        }
    }
}

/// The LR schedule every rank must agree on: an explicit
/// `CallbackSpec::LrSchedule` wins; otherwise the legacy
/// `Algo::lr_decay`/`lr_decay_every` fields translate to a step
/// schedule. Pure in the update count, so the all-reduce mode applies
/// it identically on every rank without any callback traffic.
pub fn effective_lr_schedule(algo: &Algo, specs: &[CallbackSpec])
    -> Option<LrScheduleSpec> {
    for spec in specs {
        if let CallbackSpec::LrSchedule(s) = spec {
            return Some(*s);
        }
    }
    if algo.lr_decay > 0.0 && algo.lr_decay_every > 0 {
        return Some(LrScheduleSpec::Step {
            gamma: algo.lr_decay,
            every: algo.lr_decay_every,
        });
    }
    None
}

// ---------------------------------------------------------------------
// built-ins
// ---------------------------------------------------------------------

/// Stop training when validation loss stops improving.
pub struct EarlyStopping {
    patience: u32,
    min_delta: f32,
    best: f32,
    bad: u32,
}

impl EarlyStopping {
    pub fn new(patience: u32, min_delta: f32) -> Self {
        Self { patience, min_delta, best: f32::INFINITY, bad: 0 }
    }
}

impl Callback for EarlyStopping {
    fn on_train_begin(&mut self, _n_params: usize) {
        self.best = f32::INFINITY;
        self.bad = 0;
    }

    fn on_validation(&mut self, info: &ValInfo<'_>, ctl: &mut Control) {
        // NaN never counts as an improvement
        if info.val_loss < self.best - self.min_delta {
            self.best = info.val_loss;
            self.bad = 0;
        } else {
            self.bad += 1;
            if self.bad >= self.patience {
                log::info!(
                    "early stopping: no val-loss improvement in {} \
                     validation(s) (best {:.4}) — stopping at update {}",
                    self.bad, self.best, info.update);
                ctl.stop();
            }
        }
    }
}

/// Write `ParamSet` checkpoints (the LE `save` format, reloadable with
/// `ParamSet::load`). `best.mplw` tracks the best validation loss;
/// periodic `checkpoint-{update}.mplw` files are written unless
/// `best_only`.
pub struct ModelCheckpoint {
    dir: PathBuf,
    every: u64,
    best_only: bool,
    best: f32,
}

impl ModelCheckpoint {
    pub fn new(dir: PathBuf, every: u64, best_only: bool) -> Self {
        Self { dir, every, best_only, best: f32::INFINITY }
    }

    fn save(&self, name: &str, weights: &ParamSet) {
        let path = self.dir.join(name);
        if let Err(e) = weights.save(&path) {
            log::error!("checkpoint write {} failed: {e}",
                        path.display());
        }
    }
}

impl Callback for ModelCheckpoint {
    fn on_train_begin(&mut self, _n_params: usize) {
        self.best = f32::INFINITY;
        if let Err(e) = std::fs::create_dir_all(&self.dir) {
            log::error!("checkpoint dir {} failed: {e}",
                        self.dir.display());
        }
    }

    fn on_round(&mut self, info: &RoundInfo<'_>, _ctl: &mut Control) {
        if !self.best_only && self.every > 0
            && info.update % self.every == 0 {
            self.save(&format!("checkpoint-{}.mplw", info.update),
                      info.weights);
        }
    }

    fn on_validation(&mut self, info: &ValInfo<'_>, _ctl: &mut Control) {
        if info.val_loss < self.best {
            self.best = info.val_loss;
            self.save("best.mplw", info.weights);
        }
    }
}

/// Declarative LR decay on the master/replicated optimizer.
pub struct LrSchedule {
    spec: LrScheduleSpec,
}

impl Callback for LrSchedule {
    fn on_round(&mut self, info: &RoundInfo<'_>, ctl: &mut Control) {
        // sets the scale for the NEXT update (info.update + 1)
        ctl.set_lr_scale(self.spec.scale_for_update(info.update + 1));
    }
}

/// Stream metrics as JSON lines (one object per round / validation).
pub struct JsonlLogger {
    path: PathBuf,
    out: Option<std::io::BufWriter<std::fs::File>>,
}

impl JsonlLogger {
    pub fn new(path: PathBuf) -> Self {
        Self { path, out: None }
    }

    fn write_line(&mut self, line: String) {
        if let Some(out) = self.out.as_mut() {
            if let Err(e) = writeln!(out, "{line}") {
                log::error!("jsonl write failed: {e}");
                self.out = None;
            }
        }
    }
}

/// JSON number or `null` for non-finite values (NaN is not JSON).
fn jnum(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

impl Callback for JsonlLogger {
    fn on_train_begin(&mut self, n_params: usize) {
        if let Some(parent) = self.path.parent() {
            if !parent.as_os_str().is_empty() {
                let _ = std::fs::create_dir_all(parent);
            }
        }
        match std::fs::File::create(&self.path) {
            Ok(f) => {
                self.out = Some(std::io::BufWriter::new(f));
                self.write_line(format!(
                    "{{\"event\":\"begin\",\"n_params\":{n_params}}}"));
            }
            Err(e) => log::error!("jsonl open {} failed: {e}",
                                  self.path.display()),
        }
    }

    fn on_round(&mut self, info: &RoundInfo<'_>, _ctl: &mut Control) {
        self.write_line(format!(
            "{{\"event\":\"round\",\"update\":{},\"train_loss\":{},\
             \"t_s\":{}}}",
            info.update, jnum(info.train_loss as f64), jnum(info.t_s)));
    }

    fn on_validation(&mut self, info: &ValInfo<'_>, _ctl: &mut Control) {
        self.write_line(format!(
            "{{\"event\":\"validation\",\"update\":{},\"val_loss\":{},\
             \"val_acc\":{},\"t_s\":{}}}",
            info.update, jnum(info.val_loss as f64),
            jnum(info.val_acc as f64), jnum(info.t_s)));
        if let Some(out) = self.out.as_mut() {
            let _ = out.flush();
        }
    }

    fn on_train_end(&mut self, history: &History) {
        self.write_line(format!(
            "{{\"event\":\"end\",\"master_updates\":{},\
             \"wallclock_s\":{},\"best_val_loss\":{}}}",
            history.master_updates, jnum(history.wallclock_s),
            jnum(history.best_val_loss().unwrap_or(f32::NAN) as f64)));
        if let Some(out) = self.out.as_mut() {
            let _ = out.flush();
        }
    }
}

// ---------------------------------------------------------------------
// the host side
// ---------------------------------------------------------------------

/// An ordered set of callbacks plus the merged control state.
#[derive(Default)]
pub struct CallbackSet {
    cbs: Vec<Box<dyn Callback>>,
    stopped: bool,
    lr_scale: Option<f32>,
}

impl CallbackSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build the launch-time set: every spec, plus the legacy
    /// `Algo::lr_decay` fields as a step schedule when no explicit
    /// schedule spec is present.
    pub fn from_config(algo: &Algo, specs: &[CallbackSpec]) -> Self {
        let mut set = CallbackSet::new();
        let mut have_lr = false;
        for spec in specs {
            if matches!(spec, CallbackSpec::LrSchedule(_)) {
                have_lr = true;
            }
            set.push(spec.build());
        }
        if !have_lr {
            if let Some(lr) = effective_lr_schedule(algo, &[]) {
                set.push(Box::new(LrSchedule { spec: lr }));
            }
        }
        set
    }

    pub fn push(&mut self, cb: Box<dyn Callback>) {
        self.cbs.push(cb);
    }

    pub fn is_empty(&self) -> bool {
        self.cbs.is_empty()
    }

    pub fn on_train_begin(&mut self, n_params: usize) {
        for cb in &mut self.cbs {
            cb.on_train_begin(n_params);
        }
    }

    pub fn on_round(&mut self, info: &RoundInfo<'_>) {
        let mut ctl = Control::default();
        for cb in &mut self.cbs {
            cb.on_round(info, &mut ctl);
        }
        self.merge(ctl);
    }

    pub fn on_validation(&mut self, info: &ValInfo<'_>) {
        let mut ctl = Control::default();
        for cb in &mut self.cbs {
            cb.on_validation(info, &mut ctl);
        }
        self.merge(ctl);
    }

    pub fn on_train_end(&mut self, history: &History) {
        for cb in &mut self.cbs {
            cb.on_train_end(history);
        }
    }

    fn merge(&mut self, ctl: Control) {
        self.stopped |= ctl.stop;
        if ctl.lr_scale.is_some() {
            self.lr_scale = ctl.lr_scale;
        }
    }

    pub fn should_stop(&self) -> bool {
        self.stopped
    }

    /// The latest requested LR scale, if it changed since last taken.
    pub fn take_lr_scale(&mut self) -> Option<f32> {
        self.lr_scale.take()
    }
}

/// Everything the *observer* role (master / ring rank 0 /
/// `train_direct`) needs beyond its training loop: held-out eval data,
/// the validation schedule, and the callback set. Replaces the old
/// `eval: Option<(&ModelExecutables, &DataSet)>` constructor threading.
pub struct Observer<'a> {
    eval: Option<(&'a ModelExecutables, &'a DataSet)>,
    schedule: ValidationSchedule,
    max_val_batches: usize,
    callbacks: CallbackSet,
}

impl<'a> Observer<'a> {
    pub fn new(algo: &Algo,
               eval: Option<(&'a ModelExecutables, &'a DataSet)>,
               callbacks: CallbackSet) -> Self {
        Self {
            eval,
            schedule: ValidationSchedule::new(algo.validate_every),
            max_val_batches: algo.max_val_batches,
            callbacks,
        }
    }

    /// A no-op observer for non-observing ranks and unit tests.
    pub fn disabled() -> Observer<'static> {
        Observer {
            eval: None,
            schedule: ValidationSchedule::new(0),
            max_val_batches: 0,
            callbacks: CallbackSet::new(),
        }
    }

    pub fn begin(&mut self, n_params: usize) {
        self.callbacks.on_train_begin(n_params);
    }

    /// Hook called after master/replicated update number `update`:
    /// samples the train-loss curve, fires `on_round`, and runs any due
    /// validation (recording it and firing `on_validation`).
    pub fn after_update(&mut self, update: u64, train_loss: f32,
                        weights: &ParamSet, t_s: f64,
                        history: &mut History) {
        if train_loss.is_finite() && (update % 16 == 0 || update == 1) {
            history.train_losses.push((update, train_loss));
        }
        self.callbacks.on_round(&RoundInfo {
            update,
            train_loss,
            weights,
            t_s,
        });
        if self.schedule.due(update) {
            self.validate(update, weights, t_s, history);
        }
    }

    fn validate(&mut self, update: u64, weights: &ParamSet, t_s: f64,
                history: &mut History) {
        let Some((exes, val)) = self.eval else { return };
        match run_validation(exes, weights, val, self.max_val_batches) {
            Ok((loss, acc)) => {
                log::info!(
                    "validation @ update {update}: loss={loss:.4} \
                     acc={acc:.4}");
                history.validations.push(ValRecord {
                    t_s,
                    update,
                    val_loss: loss,
                    val_acc: acc,
                });
                self.callbacks.on_validation(&ValInfo {
                    update,
                    val_loss: loss,
                    val_acc: acc,
                    weights,
                    t_s,
                });
            }
            Err(e) => log::error!("validation failed: {e}"),
        }
    }

    /// Wind-down: force a final validation (so every run ends with a
    /// measurement) and fire `on_train_end` with the finished history.
    pub fn finish(&mut self, update: u64, weights: &ParamSet, t_s: f64,
                  history: &mut History) {
        self.validate(update, weights, t_s, history);
        self.callbacks.on_train_end(history);
    }

    pub fn should_stop(&self) -> bool {
        self.callbacks.should_stop()
    }

    pub fn take_lr_scale(&mut self) -> Option<f32> {
        self.callbacks.take_lr_scale()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn val_info(update: u64, loss: f32, w: &ParamSet) -> ValInfo<'_> {
        ValInfo { update, val_loss: loss, val_acc: 0.5, weights: w,
                  t_s: 0.0 }
    }

    #[test]
    fn early_stopping_counts_patience() {
        let w = ParamSet::zeros(&[("w".into(), vec![2])]);
        let mut es = EarlyStopping::new(2, 0.0);
        es.on_train_begin(2);
        let mut ctl = Control::default();
        es.on_validation(&val_info(5, 1.0, &w), &mut ctl); // improves
        es.on_validation(&val_info(10, 1.0, &w), &mut ctl); // bad 1
        assert!(!ctl.stop);
        es.on_validation(&val_info(15, 1.2, &w), &mut ctl); // bad 2
        assert!(ctl.stop, "patience 2 exhausted");
        // an improvement resets the counter
        let mut es = EarlyStopping::new(2, 0.0);
        let mut ctl = Control::default();
        es.on_validation(&val_info(5, 1.0, &w), &mut ctl);
        es.on_validation(&val_info(10, 1.1, &w), &mut ctl); // bad 1
        es.on_validation(&val_info(15, 0.5, &w), &mut ctl); // improves
        es.on_validation(&val_info(20, 0.6, &w), &mut ctl); // bad 1
        assert!(!ctl.stop);
    }

    #[test]
    fn early_stopping_min_delta_and_nan() {
        let w = ParamSet::zeros(&[("w".into(), vec![2])]);
        let mut es = EarlyStopping::new(1, 0.5);
        let mut ctl = Control::default();
        es.on_validation(&val_info(1, 2.0, &w), &mut ctl);
        // 1.8 is better but not by > 0.5 -> no improvement
        es.on_validation(&val_info(2, 1.8, &w), &mut ctl);
        assert!(ctl.stop);
        let mut es = EarlyStopping::new(1, 0.0);
        let mut ctl = Control::default();
        es.on_validation(&val_info(1, f32::NAN, &w), &mut ctl);
        assert!(ctl.stop, "NaN is never an improvement");
    }

    #[test]
    fn model_checkpoint_writes_loadable_best() {
        let dir = std::env::temp_dir().join("mpi_learn_cb_ckpt_unit");
        let _ = std::fs::remove_dir_all(&dir);
        let mut ps = ParamSet::zeros(&[("w".into(), vec![3])]);
        let mut cb = ModelCheckpoint::new(dir.clone(), 0, true);
        cb.on_train_begin(3);
        let mut ctl = Control::default();
        ps.flat_mut()[0] = 1.5;
        cb.on_validation(&val_info(10, 0.9, &ps), &mut ctl);
        let best = ParamSet::load(&dir.join("best.mplw")).unwrap();
        assert_eq!(best, ps);
        // a worse validation must NOT overwrite best
        ps.flat_mut()[0] = -7.0;
        cb.on_validation(&val_info(20, 1.4, &ps), &mut ctl);
        let best = ParamSet::load(&dir.join("best.mplw")).unwrap();
        assert_eq!(best.flat()[0], 1.5);
    }

    #[test]
    fn model_checkpoint_periodic_files() {
        let dir = std::env::temp_dir().join("mpi_learn_cb_ckpt_periodic");
        let _ = std::fs::remove_dir_all(&dir);
        let ps = ParamSet::zeros(&[("w".into(), vec![3])]);
        let mut cb = ModelCheckpoint::new(dir.clone(), 2, false);
        cb.on_train_begin(3);
        let mut ctl = Control::default();
        for u in 1..=4u64 {
            cb.on_round(&RoundInfo { update: u, train_loss: 1.0,
                                     weights: &ps, t_s: 0.0 },
                        &mut ctl);
        }
        assert!(dir.join("checkpoint-2.mplw").exists());
        assert!(dir.join("checkpoint-4.mplw").exists());
        assert!(!dir.join("checkpoint-3.mplw").exists());
        ParamSet::load(&dir.join("checkpoint-4.mplw")).unwrap();
    }

    #[test]
    fn lr_schedule_scales() {
        let step = LrScheduleSpec::Step { gamma: 0.5, every: 2 };
        // matches the legacy StepDecay: scale gamma^(u/every) at update u
        assert_eq!(step.scale_for_update(1), 1.0);
        assert_eq!(step.scale_for_update(2), 0.5);
        assert_eq!(step.scale_for_update(3), 0.5);
        assert_eq!(step.scale_for_update(4), 0.25);
        let exp = LrScheduleSpec::Exponential { gamma: 0.5 };
        assert_eq!(exp.scale_for_update(1), 1.0);
        assert_eq!(exp.scale_for_update(3), 0.25);
    }

    #[test]
    fn spec_json_parsing() {
        let j = Json::parse(
            r#"[{"kind": "early_stopping", "patience": 4},
                {"kind": "checkpoint", "dir": "/tmp/x", "every": 10,
                 "best_only": false},
                {"kind": "lr_schedule", "schedule": "step",
                 "gamma": 0.9, "every": 50},
                {"kind": "jsonl", "path": "m.jsonl"}]"#).unwrap();
        let specs = CallbackSpec::parse_list(&j).unwrap();
        assert_eq!(specs.len(), 4);
        assert_eq!(specs[0], CallbackSpec::EarlyStopping {
            patience: 4, min_delta: 0.0 });
        assert_eq!(specs[1], CallbackSpec::ModelCheckpoint {
            dir: PathBuf::from("/tmp/x"), every: 10, best_only: false });
        match specs[2] {
            CallbackSpec::LrSchedule(LrScheduleSpec::Step {
                gamma, every }) => {
                assert!((gamma - 0.9).abs() < 1e-6);
                assert_eq!(every, 50);
            }
            ref s => panic!("{s:?}"),
        }
        assert!(CallbackSpec::from_json(
            &Json::parse(r#"{"kind": "bogus"}"#).unwrap()).is_err());
        assert!(CallbackSpec::from_json(
            &Json::parse(r#"{"kind": "checkpoint"}"#).unwrap()).is_err());
    }

    /// Mistyped values must be rejected, not silently defaulted.
    #[test]
    fn spec_json_rejects_wrong_types() {
        for bad in [
            r#"{"kind": "early_stopping", "patience": "5"}"#,
            r#"{"kind": "checkpoint", "dir": "d", "every": "100"}"#,
            r#"{"kind": "checkpoint", "dir": "d", "best_only": 1}"#,
            r#"{"kind": "lr_schedule", "gamma": "0.5"}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(CallbackSpec::from_json(&j).is_err(), "{bad}");
        }
    }

    #[test]
    fn effective_lr_schedule_precedence() {
        let mut algo = Algo::default();
        assert_eq!(effective_lr_schedule(&algo, &[]), None);
        algo.lr_decay = 0.5;
        algo.lr_decay_every = 10;
        assert_eq!(effective_lr_schedule(&algo, &[]),
                   Some(LrScheduleSpec::Step { gamma: 0.5, every: 10 }));
        let explicit = [CallbackSpec::LrSchedule(
            LrScheduleSpec::Exponential { gamma: 0.99 })];
        assert_eq!(effective_lr_schedule(&algo, &explicit),
                   Some(LrScheduleSpec::Exponential { gamma: 0.99 }));
    }

    #[test]
    fn jsonl_logger_emits_valid_json_lines() {
        let path = std::env::temp_dir()
            .join("mpi_learn_cb_jsonl_unit/metrics.jsonl");
        let _ = std::fs::remove_file(&path);
        let ps = ParamSet::zeros(&[("w".into(), vec![2])]);
        let mut cb = JsonlLogger::new(path.clone());
        cb.on_train_begin(2);
        let mut ctl = Control::default();
        cb.on_round(&RoundInfo { update: 1, train_loss: 0.5,
                                 weights: &ps, t_s: 0.1 }, &mut ctl);
        cb.on_round(&RoundInfo { update: 2, train_loss: f32::NAN,
                                 weights: &ps, t_s: 0.2 }, &mut ctl);
        cb.on_validation(&val_info(2, 0.4, &ps), &mut ctl);
        cb.on_train_end(&History::default());
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5);
        for line in &lines {
            Json::parse(line).unwrap_or_else(
                |e| panic!("invalid json line {line}: {e}"));
        }
        assert!(lines[2].contains("\"train_loss\":null"));
    }

    #[test]
    fn callback_set_merges_control() {
        struct Stopper;
        impl Callback for Stopper {
            fn on_round(&mut self, _i: &RoundInfo<'_>,
                        ctl: &mut Control) {
                ctl.stop();
                ctl.set_lr_scale(0.25);
            }
        }
        let ps = ParamSet::zeros(&[("w".into(), vec![2])]);
        let mut set = CallbackSet::new();
        set.push(Box::new(Stopper));
        assert!(!set.should_stop());
        set.on_round(&RoundInfo { update: 1, train_loss: 1.0,
                                  weights: &ps, t_s: 0.0 });
        assert!(set.should_stop());
        assert_eq!(set.take_lr_scale(), Some(0.25));
        assert_eq!(set.take_lr_scale(), None);
    }
}
