//! The launcher: maps ranks to roles, spawns the world, runs training.
//!
//! This is `mpi_learn`'s `MPIManager` + `train.py` equivalent: given an
//! [`Algo`], a [`ModelBuilder`] and a [`Data`] source, it brings up a
//! master + N workers (optionally a two-level hierarchy), trains, and
//! returns the merged [`History`].
//!
//! Also provides [`train_direct`] — the "Keras alone" baseline of §V: the
//! identical compute loop with no distribution framework at all, used to
//! measure the framework's own overhead.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::algo::{Algo, Mode};
use crate::coordinator::builder::{Data, ModelBuilder};
use crate::coordinator::hierarchy::{GroupMaster, HierarchySpec, Role};
use crate::coordinator::master::{Master, MasterContext};
use crate::coordinator::worker::{RingWorker, Worker};
use crate::data::DataSet;
use crate::metrics::History;
use crate::mpi;
use crate::runtime::{ModelExecutables, Session};
use crate::tensor::ParamSet;
use crate::util::rng::Rng;

#[derive(Debug)]
pub enum TrainError {
    Session(crate::runtime::SessionError),
    Data(crate::data::ShardError),
    Comm(mpi::CommError),
    Worker { rank: usize, msg: String },
    Panic(String),
    Config(String),
}

impl std::fmt::Display for TrainError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TrainError::Session(e) => write!(f, "session: {e}"),
            TrainError::Data(e) => write!(f, "data: {e}"),
            TrainError::Comm(e) => write!(f, "comm: {e}"),
            TrainError::Worker { rank, msg } => {
                write!(f, "worker {rank}: {msg}")
            }
            TrainError::Panic(what) => write!(f, "thread panicked: {what}"),
            TrainError::Config(msg) => write!(f, "config: {msg}"),
        }
    }
}

impl std::error::Error for TrainError {}

impl From<crate::runtime::SessionError> for TrainError {
    fn from(e: crate::runtime::SessionError) -> Self {
        TrainError::Session(e)
    }
}

impl From<crate::data::ShardError> for TrainError {
    fn from(e: crate::data::ShardError) -> Self {
        TrainError::Data(e)
    }
}

impl From<mpi::CommError> for TrainError {
    fn from(e: mpi::CommError) -> Self {
        TrainError::Comm(e)
    }
}

/// Which transport carries the training protocol.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Transport {
    /// Threads + channels (paper's shared-memory single-node case).
    Inproc,
    /// Localhost TCP mesh (cluster-style framing and copies).
    Tcp { base_port: u16 },
}

/// Full training-session configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub builder: ModelBuilder,
    pub algo: Algo,
    pub n_workers: usize,
    pub seed: u64,
    pub transport: Transport,
    /// Two-level topology; when set, `n_workers` is ignored in favor of
    /// `hierarchy.n_groups * hierarchy.workers_per_group`.
    pub hierarchy: Option<HierarchySpec>,
}

impl TrainConfig {
    pub fn new(model: &str, batch: usize, n_workers: usize) -> Self {
        TrainConfig {
            builder: ModelBuilder::new(model, batch),
            algo: Algo { batch_size: batch, ..Algo::default() },
            n_workers,
            seed: 2017,
            transport: Transport::Inproc,
            hierarchy: None,
        }
    }

    fn total_workers(&self) -> usize {
        match &self.hierarchy {
            Some(h) => h.n_groups * h.workers_per_group,
            None => self.n_workers,
        }
    }
}

/// Outcome of a training session.
pub struct TrainResult {
    pub history: History,
    pub weights: ParamSet,
    pub wallclock_s: f64,
}

/// Run a full distributed training session.
pub fn train(session: &Session, cfg: &TrainConfig, data: &Data)
    -> Result<TrainResult, TrainError> {
    crate::util::logging::init();
    let exes = session.executables(&cfg.builder.variant_key())?;
    let n_workers = cfg.total_workers();
    assert!(n_workers >= 1, "need at least one worker");

    // materialize data up front (outside the timed region, like the
    // paper's setup phase)
    let mut worker_data = Vec::with_capacity(n_workers);
    for w in 0..n_workers {
        worker_data.push(data.worker_dataset(w, n_workers)?);
    }
    let val = data.validation_dataset()?;

    let mut rng = Rng::new(cfg.seed);
    let init = ParamSet::glorot_init(&exes.meta.params, &mut rng);

    if matches!(cfg.algo.mode, Mode::AllReduce) {
        if cfg.hierarchy.is_some() {
            return Err(TrainError::Config(
                "allreduce mode is flat by construction; drop the \
                 hierarchy spec"
                    .into(),
            ));
        }
        return train_allreduce(cfg, &exes, init, worker_data, val);
    }

    match &cfg.hierarchy {
        None => train_flat(cfg, &exes, init, worker_data, val),
        Some(spec) => train_hierarchical(cfg, *spec, &exes, init,
                                         worker_data, val),
    }
}

fn make_world(transport: Transport, size: usize)
    -> Result<Vec<mpi::Comm>, TrainError> {
    Ok(match transport {
        Transport::Inproc => mpi::inproc_world(size),
        Transport::Tcp { base_port } => mpi::tcp_world(size, base_port)?,
    })
}

fn train_flat(cfg: &TrainConfig, exes: &Arc<ModelExecutables>,
              init: ParamSet, worker_data: Vec<DataSet>, val: DataSet)
    -> Result<TrainResult, TrainError> {
    let n_workers = worker_data.len();
    let mut world = make_world(cfg.transport, n_workers + 1)?;
    let master_comm = world.remove(0);
    let t0 = Instant::now();

    let outcome = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (wi, (comm, ds)) in
            world.into_iter().zip(worker_data.iter()).enumerate() {
            let algo = &cfg.algo;
            let exes = exes.clone();
            let seed = cfg.seed ^ (wi as u64 + 1).wrapping_mul(0x9E37);
            handles.push(s.spawn(move || {
                crate::util::logging::set_rank_tag(
                    &format!("worker-{}", wi + 1));
                Worker::new(&comm, 0, algo, &exes, ds, seed).run()
            }));
        }

        crate::util::logging::set_rank_tag("master");
        let ctx = MasterContext {
            algo: &cfg.algo,
            children: (1..=n_workers).collect(),
            eval: Some((exes.as_ref(), &val)),
        };
        let outcome = Master::new(&master_comm, ctx, init).run();

        for (wi, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(Ok(_report)) => {}
                Ok(Err(e)) => {
                    return Err(TrainError::Worker { rank: wi + 1,
                                                    msg: e.to_string() })
                }
                Err(_) => {
                    return Err(TrainError::Panic(format!(
                        "worker {}", wi + 1)))
                }
            }
        }
        Ok(outcome)
    })?;

    let wallclock_s = t0.elapsed().as_secs_f64();
    let mut history = outcome.history;
    history.wallclock_s = wallclock_s;
    Ok(TrainResult { history, weights: outcome.weights, wallclock_s })
}

/// Masterless all-reduce session: the world is exactly the worker set —
/// no master rank at all. Rank 0 runs on the calling thread, owns the
/// validation schedule, and returns the merged history; every rank ends
/// the run with bitwise-identical weights.
fn train_allreduce(cfg: &TrainConfig, exes: &Arc<ModelExecutables>,
                   init: ParamSet, worker_data: Vec<DataSet>, val: DataSet)
    -> Result<TrainResult, TrainError> {
    let n = worker_data.len();
    let mut world = make_world(cfg.transport, n)?;
    let t0 = Instant::now();

    let outcome = std::thread::scope(|s| {
        let rank0_comm = world.remove(0);
        let mut handles = Vec::new();
        for comm in world {
            let rank = comm.rank();
            let ds = &worker_data[rank];
            let algo = &cfg.algo;
            let exes = exes.clone();
            let seed = cfg.seed ^ (rank as u64 + 1).wrapping_mul(0x9E37);
            handles.push((rank, s.spawn(move || {
                crate::util::logging::set_rank_tag(
                    &format!("rank-{rank}"));
                RingWorker::new(&comm, algo, &exes, ds, seed, None)
                    .run(None)
                    .map(|_| ())
                    .map_err(|e| e.to_string())
            })));
        }

        crate::util::logging::set_rank_tag("rank-0");
        let seed0 = cfg.seed ^ 1u64.wrapping_mul(0x9E37);
        let outcome = RingWorker::new(&rank0_comm, &cfg.algo,
                                      exes.as_ref(), &worker_data[0],
                                      seed0,
                                      Some((exes.as_ref(), &val)))
            .run(Some(init))
            .map_err(|e| TrainError::Worker { rank: 0,
                                              msg: e.to_string() })?;

        for (rank, h) in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => {
                    return Err(TrainError::Worker { rank, msg })
                }
                Err(_) => {
                    return Err(TrainError::Panic(format!("rank {rank}")))
                }
            }
        }
        Ok(outcome)
    })?;

    let wallclock_s = t0.elapsed().as_secs_f64();
    let mut history = outcome.history;
    history.wallclock_s = wallclock_s;
    Ok(TrainResult { history, weights: outcome.weights, wallclock_s })
}

fn train_hierarchical(cfg: &TrainConfig, spec: HierarchySpec,
                      exes: &Arc<ModelExecutables>, init: ParamSet,
                      worker_data: Vec<DataSet>, val: DataSet)
    -> Result<TrainResult, TrainError> {
    let size = spec.world_size();
    let mut world = make_world(cfg.transport, size)?;
    // index worker ranks -> contiguous data shard index
    let mut worker_index = std::collections::BTreeMap::new();
    let mut next = 0usize;
    for rank in 1..size {
        if let Role::Worker { .. } = spec.role_of(rank) {
            worker_index.insert(rank, next);
            next += 1;
        }
    }
    let t0 = Instant::now();

    // The super-master integrates group deltas verbatim: identity SGD.
    let super_algo = Algo {
        optimizer: crate::optim::OptimizerConfig::Sgd { lr: 1.0 },
        ..cfg.algo.clone()
    };

    let outcome = std::thread::scope(|s| {
        let mut handles = Vec::new();
        // ranks come off the world vector highest-first
        while let Some(comm) = world.pop() {
            let rank = comm.rank();
            if rank == 0 {
                world.push(comm);
                break;
            }
            match spec.role_of(rank) {
                Role::GroupMaster { group } => {
                    let algo = &cfg.algo;
                    let exes = exes.clone();
                    handles.push(s.spawn(move || {
                        crate::util::logging::set_rank_tag(
                            &format!("gmaster-{group}"));
                        GroupMaster::new(&comm, algo, spec, group, &exes)
                            .run()
                            .map(|_| ())
                            .map_err(|e| e.to_string())
                    }));
                }
                Role::Worker { master, .. } => {
                    let algo = &cfg.algo;
                    let exes = exes.clone();
                    let wi = worker_index[&rank];
                    let ds = &worker_data[wi];
                    let seed = cfg.seed ^ (wi as u64 + 1)
                        .wrapping_mul(0x9E37);
                    handles.push(s.spawn(move || {
                        crate::util::logging::set_rank_tag(
                            &format!("worker-{rank}"));
                        Worker::new(&comm, master, algo, &exes, ds, seed)
                            .run()
                            .map(|_| ())
                            .map_err(|e| e.to_string())
                    }));
                }
                Role::SuperMaster => unreachable!(),
            }
        }

        let master_comm = world.remove(0);
        crate::util::logging::set_rank_tag("super-master");
        let ctx = MasterContext {
            algo: &super_algo,
            children: spec.group_masters(),
            eval: Some((exes.as_ref(), &val)),
        };
        let outcome = Master::new(&master_comm, ctx, init).run();

        for (i, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => {
                    return Err(TrainError::Worker { rank: i, msg })
                }
                Err(_) => return Err(TrainError::Panic(format!(
                    "rank-thread {i}"))),
            }
        }
        Ok(outcome)
    })?;

    let wallclock_s = t0.elapsed().as_secs_f64();
    let mut history = outcome.history;
    history.wallclock_s = wallclock_s;
    Ok(TrainResult { history, weights: outcome.weights, wallclock_s })
}

/// SPMD entry point: run THIS process's single rank over a TCP mesh —
/// the true multi-process cluster deployment (each rank its own OS
/// process, like `mpirun -np N`). All ranks must be started with the
/// same `cfg`/`base_port`; rank 0 is the (super-)master and returns the
/// `TrainResult`, other ranks return `Ok(None)` when their role
/// completes.
pub fn run_rank(session: &Session, cfg: &TrainConfig, data: &Data,
                rank: usize, base_port: u16)
    -> Result<Option<TrainResult>, TrainError> {
    crate::util::logging::init();
    let exes = session.executables(&cfg.builder.variant_key())?;
    let n_workers = cfg.total_workers();
    let t0 = Instant::now();

    if matches!(cfg.algo.mode, Mode::AllReduce) {
        if cfg.hierarchy.is_some() {
            return Err(TrainError::Config(
                "allreduce mode is flat by construction; drop the \
                 hierarchy spec"
                    .into(),
            ));
        }
        // Masterless: the world is exactly the worker set.
        let size = n_workers;
        let comm = crate::mpi::transport::tcp::endpoint(rank, size,
                                                        base_port)?;
        crate::util::logging::set_rank_tag(&format!("rank-{rank}"));
        let ds = data.worker_dataset(rank, size)?;
        let seed = cfg.seed ^ (rank as u64 + 1).wrapping_mul(0x9E37);
        if rank == 0 {
            let val = data.validation_dataset()?;
            let mut rng = Rng::new(cfg.seed);
            let init = ParamSet::glorot_init(&exes.meta.params, &mut rng);
            let outcome = RingWorker::new(&comm, &cfg.algo,
                                          exes.as_ref(), &ds, seed,
                                          Some((exes.as_ref(), &val)))
                .run(Some(init))
                .map_err(|e| TrainError::Worker { rank,
                                                  msg: e.to_string() })?;
            let wallclock_s = t0.elapsed().as_secs_f64();
            let mut history = outcome.history;
            history.wallclock_s = wallclock_s;
            return Ok(Some(TrainResult { history,
                                         weights: outcome.weights,
                                         wallclock_s }));
        }
        RingWorker::new(&comm, &cfg.algo, exes.as_ref(), &ds, seed, None)
            .run(None)
            .map_err(|e| TrainError::Worker { rank,
                                              msg: e.to_string() })?;
        return Ok(None);
    }

    match &cfg.hierarchy {
        None => {
            let size = n_workers + 1;
            let comm = crate::mpi::transport::tcp::endpoint(
                rank, size, base_port)?;
            if rank == 0 {
                crate::util::logging::set_rank_tag("master");
                let val = data.validation_dataset()?;
                let mut rng = Rng::new(cfg.seed);
                let init = ParamSet::glorot_init(&exes.meta.params,
                                                 &mut rng);
                let ctx = MasterContext {
                    algo: &cfg.algo,
                    children: (1..=n_workers).collect(),
                    eval: Some((exes.as_ref(), &val)),
                };
                let outcome = Master::new(&comm, ctx, init).run();
                let wallclock_s = t0.elapsed().as_secs_f64();
                let mut history = outcome.history;
                history.wallclock_s = wallclock_s;
                Ok(Some(TrainResult { history,
                                      weights: outcome.weights,
                                      wallclock_s }))
            } else {
                crate::util::logging::set_rank_tag(
                    &format!("worker-{rank}"));
                let ds = data.worker_dataset(rank - 1, n_workers)?;
                let seed = cfg.seed ^ (rank as u64)
                    .wrapping_mul(0x9E37);
                Worker::new(&comm, 0, &cfg.algo, &exes, &ds, seed)
                    .run()
                    .map_err(|e| TrainError::Worker {
                        rank, msg: e.to_string() })?;
                Ok(None)
            }
        }
        Some(spec) => {
            let size = spec.world_size();
            let comm = crate::mpi::transport::tcp::endpoint(
                rank, size, base_port)?;
            match spec.role_of(rank) {
                Role::SuperMaster => {
                    crate::util::logging::set_rank_tag("super-master");
                    let val = data.validation_dataset()?;
                    let mut rng = Rng::new(cfg.seed);
                    let init = ParamSet::glorot_init(&exes.meta.params,
                                                     &mut rng);
                    let super_algo = Algo {
                        optimizer: crate::optim::OptimizerConfig::Sgd {
                            lr: 1.0 },
                        ..cfg.algo.clone()
                    };
                    let ctx = MasterContext {
                        algo: &super_algo,
                        children: spec.group_masters(),
                        eval: Some((exes.as_ref(), &val)),
                    };
                    let outcome = Master::new(&comm, ctx, init).run();
                    let wallclock_s = t0.elapsed().as_secs_f64();
                    let mut history = outcome.history;
                    history.wallclock_s = wallclock_s;
                    Ok(Some(TrainResult { history,
                                          weights: outcome.weights,
                                          wallclock_s }))
                }
                Role::GroupMaster { group } => {
                    crate::util::logging::set_rank_tag(
                        &format!("gmaster-{group}"));
                    GroupMaster::new(&comm, &cfg.algo, *spec, group,
                                     &exes)
                        .run()?;
                    Ok(None)
                }
                Role::Worker { master, group } => {
                    crate::util::logging::set_rank_tag(
                        &format!("worker-{rank}"));
                    // contiguous worker index for data division
                    let wi = group * spec.workers_per_group
                        + (rank - master - 1);
                    let ds = data.worker_dataset(wi, n_workers)?;
                    let seed = cfg.seed ^ (wi as u64 + 1)
                        .wrapping_mul(0x9E37);
                    Worker::new(&comm, master, &cfg.algo, &exes, &ds,
                                seed)
                        .run()
                        .map_err(|e| TrainError::Worker {
                            rank, msg: e.to_string() })?;
                    Ok(None)
                }
            }
        }
    }
}

/// The "Keras alone" baseline (§V): identical compute, no framework.
/// One process runs batch -> gradient -> local optimizer update.
pub fn train_direct(session: &Session, cfg: &TrainConfig, data: &Data)
    -> Result<TrainResult, TrainError> {
    crate::util::logging::init();
    let exes = session.executables(&cfg.builder.variant_key())?;
    let ds = data.worker_dataset(0, 1)?;
    let val = data.validation_dataset()?;
    let mut rng = Rng::new(cfg.seed);
    let mut params = ParamSet::glorot_init(&exes.meta.params, &mut rng);
    let mut opt = cfg.algo.build_master_optimizer(params.num_params());
    let batch = cfg.algo.batch_size;

    let t0 = Instant::now();
    let mut history = History::default();
    let mut batches = 0u64;
    let mut last_loss = 0.0f32;
    for epoch in 0..cfg.algo.epochs {
        let mut erng = rng.fork(epoch as u64);
        let mut failure: Option<crate::runtime::RuntimeError> = None;
        let p = &mut params;
        let o = &mut opt;
        ds.for_each_batch(batch, &mut erng, |x, y| {
            if failure.is_some() {
                return;
            }
            match exes.grad_step(p, x, y) {
                Ok(out) => {
                    o.update(p.flat_mut(), &out.grads);
                    batches += 1;
                    last_loss = out.loss;
                    if batches % 16 == 0 || batches == 1 {
                        history.train_losses.push((batches, out.loss));
                    }
                }
                Err(e) => failure = Some(e),
            }
        });
        if let Some(e) = failure {
            return Err(TrainError::Worker { rank: 0, msg: e.to_string() });
        }
    }
    if let Ok((loss, acc)) = crate::coordinator::validation::run_validation(
        &exes, &params, &val, cfg.algo.max_val_batches) {
        history.validations.push(crate::metrics::ValRecord {
            t_s: t0.elapsed().as_secs_f64(),
            update: batches,
            val_loss: loss,
            val_acc: acc,
        });
    }
    let wallclock_s = t0.elapsed().as_secs_f64();
    history.master_updates = batches;
    history.wallclock_s = wallclock_s;
    history.workers.push(crate::metrics::WorkerReport {
        rank: 0,
        epochs: cfg.algo.epochs,
        batches,
        samples: batches * batch as u64,
        last_train_loss: last_loss,
        ..Default::default()
    });
    Ok(TrainResult { history, weights: params, wallclock_s })
}
