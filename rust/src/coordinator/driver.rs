//! The launcher: one role-execution path for every deployment.
//!
//! A [`WorldPlan`](crate::coordinator::topology::WorldPlan) maps the
//! config to world size + per-rank roles; [`run_role`] executes one
//! rank's role over a communicator. `train()` spawns a thread per rank
//! and runs each through `run_role` (the paper's shared-memory
//! single-node case); the SPMD [`run_rank`] opens one TCP endpoint and
//! runs the *same* `run_role` (the `mpirun`-style cluster case). New
//! topologies are a new `RankRole` case, not a new launcher.
//!
//! Also provides [`train_direct`] — the "Keras alone" baseline of §V:
//! the identical compute loop with no distribution framework at all,
//! used to measure the framework's own overhead.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::algo::{Algo, Mode};
use crate::coordinator::builder::{Data, ModelBuilder};
use crate::coordinator::callbacks::{effective_lr_schedule, Callback,
                                    CallbackSet, CallbackSpec, Observer};
use crate::coordinator::hierarchy::{GroupMaster, HierarchySpec};
use crate::coordinator::master::{Master, MasterContext};
use crate::coordinator::planner::{self, RetuneConfig, Topology};
use crate::coordinator::topology::{RankRole, WorldPlan};
use crate::coordinator::worker::{RingWorker, Worker};
use crate::data::DataSet;
use crate::metrics::History;
use crate::mpi::codec::Codec;
use crate::mpi::{self, Payload, Tag};
use crate::runtime::{ModelExecutables, Session};
use crate::simulator::{measure_costs, CostModel, LinkCalibration};
use crate::tensor::ParamSet;
use crate::util::rng::Rng;

#[derive(Debug)]
pub enum TrainError {
    Session(crate::runtime::SessionError),
    Data(crate::data::ShardError),
    Comm(mpi::CommError),
    Worker { rank: usize, msg: String },
    Panic(String),
    Config(String),
}

impl std::fmt::Display for TrainError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TrainError::Session(e) => write!(f, "session: {e}"),
            TrainError::Data(e) => write!(f, "data: {e}"),
            TrainError::Comm(e) => write!(f, "comm: {e}"),
            TrainError::Worker { rank, msg } => {
                write!(f, "worker {rank}: {msg}")
            }
            TrainError::Panic(what) => write!(f, "thread panicked: {what}"),
            TrainError::Config(msg) => write!(f, "config: {msg}"),
        }
    }
}

impl std::error::Error for TrainError {}

impl From<crate::runtime::SessionError> for TrainError {
    fn from(e: crate::runtime::SessionError) -> Self {
        TrainError::Session(e)
    }
}

impl From<crate::data::ShardError> for TrainError {
    fn from(e: crate::data::ShardError) -> Self {
        TrainError::Data(e)
    }
}

impl From<mpi::CommError> for TrainError {
    fn from(e: mpi::CommError) -> Self {
        TrainError::Comm(e)
    }
}

/// Which transport carries the training protocol.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Transport {
    /// Threads + channels (paper's shared-memory single-node case).
    Inproc,
    /// Localhost TCP mesh (cluster-style framing and copies).
    Tcp { base_port: u16 },
}

/// Full training-session configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub builder: ModelBuilder,
    pub algo: Algo,
    pub n_workers: usize,
    pub seed: u64,
    pub transport: Transport,
    /// Two-level topology; when set, `n_workers` is ignored in favor of
    /// `hierarchy.n_groups * hierarchy.workers_per_group`.
    pub hierarchy: Option<HierarchySpec>,
    /// Declarative training callbacks, observed on the master / ring
    /// rank 0 (checkpointing, early stopping, LR schedule, logging).
    pub callbacks: Vec<CallbackSpec>,
}

impl TrainConfig {
    pub fn new(model: &str, batch: usize, n_workers: usize) -> Self {
        TrainConfig {
            builder: ModelBuilder::new(model, batch),
            algo: Algo { batch_size: batch, ..Algo::default() },
            n_workers,
            seed: 2017,
            transport: Transport::Inproc,
            hierarchy: None,
            callbacks: Vec::new(),
        }
    }
}

/// Outcome of a training session.
pub struct TrainResult {
    pub history: History,
    pub weights: ParamSet,
    pub wallclock_s: f64,
}

/// What an observer role hands back to the launcher.
type RoleOutcome = Option<(History, ParamSet)>;

/// Cheap pre-launch sanity check so configuration errors surface
/// before a world is spawned (a missing shard file discovered inside a
/// lockstep collective would hang the ring instead of erroring).
fn preflight(data: &Data) -> Result<(), TrainError> {
    if let Data::Files { train, val } = data {
        for p in train.iter().chain(std::iter::once(val)) {
            if !p.exists() {
                return Err(TrainError::Config(format!(
                    "data file missing: {}", p.display())));
            }
        }
    }
    Ok(())
}

/// Ring worlds run lockstep collectives from the first broadcast, so a
/// rank that dies materializing its data would stall every peer
/// forever (peers' receivers stay connected while ANY rank lives).
/// Materialize-check every input up front instead — PS modes skip
/// this: they degrade cleanly through the Exit protocol.
fn preflight_ring(plan: &WorldPlan, data: &Data)
    -> Result<(), TrainError> {
    if plan.is_ring() {
        for w in 0..plan.n_shards() {
            data.worker_dataset(w, plan.n_shards())?;
        }
        data.validation_dataset()?;
    }
    Ok(())
}

/// Observer wiring for the rank that owns validation + callbacks: the
/// spec-built set from the config, plus any caller-supplied trait
/// objects.
fn build_observer<'a>(cfg: &'a TrainConfig,
                      exes: &'a ModelExecutables, val: &'a DataSet,
                      extra: Vec<Box<dyn Callback>>, n_params: usize)
    -> Observer<'a> {
    let mut callbacks =
        CallbackSet::from_config(&cfg.algo, &cfg.callbacks);
    for cb in extra {
        callbacks.push(cb);
    }
    let mut observer =
        Observer::new(&cfg.algo, Some((exes, val)), callbacks);
    observer.begin(n_params);
    observer
}

/// Execute rank `rank`'s role of `plan` over `comm`.
///
/// THE single orchestration path: `train()` runs it on one thread per
/// rank, `run_rank()` runs it on one process per rank. Returns
/// `Some((history, weights))` on the observer rank (always rank 0),
/// `None` elsewhere. `extra` callbacks (non-cloneable trait objects,
/// e.g. from `Experiment::callback`) join the spec-built set on the
/// observer.
fn run_role(plan: &WorldPlan, cfg: &TrainConfig,
            exes: &Arc<ModelExecutables>, data: &Data,
            comm: &mpi::Comm, extra: Vec<Box<dyn Callback>>)
    -> Result<RoleOutcome, TrainError> {
    let rank = comm.rank();
    crate::util::logging::set_rank_tag(&plan.rank_tag(rank));
    match plan.role_of(rank) {
        RankRole::Master => {
            let val = match data.validation_dataset() {
                Ok(v) => v,
                Err(e) => {
                    // unblock handshaking children before erroring
                    for child in plan.master_children() {
                        let _ = comm.send(child, Tag::Exit,
                                          Payload::Empty);
                    }
                    return Err(TrainError::Data(e));
                }
            };
            let mut rng = Rng::new(cfg.seed);
            let init = ParamSet::glorot_init(&exes.meta.params, &mut rng);
            let observer = build_observer(cfg, exes.as_ref(), &val,
                                          extra, init.num_params());
            // The super-master integrates group deltas verbatim:
            // identity SGD (the group master pre-negates its delta).
            let super_algo;
            let algo = if plan.is_hierarchical() {
                super_algo = Algo {
                    optimizer: crate::optim::OptimizerConfig::Sgd {
                        lr: 1.0 },
                    ..cfg.algo.clone()
                };
                &super_algo
            } else {
                &cfg.algo
            };
            let ctx = MasterContext {
                algo,
                children: plan.master_children(),
                observer,
            };
            let outcome = Master::new(comm, ctx, init)
                .with_pool(exes.thread_pool())
                .run();
            Ok(Some((outcome.history, outcome.weights)))
        }
        RankRole::GroupMaster { group } => {
            let spec = *plan.hierarchy().expect("group master implies \
                                                 hierarchy");
            GroupMaster::new(comm, &cfg.algo, spec, group, exes)
                .run()
                .map_err(TrainError::Comm)?;
            Ok(None)
        }
        RankRole::Worker { master, shard } => {
            let ds = match data.worker_dataset(shard, plan.n_shards()) {
                Ok(ds) => ds,
                Err(e) => {
                    // a silent death would hang the master's Exit count
                    let _ = comm.send(master, Tag::Exit, Payload::Empty);
                    return Err(TrainError::Data(e));
                }
            };
            if let Err(e) = Worker::new(comm, master, &cfg.algo, exes,
                                        &ds, plan.seed_of(rank))
                .run() {
                let _ = comm.send(master, Tag::Exit, Payload::Empty);
                return Err(TrainError::Worker { rank,
                                                msg: e.to_string() });
            }
            Ok(None)
        }
        RankRole::RingRank { shard, .. } => {
            let ds = data.worker_dataset(shard, plan.n_shards())?;
            let lr = effective_lr_schedule(&cfg.algo, &cfg.callbacks);
            let seed = plan.seed_of(rank);
            // grouped (hierarchical) ring worlds hand the collective
            // its GroupLayout; flat rings pass None
            let layout = plan.ring_layout();
            // Elastic mode: the worker replans from the launch plan on
            // churn and re-shards the dataset over member positions.
            let resharder = |pos: usize, m: usize| {
                data.worker_dataset(pos, m).map_err(|e| e.to_string())
            };
            let timeout = std::time::Duration::from_millis(
                cfg.algo.elastic_timeout_ms.max(1));
            if rank == plan.observer() {
                let val = data.validation_dataset()?;
                let mut rng = Rng::new(cfg.seed);
                let init = ParamSet::glorot_init(&exes.meta.params,
                                                 &mut rng);
                let mut observer = build_observer(cfg, exes.as_ref(),
                                                  &val, extra,
                                                  init.num_params());
                let mut w = RingWorker::new(comm, &cfg.algo,
                                            exes.as_ref(), &ds, seed,
                                            lr)
                    .with_groups(layout);
                if cfg.algo.elastic {
                    w = w.with_elastic(plan.clone(), timeout)
                        .with_resharder(&resharder);
                }
                let outcome = w
                    .run(Some(init), &mut observer)
                    .map_err(|e| TrainError::Worker {
                        rank, msg: e.to_string() })?;
                Ok(Some((outcome.history, outcome.weights)))
            } else {
                let mut observer = Observer::disabled();
                let mut w = RingWorker::new(comm, &cfg.algo,
                                            exes.as_ref(), &ds, seed,
                                            lr)
                    .with_groups(layout);
                if cfg.algo.elastic {
                    w = w.with_elastic(plan.clone(), timeout)
                        .with_resharder(&resharder);
                }
                w.run(None, &mut observer)
                    .map_err(|e| TrainError::Worker {
                        rank, msg: e.to_string() })?;
                Ok(None)
            }
        }
    }
}

fn make_world(transport: Transport, size: usize)
    -> Result<Vec<mpi::Comm>, TrainError> {
    Ok(match transport {
        Transport::Inproc => mpi::inproc_world(size),
        Transport::Tcp { base_port } => mpi::tcp_world(size, base_port)?,
    })
}

/// Probe both link classes over a short-lived world of the training
/// transport: peers echo in [`planner::respond_probe`], rank 0 times
/// ping-pongs against the provisional layout's intra/inter peers. The
/// sentinel is sent even when probing fails — a responder that never
/// hears it would hang the join.
fn probe_links(transport: Transport, n: usize)
    -> Result<LinkCalibration, TrainError> {
    let mut world = make_world(transport, n)?;
    let comm0 = world.remove(0);
    let (intra_peer, inter_peer) = planner::probe_peers(n);
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for comm in world {
            let rank = comm.rank();
            handles.push((rank, s.spawn(move || {
                planner::respond_probe(&comm).map_err(|e| e.to_string())
            })));
        }
        let mut seq = 0u64;
        let probed = probe_link_classes(&comm0, intra_peer, inter_peer,
                                        &mut seq);
        let _ = planner::finish_probe(&comm0, n);
        let joined = join_ranks(handles);
        let links = probed.map_err(TrainError::Comm)?;
        joined?;
        Ok(links)
    })
}

fn probe_link_classes(comm0: &mpi::Comm, intra_peer: usize,
                      inter_peer: Option<usize>, seq: &mut u64)
    -> Result<LinkCalibration, mpi::CommError> {
    let intra = planner::probe_link(comm0, intra_peer, seq)?;
    // a world with a single link class (too small / ragged to group)
    // uses the one measurement for both model slots
    let inter = match inter_peer {
        Some(p) => planner::probe_link(comm0, p, seq)?,
        None => intra,
    };
    Ok(LinkCalibration { intra, inter })
}

/// The self-tuning startup phase (DESIGN.md §Autotuning): probe the
/// links over a short-lived world, calibrate the compute costs on the
/// real executables, sweep the closed-form round-time models, and
/// return a copy of `cfg` with the winning topology pinned in —
/// hierarchy, codec, bucketing — plus the [`RetuneConfig`] the worker's
/// online re-tuner runs against. The returned config trains through the
/// exact same `WorldPlan` path as a hand-flagged one.
fn auto_tune_config(cfg: &TrainConfig, exes: &Arc<ModelExecutables>)
    -> Result<TrainConfig, TrainError> {
    if cfg.algo.mode != Mode::AllReduce {
        return Err(TrainError::Config(
            "auto-tuning requires allreduce mode — the planner tunes \
             ring topologies, not parameter-server worlds".into()));
    }
    if cfg.hierarchy.is_some() {
        return Err(TrainError::Config(
            "auto and an explicit hierarchy are mutually exclusive: \
             drop the hierarchy to let the planner pick the grouping, \
             or drop auto to pin it".into()));
    }
    let n = cfg.n_workers;
    // TCP probe worlds bind above the training ports so the training
    // world never races a lingering probe socket on rebind
    let probe_transport = match cfg.transport {
        Transport::Inproc => Transport::Inproc,
        Transport::Tcp { base_port } => Transport::Tcp {
            base_port: base_port + n as u16 },
    };
    let links = if n >= 2 {
        probe_links(probe_transport, n)?
    } else {
        LinkCalibration {
            intra: crate::simulator::LinkCost::unprobed(),
            inter: crate::simulator::LinkCost::unprobed(),
        }
    };
    let cal = measure_costs(exes, &cfg.algo.optimizer, 9);
    let mut cost = CostModel::cluster(exes.meta.param_count);
    cal.apply(&mut cost);
    links.apply(&mut cost);
    log::info!(
        "[planner] probe intra latency={:.3e}s bw={:.3e}B/s | inter \
         latency={:.3e}s bw={:.3e}B/s | grad={:.3e}s noise={:.1}%",
        links.intra.latency_s, links.intra.bandwidth_bytes_per_s,
        links.inter.latency_s, links.inter.bandwidth_bytes_per_s,
        cal.t_grad,
        100.0 * links.rel_spread().max(cal.grad_rel_spread));

    // the codec axis is swept only when the operator left it at the
    // fp32 default; an explicit codec (incl. top-k) is a pin
    let codecs = if cfg.algo.compression == Codec::Fp32 {
        vec![Codec::Fp32, Codec::Fp16]
    } else {
        vec![cfg.algo.compression]
    };
    let choice = planner::sweep(&cost, n, cfg.algo.batch_size, &codecs,
                                cfg.algo.buckets);
    for line in choice.log_lines() {
        log::info!("{line}");
    }

    let mut tuned = cfg.clone();
    match choice.chosen.topology {
        Topology::Flat => {
            tuned.hierarchy = None;
            tuned.algo.buckets = false;
        }
        Topology::FlatBucketed { .. } => {
            tuned.hierarchy = None;
            tuned.algo.buckets = true;
        }
        Topology::Hier { groups } => {
            tuned.hierarchy = Some(HierarchySpec {
                n_groups: groups,
                workers_per_group: 0,
                sync_every: 1,
            });
            tuned.algo.buckets = false;
        }
    }
    tuned.algo.compression = choice.chosen.codec;
    tuned.algo.retune = Some(RetuneConfig {
        predicted_round_s: choice.chosen.predicted_s,
        factor: cfg.algo.retune_factor,
        window: cfg.algo.retune_window,
        max_replans: planner::MAX_RETUNE_REPLANS,
        noise_floor: links.rel_spread().max(cal.grad_rel_spread),
    });
    Ok(tuned)
}

/// Join per-rank threads, attributing a failure to the thread's REAL
/// rank. (Regression guard: the old hierarchical launcher reported the
/// spawn-handle index as the rank.)
fn join_ranks(
    handles: Vec<(usize,
                  std::thread::ScopedJoinHandle<'_, Result<(), String>>)>,
) -> Result<(), TrainError> {
    for (rank, h) in handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => {
                return Err(TrainError::Worker { rank, msg })
            }
            Err(_) => {
                return Err(TrainError::Panic(format!("rank {rank}")))
            }
        }
    }
    Ok(())
}

/// Run a full distributed training session in-process: one thread per
/// rank of the plan, every thread through [`run_role`].
pub fn train(session: &Session, cfg: &TrainConfig, data: &Data)
    -> Result<TrainResult, TrainError> {
    train_with_callbacks(session, cfg, data, Vec::new())
}

/// [`train`] with additional non-declarative callbacks (custom
/// [`Callback`] impls) attached to the observer rank.
pub fn train_with_callbacks(session: &Session, cfg: &TrainConfig,
                            data: &Data,
                            extra: Vec<Box<dyn Callback>>)
    -> Result<TrainResult, TrainError> {
    crate::util::logging::init();
    let exes = session.executables(&cfg.builder.variant_key())?;
    // Size the compute pool before anything touches the kernels —
    // in particular before the auto phase's measure_costs, so the
    // calibrated compute term reflects the pool the run will use.
    // In-process ranks share the executables, so one call covers all.
    exes.set_threads(cfg.algo.threads);
    // Auto-tuned runs probe + sweep FIRST, then train through the same
    // plan path as a hand-flagged config (DESIGN.md §Autotuning).
    let tuned;
    let cfg = if cfg.algo.auto {
        tuned = auto_tune_config(cfg, &exes)?;
        &tuned
    } else {
        cfg
    };
    let plan = WorldPlan::new(cfg).map_err(TrainError::Config)?;
    preflight(data)?;
    preflight_ring(&plan, data)?;
    let mut world = make_world(cfg.transport, plan.world_size())?;
    let comm0 = world.remove(0);
    let t0 = Instant::now();

    let plan_ref = &plan;
    let outcome = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for comm in world {
            let rank = comm.rank();
            let exes = exes.clone();
            handles.push((rank, s.spawn(move || {
                run_role(plan_ref, cfg, &exes, data, &comm, Vec::new())
                    .map(|_| ())
                    .map_err(|e| e.to_string())
            })));
        }
        let result = run_role(plan_ref, cfg, &exes, data, &comm0, extra);
        let joined = join_ranks(handles);
        let outcome = result?;
        joined?;
        Ok(outcome.expect("rank 0 is the observer role"))
    })?;

    let wallclock_s = t0.elapsed().as_secs_f64();
    let (mut history, weights) = outcome;
    history.wallclock_s = wallclock_s;
    Ok(TrainResult { history, weights, wallclock_s })
}

/// SPMD entry point: run THIS process's single rank over a TCP mesh —
/// the true multi-process cluster deployment (each rank its own OS
/// process, like `mpirun -np N`). All ranks must be started with the
/// same `cfg`/`base_port`; rank 0 is the observer and returns the
/// `TrainResult`, other ranks return `Ok(None)` when their role
/// completes. Identical role execution to [`train`] — both call
/// [`run_role`].
pub fn run_rank(session: &Session, cfg: &TrainConfig, data: &Data,
                rank: usize, base_port: u16)
    -> Result<Option<TrainResult>, TrainError> {
    crate::util::logging::init();
    if cfg.algo.auto {
        // every SPMD process derives its role from the SAME config
        // before any connection exists, so a rank-0 probe could never
        // reshape the world the other processes already committed to
        return Err(TrainError::Config(
            "auto-tuning is not available under SPMD run_rank: run the \
             probe via train(), or pin a topology explicitly (see \
             docs/RUNBOOK.md)".into()));
    }
    let plan = WorldPlan::new(cfg).map_err(TrainError::Config)?;
    let exes = session.executables(&cfg.builder.variant_key())?;
    exes.set_threads(cfg.algo.threads);
    preflight(data)?;
    let t0 = Instant::now();
    let comm = crate::mpi::transport::tcp::endpoint(
        rank, plan.world_size(), base_port)?;
    match run_role(&plan, cfg, &exes, data, &comm, Vec::new())? {
        Some((mut history, weights)) => {
            let wallclock_s = t0.elapsed().as_secs_f64();
            history.wallclock_s = wallclock_s;
            Ok(Some(TrainResult { history, weights, wallclock_s }))
        }
        None => Ok(None),
    }
}

/// The "Keras alone" baseline (§V): identical compute, no framework.
/// One process runs batch -> gradient -> local optimizer update. The
/// same [`Observer`] drives validation and callbacks, so early
/// stopping / checkpointing behave identically to the distributed
/// modes.
pub fn train_direct(session: &Session, cfg: &TrainConfig, data: &Data)
    -> Result<TrainResult, TrainError> {
    crate::util::logging::init();
    let exes = session.executables(&cfg.builder.variant_key())?;
    exes.set_threads(cfg.algo.threads);
    preflight(data)?;
    let ds = data.worker_dataset(0, 1)?;
    let val = data.validation_dataset()?;
    let mut rng = Rng::new(cfg.seed);
    let mut params = ParamSet::glorot_init(&exes.meta.params, &mut rng);
    let mut opt = cfg.algo.build_master_optimizer(params.num_params());
    let batch = cfg.algo.batch_size;
    let mut observer = build_observer(cfg, exes.as_ref(), &val,
                                      Vec::new(), params.num_params());

    let t0 = Instant::now();
    let mut history = History::default();
    let mut batches = 0u64;
    let mut last_loss = 0.0f32;
    let mut stopped = false;
    for epoch in 0..cfg.algo.epochs {
        let mut erng = rng.fork(epoch as u64);
        let mut failure: Option<crate::runtime::RuntimeError> = None;
        let p = &mut params;
        let o = &mut opt;
        let obs = &mut observer;
        let hist = &mut history;
        let stop = &mut stopped;
        ds.for_each_batch(batch, &mut erng, |x, y| {
            if failure.is_some() || *stop {
                return;
            }
            match exes.grad_step(p, x, y) {
                Ok(out) => {
                    if let Some(scale) = obs.take_lr_scale() {
                        o.set_lr_scale(scale);
                    }
                    o.update(p.flat_mut(), &out.grads);
                    batches += 1;
                    last_loss = out.loss;
                    obs.after_update(batches, out.loss, p,
                                     t0.elapsed().as_secs_f64(), hist);
                    if obs.should_stop() {
                        *stop = true;
                    }
                }
                Err(e) => failure = Some(e),
            }
        });
        if let Some(e) = failure {
            return Err(TrainError::Worker { rank: 0, msg: e.to_string() });
        }
        if stopped {
            break;
        }
    }
    let wallclock_s = t0.elapsed().as_secs_f64();
    history.master_updates = batches;
    history.wallclock_s = wallclock_s;
    history.workers.push(crate::metrics::WorkerReport {
        rank: 0,
        epochs: cfg.algo.epochs,
        batches,
        samples: batches * batch as u64,
        last_train_loss: last_loss,
        ..Default::default()
    });
    observer.finish(batches, &params, t0.elapsed().as_secs_f64(),
                    &mut history);
    Ok(TrainResult { history, weights: params, wallclock_s })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Regression (ISSUE 2 satellite): a failing rank thread must be
    /// reported by its REAL rank, not its position in the spawn list —
    /// the old `train_hierarchical` used the handle index.
    #[test]
    fn join_ranks_reports_real_rank_not_handle_index() {
        std::thread::scope(|s| {
            let handles = vec![
                (7usize, s.spawn(|| Ok::<(), String>(()))),
                (3usize, s.spawn(|| Err("boom".to_string()))),
                (5usize, s.spawn(|| Ok::<(), String>(()))),
            ];
            match join_ranks(handles) {
                Err(TrainError::Worker { rank, msg }) => {
                    assert_eq!(rank, 3, "must report the rank label");
                    assert_eq!(msg, "boom");
                }
                other => panic!("expected Worker error, got {other:?}"),
            }
        });
    }

    #[test]
    fn preflight_catches_missing_files() {
        let data = Data::Files {
            train: vec![std::path::PathBuf::from(
                "/nonexistent_mpi_learn/shard_0.mpil")],
            val: std::path::PathBuf::from(
                "/nonexistent_mpi_learn/val.mpil"),
        };
        assert!(matches!(preflight(&data),
                         Err(TrainError::Config(_))));
    }
}
