//! `Experiment` — the documented front door of the framework.
//!
//! The paper's pitch is a Keras-sized user API: pick a model, point at
//! data, attach the usual training conveniences, call one method. The
//! fluent builder collapses `TrainConfig` + `Data` + callback wiring
//! into a single chain:
//!
//! ```no_run
//! use mpi_learn::coordinator::Experiment;
//!
//! let session = mpi_learn::runtime::Session::open_default()?;
//! let result = Experiment::new("lstm")
//!     .batch(100)
//!     .workers(8)
//!     .allreduce()
//!     .early_stopping(3)
//!     .checkpoint("runs/ckpt")
//!     .run(&session)?;
//! println!("best val acc: {:?}", result.history.best_val_acc());
//! # Ok::<(), Box<dyn std::error::Error>>(())
//! ```
//!
//! Every knob maps 1:1 onto the JSON config schema (see `config` and
//! DESIGN.md), so a chain is equally expressible as a versioned config
//! file run with `mpi-learn train --config job.json`.

use std::path::Path;

use crate::coordinator::algo::{Algo, Mode};
use crate::coordinator::builder::{Data, ModelBuilder};
use crate::coordinator::callbacks::{Callback, CallbackSpec,
                                    LrScheduleSpec};
use crate::coordinator::driver::{train_direct, train_with_callbacks,
                                 TrainConfig, TrainError, TrainResult,
                                 Transport};
use crate::coordinator::hierarchy::HierarchySpec;
use crate::data::GeneratorConfig;
use crate::mpi::codec::Codec;
use crate::optim::OptimizerConfig;
use crate::runtime::Session;

/// Fluent one-call training API. See the module docs for the shape.
pub struct Experiment {
    cfg: TrainConfig,
    data: Data,
    extra: Vec<Box<dyn Callback>>,
    direct: bool,
}

impl Experiment {
    /// Start an experiment on model family `model` ("mlp", "lstm",
    /// "transformer"). Defaults: batch 100, 1 worker, async Downpour,
    /// in-process transport, synthetic benchmark data.
    pub fn new(model: &str) -> Self {
        Self {
            cfg: TrainConfig::new(model, 100, 1),
            data: Data::Synthetic {
                gen: GeneratorConfig::default(),
                samples_per_worker: 2000,
                val_samples: 1000,
            },
            extra: Vec::new(),
            direct: false,
        }
    }

    /// Batch size (must match an AOT artifact / native variant).
    pub fn batch(mut self, batch: usize) -> Self {
        self.cfg.builder = ModelBuilder::new(&self.cfg.builder.model,
                                             batch);
        self.cfg.algo.batch_size = batch;
        self
    }

    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.n_workers = n;
        self
    }

    pub fn epochs(mut self, epochs: u32) -> Self {
        self.cfg.algo.epochs = epochs;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn optimizer(mut self, opt: OptimizerConfig) -> Self {
        self.cfg.algo.optimizer = opt;
        self
    }

    /// Validate on the observer every `every` updates (0 = only at the
    /// end), capped at `max_batches` batches per sweep (0 = all).
    pub fn validate_every(mut self, every: u64) -> Self {
        self.cfg.algo.validate_every = every;
        self
    }

    pub fn max_val_batches(mut self, max_batches: usize) -> Self {
        self.cfg.algo.max_val_batches = max_batches;
        self
    }

    pub fn grad_clip(mut self, max_norm: f32) -> Self {
        self.cfg.algo.grad_clip = max_norm;
        self
    }

    /// Compute threads per rank for the native kernel pool (GEMMs,
    /// gate activations, optimizer steps, fp16 codec). `0` (the
    /// default) auto-detects from `available_parallelism`; `1` pins
    /// the serial path. Training results are bitwise-identical at any
    /// value — the pool only partitions index ranges, never the
    /// accumulation order (DESIGN.md §Compute kernels).
    ///
    /// ```
    /// use mpi_learn::coordinator::Experiment;
    ///
    /// let exp = Experiment::new("mlp").workers(4).threads(2);
    /// assert_eq!(exp.config().algo.threads, 2);
    /// ```
    pub fn threads(mut self, n: usize) -> Self {
        self.cfg.algo.threads = n;
        self
    }

    // --- distributed algorithm -----------------------------------

    /// Full [`Algo`] override — the escape hatch for variants the
    /// named setters don't cover (e.g. a custom EASGD worker
    /// optimizer). The batch size set via [`Experiment::batch`] is
    /// kept.
    pub fn algo(mut self, algo: Algo) -> Self {
        let batch = self.cfg.algo.batch_size;
        self.cfg.algo = algo;
        self.cfg.algo.batch_size = batch;
        self
    }

    /// Asynchronous Downpour SGD (the paper default).
    pub fn downpour(mut self) -> Self {
        self.cfg.algo.mode = Mode::Downpour { sync: false };
        self
    }

    /// Downpour behind a synchronous barrier.
    pub fn downpour_sync(mut self) -> Self {
        self.cfg.algo.mode = Mode::Downpour { sync: true };
        self
    }

    /// Elastic Averaging SGD: exchange every `tau` batches with force
    /// `alpha`.
    pub fn easgd(mut self, tau: u32, alpha: f32) -> Self {
        self.cfg.algo.mode = Mode::Easgd {
            tau,
            alpha,
            worker_optimizer: OptimizerConfig::Sgd { lr: 0.05 },
        };
        self
    }

    /// Masterless synchronous ring all-reduce.
    pub fn allreduce(mut self) -> Self {
        self.cfg.algo.mode = Mode::AllReduce;
        self
    }

    /// Masterless **hierarchical** all-reduce: the world is split into
    /// `groups` contiguous intra-group rings joined by an inter-group
    /// leader tree — the `2(n-1)` flat-ring latency term becomes
    /// `2(m-1) + O(log groups)`. The per-group size is derived from
    /// [`Experiment::workers`] when the world is planned (call order
    /// does not matter); `workers` must divide evenly into `groups`
    /// (>= 2) or the plan is rejected with the offending keys named.
    pub fn allreduce_grouped(mut self, groups: usize) -> Self {
        self.cfg.algo.mode = Mode::AllReduce;
        self.cfg.hierarchy = Some(HierarchySpec {
            n_groups: groups,
            workers_per_group: 0, // derived from workers at plan time
            sync_every: 1,        // unused by the ring topology
        });
        self
    }

    /// Compress gradient exchange on the wire: [`Codec::Fp16`]
    /// (half-precision, ~0.5x bytes) or [`Codec::TopK`] (magnitude
    /// sparsification with error feedback, ~2k x bytes). Applies to
    /// every mode: ring collective hops, PS gradient uplinks, and —
    /// under fp16 — weight replication hops too.
    pub fn compression(mut self, codec: Codec) -> Self {
        self.cfg.algo.compression = codec;
        self
    }

    /// All-reduce mode: launch one all-reduce per layer bucket as its
    /// gradient lands during backprop, overlapping communication with
    /// the rest of the backward pass. Identical training results
    /// (bitwise under fp32/fp16); composes with
    /// [`Experiment::compression`] and grouped topologies. See
    /// DESIGN.md §Layer DAG & bucketed overlap.
    pub fn buckets(mut self) -> Self {
        self.cfg.algo.buckets = true;
        self
    }

    /// All-reduce mode: survive rank churn. When a rank dies mid-run
    /// the survivors detect the silence within `timeout_ms`, agree on
    /// the member set, re-form the ring over the survivors, re-shard
    /// the dataset, and resume from replicated weights; late joiners
    /// are re-admitted through the same path. See DESIGN.md
    /// §Elasticity and docs/RUNBOOK.md for the protocol and operator
    /// knobs.
    ///
    /// ```
    /// use mpi_learn::coordinator::Experiment;
    ///
    /// let exp = Experiment::new("mlp")
    ///     .workers(8)
    ///     .allreduce()
    ///     .elastic(5_000);
    /// assert!(exp.config().algo.elastic);
    /// assert_eq!(exp.config().algo.elastic_timeout_ms, 5_000);
    /// ```
    pub fn elastic(mut self, timeout_ms: u64) -> Self {
        self.cfg.algo.elastic = true;
        if timeout_ms > 0 {
            self.cfg.algo.elastic_timeout_ms = timeout_ms;
        }
        self
    }

    /// All-reduce mode: self-tune the topology instead of flagging it.
    /// At startup rank 0 probes the links (latency + bandwidth, intra
    /// vs inter class), calibrates the cost model with measured compute
    /// costs, and the planner sweep picks flat-vs-hierarchical, the
    /// group count, the wire codec, and bucketing by minimizing the
    /// predicted round time; an online re-tuner watches measured round
    /// times against the prediction (DESIGN.md §Autotuning,
    /// docs/RUNBOOK.md). Mutually exclusive with an explicit
    /// [`Experiment::hierarchy`] / [`Experiment::allreduce_grouped`];
    /// an explicit [`Experiment::compression`] or
    /// [`Experiment::buckets`] pins that axis of the sweep.
    ///
    /// ```
    /// use mpi_learn::coordinator::Experiment;
    ///
    /// let exp = Experiment::new("mlp")
    ///     .workers(8)
    ///     .allreduce()
    ///     .auto_tune();
    /// assert!(exp.config().algo.auto);
    /// ```
    pub fn auto_tune(mut self) -> Self {
        self.cfg.algo.auto = true;
        self
    }

    /// Two-level topology: a Downpour master tree, or — combined with
    /// [`Experiment::allreduce`] — hierarchical all-reduce groups
    /// (`sync_every` is ignored there; see
    /// [`Experiment::allreduce_grouped`] for the shorthand).
    pub fn hierarchy(mut self, groups: usize, workers_per_group: usize,
                     sync_every: u64) -> Self {
        self.cfg.hierarchy = Some(HierarchySpec {
            n_groups: groups,
            workers_per_group,
            sync_every,
        });
        self
    }

    /// Carry the protocol over a localhost TCP mesh instead of
    /// in-process channels.
    pub fn tcp(mut self, base_port: u16) -> Self {
        self.cfg.transport = Transport::Tcp { base_port };
        self
    }

    // --- data ----------------------------------------------------

    /// Explicit data source (shard files or synthetic).
    pub fn data(mut self, data: Data) -> Self {
        self.data = data;
        self
    }

    /// Synthetic benchmark data with the given per-worker/validation
    /// sample counts.
    pub fn synthetic(mut self, samples_per_worker: usize,
                     val_samples: usize) -> Self {
        self.data = Data::Synthetic {
            gen: GeneratorConfig::default(),
            samples_per_worker,
            val_samples,
        };
        self
    }

    // --- callbacks -----------------------------------------------

    /// Stop when val loss hasn't improved for `patience` validations.
    pub fn early_stopping(mut self, patience: u32) -> Self {
        self.cfg.callbacks.push(CallbackSpec::EarlyStopping {
            patience,
            min_delta: 0.0,
        });
        self
    }

    /// Best-validation-loss checkpointing into `dir/best.mplw`.
    pub fn checkpoint(mut self, dir: impl AsRef<Path>) -> Self {
        self.cfg.callbacks.push(CallbackSpec::ModelCheckpoint {
            dir: dir.as_ref().to_path_buf(),
            every: 0,
            best_only: true,
        });
        self
    }

    /// Best checkpoint plus periodic `checkpoint-{update}.mplw` files.
    pub fn checkpoint_every(mut self, dir: impl AsRef<Path>,
                            every: u64) -> Self {
        self.cfg.callbacks.push(CallbackSpec::ModelCheckpoint {
            dir: dir.as_ref().to_path_buf(),
            every,
            best_only: false,
        });
        self
    }

    /// Step LR decay: multiply by `gamma` every `every` updates.
    pub fn lr_step(mut self, gamma: f32, every: u64) -> Self {
        self.cfg.callbacks.push(CallbackSpec::LrSchedule(
            LrScheduleSpec::Step { gamma, every }));
        self
    }

    /// Exponential LR decay: multiply by `gamma` per update.
    pub fn lr_exponential(mut self, gamma: f32) -> Self {
        self.cfg.callbacks.push(CallbackSpec::LrSchedule(
            LrScheduleSpec::Exponential { gamma }));
        self
    }

    /// Stream per-round/validation metrics as JSON lines.
    pub fn jsonl_log(mut self, path: impl AsRef<Path>) -> Self {
        self.cfg.callbacks.push(CallbackSpec::JsonlLogger {
            path: path.as_ref().to_path_buf(),
        });
        self
    }

    /// Attach a custom [`Callback`] implementation.
    pub fn callback(mut self, cb: Box<dyn Callback>) -> Self {
        self.extra.push(cb);
        self
    }

    /// Run the "Keras alone" single-process baseline instead of the
    /// distributed framework (§V overhead measurements).
    pub fn direct(mut self) -> Self {
        self.direct = true;
        self
    }

    // --- launch --------------------------------------------------

    /// The resolved `TrainConfig` (inspection / tests / config export).
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// Launch the experiment on `session` and block until done.
    pub fn run(self, session: &Session)
        -> Result<TrainResult, TrainError> {
        if self.direct {
            train_direct(session, &self.cfg, &self.data)
        } else {
            train_with_callbacks(session, &self.cfg, &self.data,
                                 self.extra)
        }
    }
}

/// Convenience: build an `Experiment` from a parsed config file.
impl From<crate::coordinator::config::JobConfig> for Experiment {
    fn from(job: crate::coordinator::config::JobConfig) -> Self {
        Experiment {
            cfg: job.train,
            data: job.data,
            extra: Vec::new(),
            direct: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_builds_expected_config() {
        let exp = Experiment::new("lstm")
            .batch(50)
            .workers(8)
            .allreduce()
            .epochs(2)
            .seed(7)
            .early_stopping(3)
            .checkpoint("/tmp/mpi_learn_exp_ckpt")
            .lr_step(0.5, 100);
        let cfg = exp.config();
        assert_eq!(cfg.builder.variant_key(), "lstm_b50");
        assert_eq!(cfg.algo.batch_size, 50);
        assert_eq!(cfg.n_workers, 8);
        assert_eq!(cfg.algo.mode, Mode::AllReduce);
        assert_eq!(cfg.algo.epochs, 2);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.callbacks.len(), 3);
        assert!(matches!(cfg.callbacks[0],
                         CallbackSpec::EarlyStopping { patience: 3, .. }));
        assert!(matches!(cfg.callbacks[1],
                         CallbackSpec::ModelCheckpoint {
                             best_only: true, every: 0, .. }));
        assert!(matches!(cfg.callbacks[2],
                         CallbackSpec::LrSchedule(
                             LrScheduleSpec::Step { every: 100, .. })));
    }

    #[test]
    fn hierarchy_and_transport_knobs() {
        let exp = Experiment::new("mlp")
            .workers(4)
            .hierarchy(2, 2, 5)
            .tcp(47123)
            .downpour_sync();
        let cfg = exp.config();
        assert_eq!(cfg.hierarchy.unwrap().n_groups, 2);
        assert_eq!(cfg.transport, Transport::Tcp { base_port: 47123 });
        assert_eq!(cfg.algo.mode, Mode::Downpour { sync: true });
    }

    #[test]
    fn grouped_allreduce_knob() {
        use crate::coordinator::topology::WorldPlan;
        // the split is derived at plan time, so builder order must not
        // matter (regression: an early version froze it at call time)
        for exp in [
            Experiment::new("mlp").workers(8).allreduce_grouped(2),
            Experiment::new("mlp").allreduce_grouped(2).workers(8),
        ] {
            let cfg = exp.config();
            assert_eq!(cfg.algo.mode, Mode::AllReduce);
            assert_eq!(cfg.hierarchy.unwrap().n_groups, 2);
            let plan = WorldPlan::new(cfg).unwrap();
            assert_eq!(plan.world_size(), 8);
            let layout = plan.ring_layout().unwrap();
            assert_eq!(layout.leaders(), vec![0, 4]);
        }
        // non-divisible splits are rejected at plan time, naming keys
        let exp = Experiment::new("mlp").workers(7).allreduce_grouped(2);
        let err = WorldPlan::new(exp.config()).unwrap_err();
        assert!(err.contains("\"workers\"") && err.contains("\"groups\""),
                "{err}");
    }

    #[test]
    fn buckets_knob() {
        let exp = Experiment::new("mlp").allreduce().buckets();
        assert!(exp.config().algo.buckets);
        assert!(!Experiment::new("mlp").config().algo.buckets);
    }

    #[test]
    fn elastic_knob() {
        let exp = Experiment::new("mlp").allreduce().elastic(2_000);
        assert!(exp.config().algo.elastic);
        assert_eq!(exp.config().algo.elastic_timeout_ms, 2_000);
        // 0 keeps the default window rather than a zero-length one
        let exp = Experiment::new("mlp").allreduce().elastic(0);
        assert!(exp.config().algo.elastic);
        assert_eq!(exp.config().algo.elastic_timeout_ms, 30_000);
        assert!(!Experiment::new("mlp").config().algo.elastic);
    }

    #[test]
    fn threads_knob() {
        let exp = Experiment::new("mlp").threads(4);
        assert_eq!(exp.config().algo.threads, 4);
        // default: 0 = auto-detect
        assert_eq!(Experiment::new("mlp").config().algo.threads, 0);
    }

    #[test]
    fn compression_knob() {
        let exp = Experiment::new("mlp").allreduce()
            .compression(Codec::Fp16);
        assert_eq!(exp.config().algo.compression, Codec::Fp16);
        let exp = Experiment::new("mlp")
            .compression(Codec::TopK { k: 0.1 });
        assert_eq!(exp.config().algo.compression,
                   Codec::TopK { k: 0.1 });
        // default stays raw
        assert_eq!(Experiment::new("mlp").config().algo.compression,
                   Codec::Fp32);
    }

    #[test]
    fn from_job_config() {
        let job = crate::coordinator::config::JobConfig::from_json_text(
            r#"{"model": "mlp", "workers": 2,
                "callbacks": [{"kind": "early_stopping"}]}"#)
            .unwrap();
        let exp = Experiment::from(job);
        assert_eq!(exp.config().n_workers, 2);
        assert_eq!(exp.config().callbacks.len(), 1);
    }
}
