//! Hierarchical masters (paper §III-A: "several master processes, each
//! coordinating a group of workers and reporting to a higher-level
//! master").
//!
//! A *group master* runs the ordinary Downpour master loop over its
//! workers, but every `sync_every` local updates it reports upward: it
//! sends the (negated) weight delta accumulated since its last sync as an
//! `AggGradients` payload, and adopts the global weights the super-master
//! returns. With the super-master running identity SGD (lr = 1), the
//! global model integrates group deltas — momentum or a smaller lr at the
//! top level damps cross-group oscillation.
//!
//! Early stopping: when the super-master's callbacks request a stop it
//! answers the group master's next sync (or handshake) with `Tag::Exit`.
//! The group master then drains its own workers the same way — every
//! request is answered with Exit — forwards their final stats upward,
//! and exits, so the whole tree winds down through the ordinary Exit
//! protocol.
//!
//! Rank layout (see [`HierarchySpec`]): rank 0 is the super-master; group
//! `g` occupies a contiguous block starting at `1 + g * (workers_per_group
//! + 1)` with its master first.

use std::collections::BTreeSet;

use crate::coordinator::algo::{Algo, Mode};
use crate::metrics::{History, Stopwatch, WorkerReport};
use crate::mpi::codec::{grad_payload, Compressor};
use crate::mpi::{Comm, Envelope, Payload, Rank, Tag};
use crate::runtime::ModelExecutables;
use crate::tensor::ParamSet;

/// Static description of the two-level topology.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HierarchySpec {
    pub n_groups: usize,
    pub workers_per_group: usize,
    /// Group master syncs upward every this many local updates.
    pub sync_every: u64,
}

impl HierarchySpec {
    pub fn world_size(&self) -> usize {
        1 + self.n_groups * (self.workers_per_group + 1)
    }

    pub fn super_master(&self) -> Rank {
        0
    }

    pub fn group_master(&self, group: usize) -> Rank {
        1 + group * (self.workers_per_group + 1)
    }

    pub fn group_workers(&self, group: usize) -> Vec<Rank> {
        let gm = self.group_master(group);
        (gm + 1..=gm + self.workers_per_group).collect()
    }

    pub fn group_masters(&self) -> Vec<Rank> {
        (0..self.n_groups).map(|g| self.group_master(g)).collect()
    }

    /// Which role does `rank` play?
    pub fn role_of(&self, rank: Rank) -> Role {
        if rank == 0 {
            return Role::SuperMaster;
        }
        let idx = rank - 1;
        let block = self.workers_per_group + 1;
        let group = idx / block;
        if idx % block == 0 {
            Role::GroupMaster { group }
        } else {
            Role::Worker { group, master: self.group_master(group) }
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Role {
    SuperMaster,
    GroupMaster { group: usize },
    Worker { group: usize, master: Rank },
}

/// Group master: Downpour master below, Downpour "worker" above.
pub struct GroupMaster<'a> {
    comm: &'a Comm,
    algo: &'a Algo,
    spec: HierarchySpec,
    group: usize,
    exes: &'a ModelExecutables,
}

pub struct GroupOutcome {
    pub history: History,
    pub weights: ParamSet,
}

impl<'a> GroupMaster<'a> {
    pub fn new(comm: &'a Comm, algo: &'a Algo, spec: HierarchySpec,
               group: usize, exes: &'a ModelExecutables) -> Self {
        Self { comm, algo, spec, group, exes }
    }

    pub fn run(self) -> Result<GroupOutcome, crate::mpi::CommError> {
        assert!(matches!(self.algo.mode, Mode::Downpour { .. }),
                "hierarchical mode requires Downpour");
        let workers: BTreeSet<Rank> =
            self.spec.group_workers(self.group).into_iter().collect();
        let super_rank = self.spec.super_master();

        // Early-stop wind-down: once set, every worker request is
        // answered with Exit and no further updates apply.
        let mut stopping = false;

        // handshake upward: get the global weights. Our own workers may
        // race their Ready messages in first — stash anything that is not
        // the super-master's reply.
        let mut early: Vec<Envelope> = Vec::new();
        self.comm.send(super_rank, Tag::Ready, Payload::Empty)?;
        let mut weights = ParamSet::zeros(&self.exes.meta.params);
        let mut synced = loop {
            let env = self.comm.recv()?;
            if env.src == super_rank {
                match env.tag {
                    Tag::Weights => {
                        let data = env
                            .payload
                            .weights_like()
                            .unwrap_or_else(|| panic!(
                                "group master: bad handshake payload"))
                            .1;
                        weights.set_flat(&data);
                        break data;
                    }
                    Tag::Exit => {
                        // the run is already over (early stop before we
                        // ever trained): drain our workers and leave
                        stopping = true;
                        break std::sync::Arc::new(Vec::new());
                    }
                    tag => panic!(
                        "group master: bad handshake {tag:?}"),
                }
            }
            early.push(env);
        };

        let mut optimizer =
            self.algo.build_master_optimizer(weights.num_params());
        optimizer.set_pool(self.exes.thread_pool());
        // Upward-sync codec state (AggGradients is a gradient hop:
        // lossy codecs apply, with error feedback across syncs).
        let mut compressor = Compressor::new(self.algo.compression);
        compressor.set_pool(self.exes.thread_pool());
        let mut done: BTreeSet<Rank> = BTreeSet::new();
        let mut updates_since_sync = 0u64;
        let mut update_count = 0u64;
        let mut history = History::default();
        let mut update_timer = Stopwatch::new();
        let mut loss_accum = 0.0f32;
        let started = std::time::Instant::now();
        // Worker messages that arrive while we block on the super-master
        // are stashed here and replayed — dropping them would deadlock
        // the senders (they block awaiting weight replies).
        let mut stash: std::collections::VecDeque<Envelope> =
            early.into_iter().collect();

        while done.len() < workers.len() {
            let env = match stash.pop_front() {
                Some(env) => env,
                None => self.comm.recv()?,
            };
            if env.src == super_rank {
                // outside a sync we expect nothing from above except an
                // early-stop order
                if env.tag == Tag::Exit {
                    stopping = true;
                } else {
                    log::warn!("group master: unexpected {:?} from \
                                super-master", env.tag);
                }
                continue;
            }
            match (env.tag, env.payload) {
                (Tag::Ready, _) => {
                    if stopping {
                        self.comm.send(env.src, Tag::Exit,
                                       Payload::Empty)?;
                    } else {
                        self.comm.send(
                            env.src,
                            Tag::Weights,
                            self.algo.compression.weights_payload(
                                update_count, weights.flat()))?;
                    }
                }
                (Tag::Gradients, payload) => {
                    let Some((_, loss, data)) = payload.grad_like()
                    else {
                        log::warn!("group master: Gradients from {} \
                                    without a gradient payload",
                                   env.src);
                        continue;
                    };
                    if stopping {
                        self.comm.send(env.src, Tag::Exit,
                                       Payload::Empty)?;
                        continue;
                    }
                    update_timer.start();
                    optimizer.update(weights.flat_mut(), &data);
                    update_timer.stop();
                    update_count += 1;
                    updates_since_sync += 1;
                    loss_accum = loss;
                    if updates_since_sync >= self.spec.sync_every {
                        updates_since_sync = 0;
                        // report upward: negated delta as a "gradient"
                        let delta_neg: Vec<f32> = synced
                            .iter()
                            .zip(weights.flat())
                            .map(|(old, new)| old - new)
                            .collect();
                        self.comm.send(
                            super_rank,
                            Tag::AggGradients,
                            grad_payload(&mut compressor, update_count,
                                         loss_accum, delta_neg),
                        )?;
                        // block for the super-master's reply, stashing
                        // any concurrent worker traffic
                        loop {
                            let env = self.comm.recv()?;
                            if env.src == super_rank {
                                match env.tag {
                                    Tag::Weights => match env
                                        .payload
                                        .weights_like()
                                    {
                                        Some((_, data)) => {
                                            weights.set_flat(&data);
                                            synced = data;
                                        }
                                        None => log::warn!(
                                            "group master: sync reply \
                                             without weights"),
                                    },
                                    Tag::Exit => {
                                        // early stop ordered from above
                                        stopping = true;
                                    }
                                    tag => log::warn!(
                                        "group master: unexpected \
                                         {tag:?} during sync"),
                                }
                                break;
                            }
                            stash.push_back(env);
                        }
                    }
                    if stopping {
                        self.comm.send(env.src, Tag::Exit,
                                       Payload::Empty)?;
                    } else {
                        self.comm.send(
                            env.src,
                            Tag::Weights,
                            self.algo.compression.weights_payload(
                                update_count, weights.flat()))?;
                    }
                }
                (Tag::TrainStats, Payload::Stats(s)) => {
                    history.workers.push(WorkerReport {
                        rank: env.src,
                        epochs: s.epoch,
                        batches: s.batches_done,
                        samples: s.samples_done,
                        last_train_loss: s.train_loss,
                        grad_time_s: s.grad_time_s,
                        comm_wait_s: s.comm_wait_s,
                    });
                    // forward upward so the global History sees every
                    // worker's totals
                    self.comm.send(super_rank, Tag::TrainStats,
                                   Payload::Stats(s))?;
                }
                (Tag::Exit, _) => {
                    done.insert(env.src);
                }
                (tag, payload) => log::warn!(
                    "group master: unexpected {tag:?} ({payload:?})"),
            }
        }
        // final upstream sync + exit (skipped when the super-master
        // already ordered the stop — it only wants our Exit now)
        if !stopping {
            let delta_neg: Vec<f32> = synced
                .iter()
                .zip(weights.flat())
                .map(|(old, new)| old - new)
                .collect();
            self.comm.send(super_rank, Tag::AggGradients,
                           grad_payload(&mut compressor, update_count,
                                        loss_accum, delta_neg))?;
            // the reply may be Weights (normal) or Exit (the stop
            // raced our final sync) — only Weights changes state
            if let Ok(Envelope { tag: Tag::Weights, payload, .. }) =
                self.comm.recv()
            {
                if let Some((_, data)) = payload.weights_like() {
                    weights.set_flat(&data);
                }
            }
        }
        self.comm.send(super_rank, Tag::Exit, Payload::Empty)?;
        history.master_updates = update_count;
        history.master_update_time_s = update_timer.total_s();
        history.wallclock_s = started.elapsed().as_secs_f64();
        Ok(GroupOutcome { history, weights })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_rank_layout() {
        let spec = HierarchySpec { n_groups: 2, workers_per_group: 3,
                                   sync_every: 5 };
        assert_eq!(spec.world_size(), 9);
        assert_eq!(spec.group_master(0), 1);
        assert_eq!(spec.group_master(1), 5);
        assert_eq!(spec.group_workers(0), vec![2, 3, 4]);
        assert_eq!(spec.group_workers(1), vec![6, 7, 8]);
        assert_eq!(spec.group_masters(), vec![1, 5]);
    }

    #[test]
    fn roles_cover_world() {
        let spec = HierarchySpec { n_groups: 3, workers_per_group: 2,
                                   sync_every: 1 };
        assert_eq!(spec.role_of(0), Role::SuperMaster);
        let mut masters = 0;
        let mut workers = 0;
        for r in 1..spec.world_size() {
            match spec.role_of(r) {
                Role::GroupMaster { .. } => masters += 1,
                Role::Worker { master, .. } => {
                    workers += 1;
                    assert!(matches!(spec.role_of(master),
                                     Role::GroupMaster { .. }));
                }
                Role::SuperMaster => panic!("only rank 0"),
            }
        }
        assert_eq!(masters, 3);
        assert_eq!(workers, 6);
    }
}
