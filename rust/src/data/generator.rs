//! Synthetic HEP-like dataset generator (the Delphes-simulation substitute).
//!
//! The paper's benchmark classifies simulated LHC collision events into
//! three categories from sequences of reconstructed-object features. We
//! generate a structurally similar task: each sample is a length-T sequence
//! of F "particle-flow" features whose *dynamics* depend on the class —
//! class-specific oscillation frequency/amplitude (resonance-mass
//! analogue), AR(1) persistence (jet-shape analogue), and heavy-tailed
//! energy-like marginals. A `separation` knob scales class distinguish-
//! ability so accuracy experiments (Fig 2) live in a non-saturated regime,
//! mirroring a classifier that tops out well below 100%.

use std::path::{Path, PathBuf};

use crate::data::format::Shard;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct GeneratorConfig {
    pub seq_len: usize,
    pub features: usize,
    pub classes: usize,
    /// Class separability in [0, ~2]; ~0.6 gives a task where the paper
    /// LSTM plateaus around 85-95% — stale-gradient effects visible.
    pub separation: f32,
    pub noise: f32,
    pub seed: u64,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        Self {
            seq_len: 30,
            features: 16,
            classes: 3,
            separation: 0.6,
            noise: 1.0,
            seed: 2017, // the paper's year
        }
    }
}

/// Class-conditional sequence parameters, derived deterministically.
struct ClassDynamics {
    freq: f32,
    amp: f32,
    phase: f32,
    ar: f32,
    drift: f32,
}

fn class_dynamics(cfg: &GeneratorConfig, class: usize, feat: usize)
    -> ClassDynamics {
    // Smooth per-(class, feature) parameter field; classes differ by
    // `separation`-scaled offsets.
    let c = class as f32;
    let f = feat as f32;
    let s = cfg.separation;
    ClassDynamics {
        freq: 1.0 + 0.5 * ((f * 0.7).sin() + s * c),
        amp: 0.8 + s * 0.5 * ((c + 1.0) * (f * 0.3 + 0.5).cos()),
        phase: 0.9 * c * s + 0.2 * f,
        ar: (0.55 + 0.12 * s * c + 0.02 * (f * 1.3).sin()).min(0.95),
        drift: 0.03 * s * (c - 1.0),
    }
}

/// Generate one sample into `out` ([seq_len * features], row-major [t, f]).
pub fn generate_sample(cfg: &GeneratorConfig, class: usize, rng: &mut Rng,
                       out: &mut [f32]) {
    assert_eq!(out.len(), cfg.seq_len * cfg.features);
    let t_total = cfg.seq_len as f32;
    for feat in 0..cfg.features {
        let dyn_ = class_dynamics(cfg, class, feat);
        let mut prev = rng.normal_f32(0.0, 0.5);
        for t in 0..cfg.seq_len {
            let tf = t as f32 / t_total;
            let osc = dyn_.amp
                * (2.0 * std::f32::consts::PI * dyn_.freq * tf + dyn_.phase)
                    .sin();
            // heavy-ish tail: occasional energy spike (jet analogue)
            let spike = if rng.uniform() < 0.02 {
                rng.normal_f32(0.0, 2.0).abs()
            } else {
                0.0
            };
            let eps = rng.normal_f32(0.0, cfg.noise * 0.3);
            let val = dyn_.ar * prev + osc + dyn_.drift * t as f32 + spike
                + eps;
            out[t * cfg.features + feat] = val;
            prev = val;
        }
    }
}

/// Generate a shard of `n` samples with balanced random classes.
pub fn generate_shard(cfg: &GeneratorConfig, n: usize, rng: &mut Rng)
    -> Shard {
    let mut labels = Vec::with_capacity(n);
    let mut x = vec![0.0f32; n * cfg.seq_len * cfg.features];
    let sl = cfg.seq_len * cfg.features;
    for i in 0..n {
        let class = rng.usize_below(cfg.classes);
        labels.push(class as i32);
        generate_sample(cfg, class, rng, &mut x[i * sl..(i + 1) * sl]);
    }
    Shard {
        seq_len: cfg.seq_len as u32,
        features: cfg.features as u32,
        classes: cfg.classes as u32,
        labels,
        x,
    }
}

/// Write a full dataset: `n_files` shards of `samples_per_file` each
/// (paper: 100 files x 9500 samples), plus one held-out validation shard.
/// Returns (train file paths, validation file path).
pub fn generate_dataset(cfg: &GeneratorConfig, dir: &Path, n_files: usize,
                        samples_per_file: usize, val_samples: usize)
    -> Result<(Vec<PathBuf>, PathBuf), crate::data::format::ShardError> {
    let mut rng = Rng::new(cfg.seed);
    let mut paths = Vec::with_capacity(n_files);
    for i in 0..n_files {
        let mut shard_rng = rng.fork(i as u64);
        let shard = generate_shard(cfg, samples_per_file, &mut shard_rng);
        let path = dir.join(format!("train_{i:04}.mpil"));
        shard.write(&path)?;
        paths.push(path);
    }
    // validation stream id far outside the train-shard fork range
    let mut val_rng = rng.fork(0xA11_DA7A);
    let val = generate_shard(cfg, val_samples, &mut val_rng);
    let val_path = dir.join("val.mpil");
    val.write(&val_path)?;
    Ok((paths, val_path))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_shapes_and_finite() {
        let cfg = GeneratorConfig::default();
        let mut rng = Rng::new(1);
        let mut out = vec![0.0; cfg.seq_len * cfg.features];
        generate_sample(&cfg, 0, &mut rng, &mut out);
        assert!(out.iter().all(|v| v.is_finite()));
        assert!(out.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn classes_are_statistically_distinct() {
        // Mean per-feature trajectory should differ across classes when
        // separation > 0 — otherwise Fig 2 would be untrainable.
        let cfg = GeneratorConfig { noise: 0.2, ..Default::default() };
        let mut rng = Rng::new(2);
        let sl = cfg.seq_len * cfg.features;
        let mut means = vec![vec![0.0f64; sl]; cfg.classes];
        let reps = 200;
        for class in 0..cfg.classes {
            let mut buf = vec![0.0; sl];
            for _ in 0..reps {
                generate_sample(&cfg, class, &mut rng, &mut buf);
                for (m, v) in means[class].iter_mut().zip(&buf) {
                    *m += *v as f64 / reps as f64;
                }
            }
        }
        let dist01: f64 = means[0].iter().zip(&means[1])
            .map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        let dist02: f64 = means[0].iter().zip(&means[2])
            .map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        assert!(dist01 > 1.0, "class 0/1 too close: {dist01}");
        assert!(dist02 > 1.0, "class 0/2 too close: {dist02}");
    }

    #[test]
    fn zero_separation_collapses_classes() {
        let cfg = GeneratorConfig { separation: 0.0, noise: 0.0,
                                    seed: 3, ..Default::default() };
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        let sl = cfg.seq_len * cfg.features;
        let mut a = vec![0.0; sl];
        let mut b = vec![0.0; sl];
        generate_sample(&cfg, 0, &mut r1, &mut a);
        generate_sample(&cfg, 1, &mut r2, &mut b);
        // identical rng + zero separation -> identical sequences
        assert_eq!(a, b);
    }

    #[test]
    fn shard_generation_balanced() {
        let cfg = GeneratorConfig::default();
        let mut rng = Rng::new(5);
        let shard = generate_shard(&cfg, 3000, &mut rng);
        let mut counts = [0usize; 3];
        for &l in &shard.labels {
            counts[l as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 1000.0).abs() < 150.0, "{counts:?}");
        }
    }

    #[test]
    fn dataset_files_deterministic() {
        let cfg = GeneratorConfig { seed: 9, ..Default::default() };
        let d1 = std::env::temp_dir().join("mpi_learn_gen_a");
        let d2 = std::env::temp_dir().join("mpi_learn_gen_b");
        let (p1, v1) = generate_dataset(&cfg, &d1, 2, 50, 20).unwrap();
        let (p2, v2) = generate_dataset(&cfg, &d2, 2, 50, 20).unwrap();
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(Shard::read(a).unwrap(), Shard::read(b).unwrap());
        }
        assert_eq!(Shard::read(&v1).unwrap(), Shard::read(&v2).unwrap());
    }
}
