//! Binary shard file format (the 100-file Delphes dataset substitute).
//!
//! Layout (little-endian):
//! ```text
//! magic   "MPIL"            4 bytes
//! version u32               = 1
//! n       u32  samples
//! t       u32  seq_len
//! f       u32  features
//! c       u32  classes
//! labels  i32[n]
//! x       f32[n * t * f]    (sample-major, row-major [t, f] per sample)
//! crc     u32               CRC-32 of everything after the magic
//! ```
//! CRC guards against torn writes — a worker failing mid-epoch because its
//! shard was corrupt is a failure mode the paper's file-division scheme
//! has to survive.

use std::io::{Read, Write};
use std::path::Path;

use crc32fast::Hasher;

#[derive(Debug)]
pub enum ShardError {
    Io(std::io::Error),
    BadMagic,
    BadVersion(u32),
    BadChecksum,
    Truncated,
    BadLabel { label: i32, classes: u32 },
}

impl std::fmt::Display for ShardError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardError::Io(e) => write!(f, "io: {e}"),
            ShardError::BadMagic => {
                write!(f, "not a shard file (bad magic)")
            }
            ShardError::BadVersion(v) => {
                write!(f, "unsupported shard version {v}")
            }
            ShardError::BadChecksum => {
                write!(f, "checksum mismatch: file is corrupt")
            }
            ShardError::Truncated => write!(f, "shard truncated"),
            ShardError::BadLabel { label, classes } => {
                write!(f, "label {label} out of range for {classes} classes")
            }
        }
    }
}

impl std::error::Error for ShardError {}

impl From<std::io::Error> for ShardError {
    fn from(e: std::io::Error) -> Self {
        ShardError::Io(e)
    }
}

/// One file's worth of samples, fully in memory (shards are sized so that
/// a worker's whole division fits comfortably, as in the paper's setup).
#[derive(Clone, Debug, PartialEq)]
pub struct Shard {
    pub seq_len: u32,
    pub features: u32,
    pub classes: u32,
    pub labels: Vec<i32>,
    /// [n * seq_len * features], sample-major.
    pub x: Vec<f32>,
}

impl Shard {
    pub fn n_samples(&self) -> usize {
        self.labels.len()
    }

    pub fn sample_len(&self) -> usize {
        (self.seq_len * self.features) as usize
    }

    /// Slice of sample i's flattened [t, f] features.
    pub fn sample(&self, i: usize) -> &[f32] {
        let sl = self.sample_len();
        &self.x[i * sl..(i + 1) * sl]
    }

    pub fn write(&self, path: &Path) -> Result<(), ShardError> {
        let mut body = Vec::with_capacity(
            20 + self.labels.len() * 4 + self.x.len() * 4);
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&(self.labels.len() as u32).to_le_bytes());
        body.extend_from_slice(&self.seq_len.to_le_bytes());
        body.extend_from_slice(&self.features.to_le_bytes());
        body.extend_from_slice(&self.classes.to_le_bytes());
        for l in &self.labels {
            body.extend_from_slice(&l.to_le_bytes());
        }
        let xbytes: &[u8] = unsafe {
            std::slice::from_raw_parts(self.x.as_ptr() as *const u8,
                                       self.x.len() * 4)
        };
        body.extend_from_slice(xbytes);
        let mut h = Hasher::new();
        h.update(&body);
        let crc = h.finalize();

        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(b"MPIL")?;
        f.write_all(&body)?;
        f.write_all(&crc.to_le_bytes())?;
        Ok(())
    }

    pub fn read(path: &Path) -> Result<Shard, ShardError> {
        let mut buf = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut buf)?;
        if buf.len() < 8 || &buf[..4] != b"MPIL" {
            return Err(ShardError::BadMagic);
        }
        let body = &buf[4..buf.len() - 4];
        let crc_stored = u32::from_le_bytes(
            buf[buf.len() - 4..].try_into().unwrap());
        let mut h = Hasher::new();
        h.update(body);
        if h.finalize() != crc_stored {
            return Err(ShardError::BadChecksum);
        }
        if body.len() < 20 {
            return Err(ShardError::Truncated);
        }
        let rd = |off: usize| u32::from_le_bytes(
            body[off..off + 4].try_into().unwrap());
        let version = rd(0);
        if version != 1 {
            return Err(ShardError::BadVersion(version));
        }
        let n = rd(4) as usize;
        let seq_len = rd(8);
        let features = rd(12);
        let classes = rd(16);
        let labels_bytes = n * 4;
        let x_len = n * (seq_len as usize) * (features as usize);
        if body.len() != 20 + labels_bytes + x_len * 4 {
            return Err(ShardError::Truncated);
        }
        let mut labels = Vec::with_capacity(n);
        for chunk in body[20..20 + labels_bytes].chunks_exact(4) {
            let l = i32::from_le_bytes(chunk.try_into().unwrap());
            if l < 0 || l as u32 >= classes {
                return Err(ShardError::BadLabel { label: l, classes });
            }
            labels.push(l);
        }
        let mut x = Vec::with_capacity(x_len);
        for chunk in body[20 + labels_bytes..].chunks_exact(4) {
            x.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        Ok(Shard { seq_len, features, classes, labels, x })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_shard() -> Shard {
        Shard {
            seq_len: 3,
            features: 2,
            classes: 3,
            labels: vec![0, 1, 2, 1],
            x: (0..24).map(|i| i as f32).collect(),
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("mpi_learn_shard_{name}.bin"))
    }

    #[test]
    fn roundtrip() {
        let s = sample_shard();
        let p = tmp("rt");
        s.write(&p).unwrap();
        assert_eq!(Shard::read(&p).unwrap(), s);
    }

    #[test]
    fn sample_slicing() {
        let s = sample_shard();
        assert_eq!(s.sample(1), &[6.0, 7.0, 8.0, 9.0, 10.0, 11.0]);
        assert_eq!(s.n_samples(), 4);
    }

    #[test]
    fn corruption_detected() {
        let s = sample_shard();
        let p = tmp("corrupt");
        s.write(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(matches!(Shard::read(&p), Err(ShardError::BadChecksum)));
    }

    #[test]
    fn truncation_detected() {
        let s = sample_shard();
        let p = tmp("trunc");
        s.write(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 9]).unwrap();
        assert!(Shard::read(&p).is_err());
    }

    #[test]
    fn bad_magic_detected() {
        let p = tmp("magic");
        std::fs::write(&p, b"NOPEnope").unwrap();
        assert!(matches!(Shard::read(&p), Err(ShardError::BadMagic)));
    }

    #[test]
    fn label_range_validated() {
        let mut s = sample_shard();
        s.labels[0] = 7; // out of range for 3 classes
        let p = tmp("label");
        s.write(&p).unwrap();
        assert!(matches!(Shard::read(&p),
                         Err(ShardError::BadLabel { .. })));
    }
}
