//! Batching data loader over shard files — the paper's `Data` class.
//!
//! A worker's division of the file list is loaded into memory (shards are
//! small relative to the original 50 GB / 100 files because the benchmark
//! scales down proportionally) and iterated as shuffled fixed-size batches,
//! one epoch at a time. Partial trailing batches are dropped, matching the
//! fixed-shape HLO artifacts (and Keras `steps_per_epoch` semantics).

use std::path::{Path, PathBuf};

use crate::data::format::{Shard, ShardError};
use crate::util::rng::Rng;

/// In-memory dataset with batch iteration.
#[derive(Clone, Debug)]
pub struct DataSet {
    pub seq_len: usize,
    pub features: usize,
    pub classes: usize,
    labels: Vec<i32>,
    x: Vec<f32>, // sample-major
}

impl DataSet {
    pub fn from_files(paths: &[PathBuf]) -> Result<DataSet, ShardError> {
        assert!(!paths.is_empty(), "DataSet needs at least one file");
        let mut out: Option<DataSet> = None;
        for p in paths {
            let shard = Shard::read(p)?;
            match &mut out {
                None => {
                    out = Some(DataSet {
                        seq_len: shard.seq_len as usize,
                        features: shard.features as usize,
                        classes: shard.classes as usize,
                        labels: shard.labels,
                        x: shard.x,
                    })
                }
                Some(ds) => {
                    assert_eq!(ds.seq_len, shard.seq_len as usize,
                               "mixed seq_len across shards");
                    assert_eq!(ds.features, shard.features as usize,
                               "mixed features across shards");
                    ds.labels.extend_from_slice(&shard.labels);
                    ds.x.extend_from_slice(&shard.x);
                }
            }
        }
        Ok(out.unwrap())
    }

    pub fn from_shard(shard: Shard) -> DataSet {
        DataSet {
            seq_len: shard.seq_len as usize,
            features: shard.features as usize,
            classes: shard.classes as usize,
            labels: shard.labels,
            x: shard.x,
        }
    }

    pub fn n_samples(&self) -> usize {
        self.labels.len()
    }

    pub fn labels(&self) -> &[i32] {
        &self.labels
    }

    pub fn classes(&self) -> usize {
        self.classes
    }

    fn sample_len(&self) -> usize {
        self.seq_len * self.features
    }

    /// Copy sample `i` into `(x_out, label)` buffers.
    fn fill(&self, i: usize, x_out: &mut [f32]) -> i32 {
        let sl = self.sample_len();
        x_out.copy_from_slice(&self.x[i * sl..(i + 1) * sl]);
        self.labels[i]
    }

    /// Number of full batches per epoch.
    pub fn batches_per_epoch(&self, batch: usize) -> usize {
        self.n_samples() / batch
    }

    /// Iterate one epoch of shuffled full batches, invoking `f(x, y)`.
    /// Buffers are reused across calls — the hot path allocates nothing.
    pub fn for_each_batch<F>(&self, batch: usize, rng: &mut Rng, mut f: F)
    where
        F: FnMut(&[f32], &[i32]),
    {
        let n = self.n_samples();
        let mut order: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut order);
        let sl = self.sample_len();
        let mut xb = vec![0.0f32; batch * sl];
        let mut yb = vec![0i32; batch];
        for chunk in order.chunks_exact(batch) {
            for (j, &idx) in chunk.iter().enumerate() {
                yb[j] = self.fill(idx as usize,
                                  &mut xb[j * sl..(j + 1) * sl]);
            }
            f(&xb, &yb);
        }
    }

    /// Fixed (unshuffled) batches — used for validation.
    pub fn for_each_batch_ordered<F>(&self, batch: usize, mut f: F)
    where
        F: FnMut(&[f32], &[i32]),
    {
        let sl = self.sample_len();
        let mut xb = vec![0.0f32; batch * sl];
        let mut yb = vec![0i32; batch];
        let nb = self.batches_per_epoch(batch);
        for b in 0..nb {
            for j in 0..batch {
                let idx = b * batch + j;
                yb[j] = self.fill(idx, &mut xb[j * sl..(j + 1) * sl]);
            }
            f(&xb, &yb);
        }
    }
}

/// Divide the file list evenly among `n_workers` (paper §III-B: "input
/// file paths ... divided evenly among all worker processes"). Worker `w`
/// (0-based) gets every file `i` with `i % n_workers == w` — round-robin,
/// so uneven counts differ by at most one file.
pub fn divide_files(paths: &[PathBuf], worker: usize, n_workers: usize)
    -> Vec<PathBuf> {
    assert!(worker < n_workers);
    paths
        .iter()
        .enumerate()
        .filter(|(i, _)| i % n_workers == worker)
        .map(|(_, p)| p.clone())
        .collect()
}

/// Check a proposed division covers all files exactly once.
pub fn division_is_partition(paths: &[PathBuf], n_workers: usize) -> bool {
    let mut seen = std::collections::BTreeSet::new();
    for w in 0..n_workers {
        for p in divide_files(paths, w, n_workers) {
            if !seen.insert(p) {
                return false;
            }
        }
    }
    seen.len() == paths.len()
}

/// Helper shared by tests/benches: list shard files in a directory.
pub fn list_train_files(dir: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("train_") && n.ends_with(".mpil"))
                .unwrap_or(false)
        })
        .collect();
    files.sort();
    Ok(files)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{generate_shard, GeneratorConfig};

    fn small_ds(n: usize, seed: u64) -> DataSet {
        let cfg = GeneratorConfig { seq_len: 4, features: 3,
                                    ..Default::default() };
        let mut rng = Rng::new(seed);
        DataSet::from_shard(generate_shard(&cfg, n, &mut rng))
    }

    #[test]
    fn batches_cover_epoch_once() {
        let ds = small_ds(100, 1);
        let mut rng = Rng::new(2);
        let mut seen = 0usize;
        ds.for_each_batch(10, &mut rng, |x, y| {
            assert_eq!(x.len(), 10 * 12);
            assert_eq!(y.len(), 10);
            seen += 10;
        });
        assert_eq!(seen, 100);
    }

    #[test]
    fn partial_batch_dropped() {
        let ds = small_ds(105, 1);
        assert_eq!(ds.batches_per_epoch(10), 10);
        let mut rng = Rng::new(2);
        let mut batches = 0;
        ds.for_each_batch(10, &mut rng, |_, _| batches += 1);
        assert_eq!(batches, 10);
    }

    #[test]
    fn shuffling_changes_order_not_content() {
        let ds = small_ds(60, 3);
        let collect = |seed: u64| {
            let mut rng = Rng::new(seed);
            let mut ys = Vec::new();
            ds.for_each_batch(60, &mut rng, |_, y| ys.extend_from_slice(y));
            ys
        };
        let a = collect(1);
        let b = collect(2);
        assert_ne!(a, b, "different seeds should shuffle differently");
        let mut a2 = a.clone();
        let mut b2 = b.clone();
        a2.sort_unstable();
        b2.sort_unstable();
        assert_eq!(a2, b2, "same multiset of labels");
    }

    #[test]
    fn ordered_batches_are_stable() {
        let ds = small_ds(30, 4);
        let mut first = Vec::new();
        ds.for_each_batch_ordered(10, |_, y| first.extend_from_slice(y));
        let mut second = Vec::new();
        ds.for_each_batch_ordered(10, |_, y| second.extend_from_slice(y));
        assert_eq!(first, second);
        assert_eq!(first, ds.labels[..30].to_vec());
    }

    #[test]
    fn division_even_and_complete() {
        let paths: Vec<PathBuf> =
            (0..10).map(|i| PathBuf::from(format!("f{i}"))).collect();
        for n in 1..=10 {
            assert!(division_is_partition(&paths, n), "n={n}");
            let sizes: Vec<usize> = (0..n)
                .map(|w| divide_files(&paths, w, n).len())
                .collect();
            let min = sizes.iter().min().unwrap();
            let max = sizes.iter().max().unwrap();
            assert!(max - min <= 1, "uneven division for n={n}: {sizes:?}");
        }
    }

    #[test]
    fn concat_multiple_files() {
        let cfg = GeneratorConfig { seq_len: 4, features: 3,
                                    ..Default::default() };
        let dir = std::env::temp_dir().join("mpi_learn_loader_test");
        let mut rng = Rng::new(9);
        let mut paths = Vec::new();
        for i in 0..3 {
            let shard = generate_shard(&cfg, 20, &mut rng);
            let p = dir.join(format!("train_{i:04}.mpil"));
            shard.write(&p).unwrap();
            paths.push(p);
        }
        let ds = DataSet::from_files(&paths).unwrap();
        assert_eq!(ds.n_samples(), 60);
        let listed = list_train_files(&dir).unwrap();
        assert_eq!(listed, paths);
    }
}
