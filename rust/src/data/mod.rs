//! Data substrate: shard file format, synthetic HEP-like generator, and
//! the batching loader with the paper's even file-division scheme.

pub mod format;
pub mod generator;
pub mod loader;

pub use format::{Shard, ShardError};
pub use generator::{generate_dataset, generate_shard, GeneratorConfig};
pub use loader::{divide_files, list_train_files, DataSet};
