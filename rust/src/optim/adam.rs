//! Adam (Kingma & Ba) with bias correction.

use std::sync::Arc;

use super::Optimizer;
use crate::runtime::kernels::par_blocks;
use crate::util::threadpool::{SharedMut, ThreadPool};

pub struct Adam {
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    scale: f32,
    t: u64,
    m: Vec<f32>,
    v: Vec<f32>,
    pool: Option<Arc<ThreadPool>>,
}

impl Adam {
    pub fn new(lr: f32, beta1: f32, beta2: f32, eps: f32, n: usize) -> Self {
        Self {
            lr,
            beta1,
            beta2,
            eps,
            scale: 1.0,
            t: 0,
            m: vec![0.0; n],
            v: vec![0.0; n],
            pool: None,
        }
    }
}

impl Optimizer for Adam {
    fn update(&mut self, weights: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(weights.len(), grads.len());
        self.t += 1;
        let b1 = self.beta1;
        let b2 = self.beta2;
        let eps = self.eps;
        // t, bias correction and the effective lr are scalars fixed
        // before the loop, so partitioning the element range cannot
        // change any per-element arithmetic.
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let lr = self.lr * self.scale * bc2.sqrt() / bc1;
        let step = |w: &mut [f32], g: &[f32], m: &mut [f32],
                    v: &mut [f32]| {
            for i in 0..w.len() {
                let gi = g[i];
                m[i] = b1 * m[i] + (1.0 - b1) * gi;
                v[i] = b2 * v[i] + (1.0 - b2) * gi * gi;
                w[i] -= lr * m[i] / (v[i].sqrt() + eps);
            }
        };
        match &self.pool {
            Some(pool) => {
                let wv = SharedMut::new(weights);
                let mv = SharedMut::new(&mut self.m);
                let vv = SharedMut::new(&mut self.v);
                par_blocks(pool, grads.len(), |r| {
                    step(unsafe { wv.range(r.clone()) }, &grads[r.clone()],
                         unsafe { mv.range(r.clone()) },
                         unsafe { vv.range(r) });
                });
            }
            None => step(weights, grads, &mut self.m, &mut self.v),
        }
    }

    fn name(&self) -> &'static str {
        "adam"
    }

    fn set_lr_scale(&mut self, scale: f32) {
        self.scale = scale;
    }

    fn set_pool(&mut self, pool: Arc<ThreadPool>) {
        self.pool = Some(pool);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_lr_sized() {
        // With bias correction, step 1 ≈ lr * sign(g).
        let mut opt = Adam::new(0.1, 0.9, 0.999, 1e-8, 2);
        let mut w = vec![0.0f32, 0.0];
        opt.update(&mut w, &[3.0, -7.0]);
        assert!((w[0] + 0.1).abs() < 1e-3, "{w:?}");
        assert!((w[1] - 0.1).abs() < 1e-3, "{w:?}");
    }

    #[test]
    fn adapts_per_coordinate() {
        let mut opt = Adam::new(0.01, 0.9, 0.999, 1e-8, 2);
        let mut w = vec![0.0f32, 0.0];
        // coordinate 0 sees huge gradients, coordinate 1 tiny ones;
        // Adam normalizes so displacement magnitudes stay comparable.
        for _ in 0..50 {
            opt.update(&mut w, &[100.0, 0.01]);
        }
        assert!(w[0] < 0.0 && w[1] < 0.0);
        let ratio = w[0] / w[1];
        assert!(ratio < 2.0, "ratio={ratio}, w={w:?}");
    }

    #[test]
    fn pooled_updates_are_bitwise_identical() {
        let n = 9_473usize; // not a multiple of any block size
        let grads: Vec<f32> =
            (0..n).map(|i| ((i % 113) as f32 - 56.0) * 0.017).collect();
        let init: Vec<f32> =
            (0..n).map(|i| ((i % 97) as f32) * 0.021 - 1.0).collect();
        let pool = Arc::new(ThreadPool::new(4));
        let mut serial = Adam::new(0.01, 0.9, 0.999, 1e-8, n);
        let mut pooled = Adam::new(0.01, 0.9, 0.999, 1e-8, n);
        pooled.set_pool(pool);
        let mut ws = init.clone();
        let mut wp = init;
        for _ in 0..3 {
            serial.update(&mut ws, &grads);
            pooled.update(&mut wp, &grads);
        }
        assert!(ws.iter().zip(&wp)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }
}
