//! Adam (Kingma & Ba) with bias correction.

use super::Optimizer;

pub struct Adam {
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    scale: f32,
    t: u64,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl Adam {
    pub fn new(lr: f32, beta1: f32, beta2: f32, eps: f32, n: usize) -> Self {
        Self {
            lr,
            beta1,
            beta2,
            eps,
            scale: 1.0,
            t: 0,
            m: vec![0.0; n],
            v: vec![0.0; n],
        }
    }
}

impl Optimizer for Adam {
    fn update(&mut self, weights: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(weights.len(), grads.len());
        self.t += 1;
        let b1 = self.beta1;
        let b2 = self.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let lr = self.lr * self.scale * bc2.sqrt() / bc1;
        for i in 0..weights.len() {
            let g = grads[i];
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            weights[i] -= lr * self.m[i] / (self.v[i].sqrt() + self.eps);
        }
    }

    fn name(&self) -> &'static str {
        "adam"
    }

    fn set_lr_scale(&mut self, scale: f32) {
        self.scale = scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_lr_sized() {
        // With bias correction, step 1 ≈ lr * sign(g).
        let mut opt = Adam::new(0.1, 0.9, 0.999, 1e-8, 2);
        let mut w = vec![0.0f32, 0.0];
        opt.update(&mut w, &[3.0, -7.0]);
        assert!((w[0] + 0.1).abs() < 1e-3, "{w:?}");
        assert!((w[1] - 0.1).abs() < 1e-3, "{w:?}");
    }

    #[test]
    fn adapts_per_coordinate() {
        let mut opt = Adam::new(0.01, 0.9, 0.999, 1e-8, 2);
        let mut w = vec![0.0f32, 0.0];
        // coordinate 0 sees huge gradients, coordinate 1 tiny ones;
        // Adam normalizes so displacement magnitudes stay comparable.
        for _ in 0..50 {
            opt.update(&mut w, &[100.0, 0.01]);
        }
        assert!(w[0] < 0.0 && w[1] < 0.0);
        let ratio = w[0] / w[1];
        assert!(ratio < 2.0, "ratio={ratio}, w={w:?}");
    }
}
