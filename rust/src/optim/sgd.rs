//! Plain SGD and (Nesterov) momentum SGD.

use super::Optimizer;

/// w -= lr * g
pub struct Sgd {
    lr: f32,
    scale: f32,
}

impl Sgd {
    pub fn new(lr: f32) -> Self {
        Self { lr, scale: 1.0 }
    }
}

impl Optimizer for Sgd {
    fn update(&mut self, weights: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(weights.len(), grads.len());
        let lr = self.lr * self.scale;
        for (w, g) in weights.iter_mut().zip(grads) {
            *w -= lr * g;
        }
    }

    fn name(&self) -> &'static str {
        "sgd"
    }

    fn set_lr_scale(&mut self, scale: f32) {
        self.scale = scale;
    }
}

/// Momentum SGD: v = mu*v - lr*g; w += v  (Nesterov optional).
///
/// The paper's recommended mitigation for Downpour's stale-gradient
/// degradation (ref [9], Omnivore) — benchmark default.
pub struct Momentum {
    lr: f32,
    mu: f32,
    nesterov: bool,
    scale: f32,
    velocity: Vec<f32>,
}

impl Momentum {
    pub fn new(lr: f32, mu: f32, nesterov: bool, n: usize) -> Self {
        Self { lr, mu, nesterov, scale: 1.0, velocity: vec![0.0; n] }
    }
}

impl Optimizer for Momentum {
    fn update(&mut self, weights: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(weights.len(), grads.len());
        debug_assert_eq!(weights.len(), self.velocity.len());
        let lr = self.lr * self.scale;
        let mu = self.mu;
        if self.nesterov {
            for ((w, g), v) in weights.iter_mut().zip(grads)
                .zip(self.velocity.iter_mut()) {
                *v = mu * *v - lr * g;
                *w += mu * *v - lr * g;
            }
        } else {
            for ((w, g), v) in weights.iter_mut().zip(grads)
                .zip(self.velocity.iter_mut()) {
                *v = mu * *v - lr * g;
                *w += *v;
            }
        }
    }

    fn name(&self) -> &'static str {
        "momentum"
    }

    fn set_lr_scale(&mut self, scale: f32) {
        self.scale = scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_single_step_exact() {
        let mut opt = Sgd::new(0.1);
        let mut w = vec![1.0f32, 2.0];
        opt.update(&mut w, &[10.0, -10.0]);
        assert_eq!(w, vec![0.0, 3.0]);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut opt = Momentum::new(0.1, 0.9, false, 1);
        let mut w = vec![0.0f32];
        opt.update(&mut w, &[1.0]); // v=-0.1, w=-0.1
        opt.update(&mut w, &[1.0]); // v=-0.19, w=-0.29
        assert!((w[0] + 0.29).abs() < 1e-6, "{w:?}");
    }

    #[test]
    fn lr_scale_applies() {
        let mut opt = Sgd::new(0.1);
        opt.set_lr_scale(0.5);
        let mut w = vec![0.0f32];
        opt.update(&mut w, &[1.0]);
        assert!((w[0] + 0.05).abs() < 1e-7);
    }

    #[test]
    fn nesterov_differs_from_plain() {
        let mut plain = Momentum::new(0.1, 0.9, false, 1);
        let mut nest = Momentum::new(0.1, 0.9, true, 1);
        let mut w1 = vec![0.0f32];
        let mut w2 = vec![0.0f32];
        for _ in 0..3 {
            plain.update(&mut w1, &[1.0]);
            nest.update(&mut w2, &[1.0]);
        }
        assert_ne!(w1, w2);
    }
}
