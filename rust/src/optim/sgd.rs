//! Plain SGD and (Nesterov) momentum SGD.

use std::sync::Arc;

use super::Optimizer;
use crate::runtime::kernels::par_blocks;
use crate::util::threadpool::{SharedMut, ThreadPool};

/// w -= lr * g
pub struct Sgd {
    lr: f32,
    scale: f32,
    pool: Option<Arc<ThreadPool>>,
}

impl Sgd {
    pub fn new(lr: f32) -> Self {
        Self { lr, scale: 1.0, pool: None }
    }
}

impl Optimizer for Sgd {
    fn update(&mut self, weights: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(weights.len(), grads.len());
        let lr = self.lr * self.scale;
        let step = |w: &mut [f32], g: &[f32]| {
            for (wi, gi) in w.iter_mut().zip(g) {
                *wi -= lr * gi;
            }
        };
        match &self.pool {
            Some(pool) => {
                let wv = SharedMut::new(weights);
                par_blocks(pool, grads.len(), |r| {
                    step(unsafe { wv.range(r.clone()) }, &grads[r]);
                });
            }
            None => step(weights, grads),
        }
    }

    fn name(&self) -> &'static str {
        "sgd"
    }

    fn set_lr_scale(&mut self, scale: f32) {
        self.scale = scale;
    }

    fn set_pool(&mut self, pool: Arc<ThreadPool>) {
        self.pool = Some(pool);
    }
}

/// Momentum SGD: v = mu*v - lr*g; w += v  (Nesterov optional).
///
/// The paper's recommended mitigation for Downpour's stale-gradient
/// degradation (ref [9], Omnivore) — benchmark default.
pub struct Momentum {
    lr: f32,
    mu: f32,
    nesterov: bool,
    scale: f32,
    velocity: Vec<f32>,
    pool: Option<Arc<ThreadPool>>,
}

impl Momentum {
    pub fn new(lr: f32, mu: f32, nesterov: bool, n: usize) -> Self {
        Self { lr, mu, nesterov, scale: 1.0, velocity: vec![0.0; n],
               pool: None }
    }
}

impl Optimizer for Momentum {
    fn update(&mut self, weights: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(weights.len(), grads.len());
        debug_assert_eq!(weights.len(), self.velocity.len());
        let lr = self.lr * self.scale;
        let mu = self.mu;
        let nesterov = self.nesterov;
        let step = |w: &mut [f32], g: &[f32], vel: &mut [f32]| {
            if nesterov {
                for ((wi, gi), vi) in w.iter_mut().zip(g)
                    .zip(vel.iter_mut()) {
                    *vi = mu * *vi - lr * gi;
                    *wi += mu * *vi - lr * gi;
                }
            } else {
                for ((wi, gi), vi) in w.iter_mut().zip(g)
                    .zip(vel.iter_mut()) {
                    *vi = mu * *vi - lr * gi;
                    *wi += *vi;
                }
            }
        };
        match &self.pool {
            Some(pool) => {
                let wv = SharedMut::new(weights);
                let vv = SharedMut::new(&mut self.velocity);
                par_blocks(pool, grads.len(), |r| {
                    step(unsafe { wv.range(r.clone()) }, &grads[r.clone()],
                         unsafe { vv.range(r) });
                });
            }
            None => step(weights, grads, &mut self.velocity),
        }
    }

    fn name(&self) -> &'static str {
        "momentum"
    }

    fn set_lr_scale(&mut self, scale: f32) {
        self.scale = scale;
    }

    fn set_pool(&mut self, pool: Arc<ThreadPool>) {
        self.pool = Some(pool);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_single_step_exact() {
        let mut opt = Sgd::new(0.1);
        let mut w = vec![1.0f32, 2.0];
        opt.update(&mut w, &[10.0, -10.0]);
        assert_eq!(w, vec![0.0, 3.0]);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut opt = Momentum::new(0.1, 0.9, false, 1);
        let mut w = vec![0.0f32];
        opt.update(&mut w, &[1.0]); // v=-0.1, w=-0.1
        opt.update(&mut w, &[1.0]); // v=-0.19, w=-0.29
        assert!((w[0] + 0.29).abs() < 1e-6, "{w:?}");
    }

    #[test]
    fn lr_scale_applies() {
        let mut opt = Sgd::new(0.1);
        opt.set_lr_scale(0.5);
        let mut w = vec![0.0f32];
        opt.update(&mut w, &[1.0]);
        assert!((w[0] + 0.05).abs() < 1e-7);
    }

    #[test]
    fn nesterov_differs_from_plain() {
        let mut plain = Momentum::new(0.1, 0.9, false, 1);
        let mut nest = Momentum::new(0.1, 0.9, true, 1);
        let mut w1 = vec![0.0f32];
        let mut w2 = vec![0.0f32];
        for _ in 0..3 {
            plain.update(&mut w1, &[1.0]);
            nest.update(&mut w2, &[1.0]);
        }
        assert_ne!(w1, w2);
    }

    /// Pooled updates must be bitwise-identical to the serial loop —
    /// the optimizer half of the thread-count-invariance contract.
    #[test]
    fn pooled_updates_are_bitwise_identical() {
        let n = 10_000usize;
        let grads: Vec<f32> =
            (0..n).map(|i| ((i % 113) as f32 - 56.0) * 0.017).collect();
        let init: Vec<f32> =
            (0..n).map(|i| ((i % 97) as f32) * 0.021 - 1.0).collect();
        for threads in [2usize, 4] {
            let pool = Arc::new(ThreadPool::new(threads));
            let mut serial = Momentum::new(0.05, 0.9, true, n);
            let mut pooled = Momentum::new(0.05, 0.9, true, n);
            pooled.set_pool(Arc::clone(&pool));
            let mut ws = init.clone();
            let mut wp = init.clone();
            for _ in 0..3 {
                serial.update(&mut ws, &grads);
                pooled.update(&mut wp, &grads);
            }
            assert!(ws.iter().zip(&wp)
                .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "momentum diverged at {threads} threads");

            let mut serial = Sgd::new(0.05);
            let mut pooled = Sgd::new(0.05);
            pooled.set_pool(pool);
            let mut ws = init.clone();
            let mut wp = init.clone();
            serial.update(&mut ws, &grads);
            pooled.update(&mut wp, &grads);
            assert!(ws.iter().zip(&wp)
                .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "sgd diverged at {threads} threads");
        }
    }
}
