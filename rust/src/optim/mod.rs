//! Master-side optimizers.
//!
//! In Downpour SGD the master owns the weights and applies every incoming
//! worker gradient with its optimizer — exactly `mpi_learn`'s `Algo`
//! optimizers. All of them operate on the flat parameter buffer. Momentum
//! is the paper's recommended mitigation for stale gradients [Omnivore,
//! ref 9], so it is the benchmark default.

mod adadelta;
mod adam;
mod rmsprop;
mod sgd;

pub use adadelta::AdaDelta;
pub use adam::Adam;
pub use rmsprop::RmsProp;
pub use sgd::{Momentum, Sgd};

/// A stateful first-order optimizer over a flat f32 parameter vector.
pub trait Optimizer: Send {
    /// In-place update of `weights` given `grads` (same length).
    fn update(&mut self, weights: &mut [f32], grads: &[f32]);

    /// Human-readable name for logs/benches.
    fn name(&self) -> &'static str;

    /// Scale the base learning rate (LR schedules / EASGD force tuning).
    fn set_lr_scale(&mut self, scale: f32);

    /// Run the per-element update loop on this compute pool. Every
    /// element's op sequence is unchanged — the pool only partitions
    /// the index range — so updates stay bitwise-identical at any
    /// thread count. Default: keep the serial loop.
    fn set_pool(&mut self, _pool: std::sync::Arc<crate::util::threadpool::ThreadPool>) {}
}

/// Optimizer hyper-parameter bundle: what the paper's `Algo` class stores.
#[derive(Clone, Debug, PartialEq)]
pub enum OptimizerConfig {
    Sgd { lr: f32 },
    Momentum { lr: f32, momentum: f32, nesterov: bool },
    Adam { lr: f32, beta1: f32, beta2: f32, eps: f32 },
    RmsProp { lr: f32, rho: f32, eps: f32 },
    AdaDelta { rho: f32, eps: f32 },
}

impl OptimizerConfig {
    /// Paper benchmark default: momentum SGD (stale-gradient mitigation).
    pub fn default_momentum() -> Self {
        OptimizerConfig::Momentum { lr: 0.05, momentum: 0.9,
                                    nesterov: false }
    }

    pub fn build(&self, n: usize) -> Box<dyn Optimizer> {
        match *self {
            OptimizerConfig::Sgd { lr } => Box::new(Sgd::new(lr)),
            OptimizerConfig::Momentum { lr, momentum, nesterov } => {
                Box::new(Momentum::new(lr, momentum, nesterov, n))
            }
            OptimizerConfig::Adam { lr, beta1, beta2, eps } => {
                Box::new(Adam::new(lr, beta1, beta2, eps, n))
            }
            OptimizerConfig::RmsProp { lr, rho, eps } => {
                Box::new(RmsProp::new(lr, rho, eps, n))
            }
            OptimizerConfig::AdaDelta { rho, eps } => {
                Box::new(AdaDelta::new(rho, eps, n))
            }
        }
    }

    /// Parse from a config JSON object: `{"kind": "momentum", "lr": 0.05}`.
    pub fn from_json(j: &crate::util::json::Json) -> Option<Self> {
        let kind = j.get("kind")?.as_str()?;
        let f = |key: &str, default: f32| {
            j.get(key).and_then(|v| v.as_f64()).map(|v| v as f32)
                .unwrap_or(default)
        };
        Some(match kind {
            "sgd" => OptimizerConfig::Sgd { lr: f("lr", 0.05) },
            "momentum" => OptimizerConfig::Momentum {
                lr: f("lr", 0.05),
                momentum: f("momentum", 0.9),
                nesterov: j.get("nesterov").and_then(|v| v.as_bool())
                    .unwrap_or(false),
            },
            "adam" => OptimizerConfig::Adam {
                lr: f("lr", 0.001),
                beta1: f("beta1", 0.9),
                beta2: f("beta2", 0.999),
                eps: f("eps", 1e-8),
            },
            "rmsprop" => OptimizerConfig::RmsProp {
                lr: f("lr", 0.001),
                rho: f("rho", 0.9),
                eps: f("eps", 1e-7),
            },
            "adadelta" => OptimizerConfig::AdaDelta {
                rho: f("rho", 0.95),
                eps: f("eps", 1e-6),
            },
            _ => return None,
        })
    }
}

/// Gradient clipping by global L2 norm — wraps any optimizer.
pub struct GradClip {
    inner: Box<dyn Optimizer>,
    max_norm: f32,
    scratch: Vec<f32>,
}

impl GradClip {
    pub fn new(inner: Box<dyn Optimizer>, max_norm: f32) -> Self {
        Self { inner, max_norm, scratch: Vec::new() }
    }
}

impl Optimizer for GradClip {
    fn update(&mut self, weights: &mut [f32], grads: &[f32]) {
        let norm = grads.iter().map(|g| g * g).sum::<f32>().sqrt();
        if norm > self.max_norm {
            let scale = self.max_norm / norm;
            self.scratch.clear();
            self.scratch.extend(grads.iter().map(|g| g * scale));
            self.inner.update(weights, &self.scratch);
        } else {
            self.inner.update(weights, grads);
        }
    }

    fn name(&self) -> &'static str {
        "grad-clip"
    }

    fn set_lr_scale(&mut self, scale: f32) {
        self.inner.set_lr_scale(scale);
    }

    // The global L2-norm reduction stays serial (its accumulation
    // order is the contract); only the inner optimizer's elementwise
    // loop parallelizes.
    fn set_pool(&mut self, pool: std::sync::Arc<crate::util::threadpool::ThreadPool>) {
        self.inner.set_pool(pool);
    }
}

/// Step-decay learning-rate schedule: lr *= gamma every `every` updates.
#[derive(Clone, Debug)]
pub struct StepDecay {
    pub gamma: f32,
    pub every: u64,
    steps: u64,
    scale: f32,
}

impl StepDecay {
    pub fn new(gamma: f32, every: u64) -> Self {
        Self { gamma, every, steps: 0, scale: 1.0 }
    }

    /// Advance one update; returns the current scale to apply.
    pub fn tick(&mut self) -> f32 {
        self.steps += 1;
        if self.every > 0 && self.steps % self.every == 0 {
            self.scale *= self.gamma;
        }
        self.scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    /// Quadratic bowl: every optimizer must descend f(w) = |w - 3|^2.
    fn descend(opt: &mut dyn Optimizer, steps: usize) -> f32 {
        let mut w = vec![0.0f32; 8];
        for _ in 0..steps {
            let g: Vec<f32> = w.iter().map(|wi| 2.0 * (wi - 3.0)).collect();
            opt.update(&mut w, &g);
        }
        w.iter().map(|wi| (wi - 3.0).powi(2)).sum()
    }

    #[test]
    fn all_optimizers_descend_quadratic() {
        let configs = [
            (OptimizerConfig::Sgd { lr: 0.1 }, 300, 0.1),
            (OptimizerConfig::Momentum { lr: 0.05, momentum: 0.9,
                                         nesterov: false }, 300, 0.1),
            (OptimizerConfig::Momentum { lr: 0.05, momentum: 0.9,
                                         nesterov: true }, 300, 0.1),
            (OptimizerConfig::Adam { lr: 0.3, beta1: 0.9, beta2: 0.999,
                                     eps: 1e-8 }, 300, 0.1),
            (OptimizerConfig::RmsProp { lr: 0.1, rho: 0.9, eps: 1e-7 },
             300, 0.1),
            // AdaDelta self-tunes its effective lr from zero — slow off
            // the mark by construction, so give it a longer horizon.
            (OptimizerConfig::AdaDelta { rho: 0.95, eps: 1e-6 }, 8000,
             1.0),
        ];
        for (cfg, steps, tol) in configs {
            let mut opt = cfg.build(8);
            let end = descend(opt.as_mut(), steps);
            assert!(end < tol, "{} ended at {end}", opt.name());
        }
    }

    #[test]
    fn grad_clip_limits_step() {
        let mut clipped = GradClip::new(
            OptimizerConfig::Sgd { lr: 1.0 }.build(4), 1.0);
        let mut w = vec![0.0f32; 4];
        clipped.update(&mut w, &[100.0, 0.0, 0.0, 0.0]);
        // clipped gradient has norm 1 -> step length exactly lr * 1
        let norm: f32 = w.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5, "{w:?}");
    }

    #[test]
    fn step_decay_halves() {
        let mut sched = StepDecay::new(0.5, 2);
        assert_eq!(sched.tick(), 1.0);
        assert_eq!(sched.tick(), 0.5);
        assert_eq!(sched.tick(), 0.5);
        assert_eq!(sched.tick(), 0.25);
    }

    #[test]
    fn config_from_json() {
        let j = Json::parse(
            r#"{"kind": "momentum", "lr": 0.1, "momentum": 0.8}"#).unwrap();
        assert_eq!(
            OptimizerConfig::from_json(&j).unwrap(),
            OptimizerConfig::Momentum { lr: 0.1, momentum: 0.8,
                                        nesterov: false });
        let j = Json::parse(r#"{"kind": "bogus"}"#).unwrap();
        assert!(OptimizerConfig::from_json(&j).is_none());
    }
}
