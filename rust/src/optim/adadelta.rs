//! AdaDelta (Zeiler) — no global learning rate.

use super::Optimizer;

pub struct AdaDelta {
    rho: f32,
    eps: f32,
    scale: f32,
    acc_g: Vec<f32>,
    acc_dx: Vec<f32>,
}

impl AdaDelta {
    pub fn new(rho: f32, eps: f32, n: usize) -> Self {
        Self { rho, eps, scale: 1.0, acc_g: vec![0.0; n],
               acc_dx: vec![0.0; n] }
    }
}

impl Optimizer for AdaDelta {
    fn update(&mut self, weights: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(weights.len(), grads.len());
        let rho = self.rho;
        let eps = self.eps;
        for i in 0..weights.len() {
            let g = grads[i];
            self.acc_g[i] = rho * self.acc_g[i] + (1.0 - rho) * g * g;
            let dx = -((self.acc_dx[i] + eps).sqrt()
                / (self.acc_g[i] + eps).sqrt())
                * g
                * self.scale;
            self.acc_dx[i] = rho * self.acc_dx[i] + (1.0 - rho) * dx * dx;
            weights[i] += dx;
        }
    }

    fn name(&self) -> &'static str {
        "adadelta"
    }

    fn set_lr_scale(&mut self, scale: f32) {
        self.scale = scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_sizes_self_tune() {
        let mut opt = AdaDelta::new(0.95, 1e-6, 1);
        let mut w = vec![10.0f32];
        for _ in 0..2000 {
            let g = 2.0 * w[0]; // descend x^2
            opt.update(&mut w, &[g]);
        }
        assert!(w[0].abs() < 1.0, "{w:?}");
    }
}
