//! AdaDelta (Zeiler) — no global learning rate.

use std::sync::Arc;

use super::Optimizer;
use crate::runtime::kernels::par_blocks;
use crate::util::threadpool::{SharedMut, ThreadPool};

pub struct AdaDelta {
    rho: f32,
    eps: f32,
    scale: f32,
    acc_g: Vec<f32>,
    acc_dx: Vec<f32>,
    pool: Option<Arc<ThreadPool>>,
}

impl AdaDelta {
    pub fn new(rho: f32, eps: f32, n: usize) -> Self {
        Self { rho, eps, scale: 1.0, acc_g: vec![0.0; n],
               acc_dx: vec![0.0; n], pool: None }
    }
}

impl Optimizer for AdaDelta {
    fn update(&mut self, weights: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(weights.len(), grads.len());
        let rho = self.rho;
        let eps = self.eps;
        let scale = self.scale;
        let step = |w: &mut [f32], g: &[f32], acc_g: &mut [f32],
                    acc_dx: &mut [f32]| {
            for i in 0..w.len() {
                let gi = g[i];
                acc_g[i] = rho * acc_g[i] + (1.0 - rho) * gi * gi;
                let dx = -((acc_dx[i] + eps).sqrt()
                    / (acc_g[i] + eps).sqrt())
                    * gi
                    * scale;
                acc_dx[i] = rho * acc_dx[i] + (1.0 - rho) * dx * dx;
                w[i] += dx;
            }
        };
        match &self.pool {
            Some(pool) => {
                let wv = SharedMut::new(weights);
                let gv = SharedMut::new(&mut self.acc_g);
                let dv = SharedMut::new(&mut self.acc_dx);
                par_blocks(pool, grads.len(), |r| {
                    step(unsafe { wv.range(r.clone()) }, &grads[r.clone()],
                         unsafe { gv.range(r.clone()) },
                         unsafe { dv.range(r) });
                });
            }
            None => step(weights, grads, &mut self.acc_g,
                         &mut self.acc_dx),
        }
    }

    fn name(&self) -> &'static str {
        "adadelta"
    }

    fn set_lr_scale(&mut self, scale: f32) {
        self.scale = scale;
    }

    fn set_pool(&mut self, pool: Arc<ThreadPool>) {
        self.pool = Some(pool);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_sizes_self_tune() {
        let mut opt = AdaDelta::new(0.95, 1e-6, 1);
        let mut w = vec![10.0f32];
        for _ in 0..2000 {
            let g = 2.0 * w[0]; // descend x^2
            opt.update(&mut w, &[g]);
        }
        assert!(w[0].abs() < 1.0, "{w:?}");
    }

    #[test]
    fn pooled_updates_are_bitwise_identical() {
        let n = 10_001usize;
        let grads: Vec<f32> =
            (0..n).map(|i| ((i % 61) as f32 - 30.0) * 0.019).collect();
        let init: Vec<f32> =
            (0..n).map(|i| ((i % 53) as f32) * 0.023 - 0.5).collect();
        let pool = Arc::new(ThreadPool::new(4));
        let mut serial = AdaDelta::new(0.95, 1e-6, n);
        let mut pooled = AdaDelta::new(0.95, 1e-6, n);
        pooled.set_pool(pool);
        let mut ws = init.clone();
        let mut wp = init;
        for _ in 0..3 {
            serial.update(&mut ws, &grads);
            pooled.update(&mut wp, &grads);
        }
        assert!(ws.iter().zip(&wp)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }
}
