//! RMSProp (Tieleman & Hinton) — Keras-style.

use super::Optimizer;

pub struct RmsProp {
    lr: f32,
    rho: f32,
    eps: f32,
    scale: f32,
    ms: Vec<f32>,
}

impl RmsProp {
    pub fn new(lr: f32, rho: f32, eps: f32, n: usize) -> Self {
        Self { lr, rho, eps, scale: 1.0, ms: vec![0.0; n] }
    }
}

impl Optimizer for RmsProp {
    fn update(&mut self, weights: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(weights.len(), grads.len());
        let lr = self.lr * self.scale;
        let rho = self.rho;
        for i in 0..weights.len() {
            let g = grads[i];
            self.ms[i] = rho * self.ms[i] + (1.0 - rho) * g * g;
            weights[i] -= lr * g / (self.ms[i].sqrt() + self.eps);
        }
    }

    fn name(&self) -> &'static str {
        "rmsprop"
    }

    fn set_lr_scale(&mut self, scale: f32) {
        self.scale = scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalizes_gradient_scale() {
        let mut big = RmsProp::new(0.01, 0.9, 1e-7, 1);
        let mut small = RmsProp::new(0.01, 0.9, 1e-7, 1);
        let mut wb = vec![0.0f32];
        let mut ws = vec![0.0f32];
        for _ in 0..100 {
            big.update(&mut wb, &[1000.0]);
            small.update(&mut ws, &[0.001]);
        }
        // steady-state step is ~lr regardless of gradient magnitude
        assert!((wb[0] - ws[0]).abs() / wb[0].abs() < 0.01,
                "wb={wb:?} ws={ws:?}");
    }
}
