//! RMSProp (Tieleman & Hinton) — Keras-style.

use std::sync::Arc;

use super::Optimizer;
use crate::runtime::kernels::par_blocks;
use crate::util::threadpool::{SharedMut, ThreadPool};

pub struct RmsProp {
    lr: f32,
    rho: f32,
    eps: f32,
    scale: f32,
    ms: Vec<f32>,
    pool: Option<Arc<ThreadPool>>,
}

impl RmsProp {
    pub fn new(lr: f32, rho: f32, eps: f32, n: usize) -> Self {
        Self { lr, rho, eps, scale: 1.0, ms: vec![0.0; n], pool: None }
    }
}

impl Optimizer for RmsProp {
    fn update(&mut self, weights: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(weights.len(), grads.len());
        let lr = self.lr * self.scale;
        let rho = self.rho;
        let eps = self.eps;
        let step = |w: &mut [f32], g: &[f32], ms: &mut [f32]| {
            for i in 0..w.len() {
                let gi = g[i];
                ms[i] = rho * ms[i] + (1.0 - rho) * gi * gi;
                w[i] -= lr * gi / (ms[i].sqrt() + eps);
            }
        };
        match &self.pool {
            Some(pool) => {
                let wv = SharedMut::new(weights);
                let msv = SharedMut::new(&mut self.ms);
                par_blocks(pool, grads.len(), |r| {
                    step(unsafe { wv.range(r.clone()) }, &grads[r.clone()],
                         unsafe { msv.range(r) });
                });
            }
            None => step(weights, grads, &mut self.ms),
        }
    }

    fn name(&self) -> &'static str {
        "rmsprop"
    }

    fn set_lr_scale(&mut self, scale: f32) {
        self.scale = scale;
    }

    fn set_pool(&mut self, pool: Arc<ThreadPool>) {
        self.pool = Some(pool);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalizes_gradient_scale() {
        let mut big = RmsProp::new(0.01, 0.9, 1e-7, 1);
        let mut small = RmsProp::new(0.01, 0.9, 1e-7, 1);
        let mut wb = vec![0.0f32];
        let mut ws = vec![0.0f32];
        for _ in 0..100 {
            big.update(&mut wb, &[1000.0]);
            small.update(&mut ws, &[0.001]);
        }
        // steady-state step is ~lr regardless of gradient magnitude
        assert!((wb[0] - ws[0]).abs() / wb[0].abs() < 0.01,
                "wb={wb:?} ws={ws:?}");
    }

    #[test]
    fn pooled_updates_are_bitwise_identical() {
        let n = 8_191usize;
        let grads: Vec<f32> =
            (0..n).map(|i| ((i % 101) as f32 - 50.0) * 0.013).collect();
        let init: Vec<f32> =
            (0..n).map(|i| ((i % 89) as f32) * 0.017 - 0.7).collect();
        let pool = Arc::new(ThreadPool::new(4));
        let mut serial = RmsProp::new(0.01, 0.9, 1e-7, n);
        let mut pooled = RmsProp::new(0.01, 0.9, 1e-7, n);
        pooled.set_pool(pool);
        let mut ws = init.clone();
        let mut wp = init;
        for _ in 0..3 {
            serial.update(&mut ws, &grads);
            pooled.update(&mut wp, &grads);
        }
        assert!(ws.iter().zip(&wp)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }
}
