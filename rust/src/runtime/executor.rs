//! PJRT execution: load HLO text, compile once, run from the hot path.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client). Interchange is HLO
//! *text* — see `python/compile/aot.py` for why.
//!
//! ### Thread safety
//! The training world runs master + workers on OS threads sharing one
//! `PjRtClient` and per-variant compiled executables. The `xla` crate's
//! wrappers are raw-pointer newtypes without `Send`/`Sync`, but the
//! underlying PJRT CPU client is documented thread-safe for `Compile` and
//! `Execute`, and each call here builds its own `Literal` inputs and
//! consumes its own outputs. We therefore wrap the client + executable in
//! newtypes with `unsafe impl Send + Sync`, and the integration suite
//! hammers concurrent `execute` calls to back the claim empirically.

use std::path::Path;
use std::sync::Arc;

use crate::runtime::artifact::ModelMeta;
use crate::tensor::ParamSet;

#[derive(Debug, thiserror::Error)]
pub enum RuntimeError {
    #[error("xla: {0}")]
    Xla(String),
    #[error("artifact {0} failed to load: {1}")]
    Load(String, String),
    #[error("input size mismatch: expected {expect} got {got} for {what}")]
    BadInput { what: &'static str, expect: usize, got: usize },
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

/// Shared PJRT CPU client (safety: see module docs).
pub struct Client {
    inner: xla::PjRtClient,
}

unsafe impl Send for Client {}
unsafe impl Sync for Client {}

impl Client {
    pub fn cpu() -> Result<Arc<Client>, RuntimeError> {
        Ok(Arc::new(Client { inner: xla::PjRtClient::cpu()? }))
    }

    pub fn platform(&self) -> String {
        self.inner.platform_name()
    }

    /// Compile HLO text from `path`.
    pub fn compile_file(&self, path: &Path)
        -> Result<Executable, RuntimeError> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("non-utf8 artifact path"))
            .map_err(|e| RuntimeError::Load(path.display().to_string(),
                                            e.to_string()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.inner.compile(&comp)?;
        Ok(Executable { inner: exe })
    }
}

/// A compiled HLO module (safety: see module docs).
pub struct Executable {
    inner: xla::PjRtLoadedExecutable,
}

unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with literal inputs; returns the flattened output tuple.
    pub fn run(&self, inputs: &[xla::Literal])
        -> Result<Vec<xla::Literal>, RuntimeError> {
        let result = self.inner.execute::<xla::Literal>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }
}

fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal,
    RuntimeError> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal,
    RuntimeError> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

/// The three per-variant executables, typed to the artifact interface.
pub struct ModelExecutables {
    pub meta: ModelMeta,
    grad: Executable,
    eval: Executable,
    predict: Option<Executable>,
}

/// Output of one gradient step.
#[derive(Clone, Debug)]
pub struct GradOutput {
    pub loss: f32,
    /// Flat gradient in the ParamSet/manifest parameter order.
    pub grads: Vec<f32>,
}

impl ModelExecutables {
    /// Compile grad+eval (+ predict if wanted) for one variant.
    pub fn load(client: &Client, meta: &ModelMeta, with_predict: bool)
        -> Result<ModelExecutables, RuntimeError> {
        Ok(ModelExecutables {
            meta: meta.clone(),
            grad: client.compile_file(&meta.grad_file)?,
            eval: client.compile_file(&meta.eval_file)?,
            predict: if with_predict {
                Some(client.compile_file(&meta.predict_file)?)
            } else {
                None
            },
        })
    }

    fn check_xy(&self, x: &[f32], y: &[i32]) -> Result<(), RuntimeError> {
        if x.len() != self.meta.x_len() {
            return Err(RuntimeError::BadInput {
                what: "x", expect: self.meta.x_len(), got: x.len() });
        }
        if y.len() != self.meta.batch {
            return Err(RuntimeError::BadInput {
                what: "y", expect: self.meta.batch, got: y.len() });
        }
        Ok(())
    }

    fn param_literals(&self, params: &ParamSet)
        -> Result<Vec<xla::Literal>, RuntimeError> {
        if params.num_params() != self.meta.param_count {
            return Err(RuntimeError::BadInput {
                what: "params",
                expect: self.meta.param_count,
                got: params.num_params(),
            });
        }
        let mut lits = Vec::with_capacity(self.meta.params.len() + 2);
        for (i, (_, shape)) in self.meta.params.iter().enumerate() {
            lits.push(literal_f32(params.slice(i), shape)?);
        }
        Ok(lits)
    }

    /// Build the positional input literals for a (params, x, y) call.
    /// Public so the microbench can price marshalling separately from
    /// execution (EXPERIMENTS.md §Perf).
    pub fn marshal_inputs(&self, params: &ParamSet, x: &[f32], y: &[i32])
        -> Result<Vec<xla::Literal>, RuntimeError> {
        self.check_xy(x, y)?;
        let mut inputs = self.param_literals(params)?;
        inputs.push(literal_f32(
            x, &[self.meta.batch, self.meta.seq_len, self.meta.features])?);
        inputs.push(literal_i32(y, &[self.meta.batch])?);
        Ok(inputs)
    }

    /// One gradient step: (params, x, y) -> (loss, flat grads).
    pub fn grad_step(&self, params: &ParamSet, x: &[f32], y: &[i32])
        -> Result<GradOutput, RuntimeError> {
        let inputs = self.marshal_inputs(params, x, y)?;
        let outputs = self.grad.run(&inputs)?;
        debug_assert_eq!(outputs.len(), 1 + self.meta.params.len());
        let loss = outputs[0].get_first_element::<f32>()?;
        // single exact-size allocation; copy_raw_to avoids the per-output
        // Vec each to_vec() would allocate (perf pass iter 1)
        let mut grads = vec![0.0f32; self.meta.param_count];
        let mut off = 0usize;
        for (lit, (_, shape)) in
            outputs[1..].iter().zip(&self.meta.params) {
            let len: usize = shape.iter().product();
            lit.copy_raw_to(&mut grads[off..off + len])?;
            off += len;
        }
        debug_assert_eq!(off, self.meta.param_count);
        Ok(GradOutput { loss, grads })
    }

    /// Evaluation: (params, x, y) -> (mean loss, n correct).
    pub fn eval_step(&self, params: &ParamSet, x: &[f32], y: &[i32])
        -> Result<(f32, f32), RuntimeError> {
        self.check_xy(x, y)?;
        let mut inputs = self.param_literals(params)?;
        inputs.push(literal_f32(
            x, &[self.meta.batch, self.meta.seq_len, self.meta.features])?);
        inputs.push(literal_i32(y, &[self.meta.batch])?);
        let outputs = self.eval.run(&inputs)?;
        let loss = outputs[0].to_vec::<f32>()?[0];
        let ncorrect = outputs[1].to_vec::<f32>()?[0];
        Ok((loss, ncorrect))
    }

    /// Inference: (params, x) -> logits [batch * classes].
    pub fn predict(&self, params: &ParamSet, x: &[f32])
        -> Result<Vec<f32>, RuntimeError> {
        let pred = self.predict.as_ref().expect(
            "ModelExecutables loaded without predict");
        if x.len() != self.meta.x_len() {
            return Err(RuntimeError::BadInput {
                what: "x", expect: self.meta.x_len(), got: x.len() });
        }
        let mut inputs = self.param_literals(params)?;
        inputs.push(literal_f32(
            x, &[self.meta.batch, self.meta.seq_len, self.meta.features])?);
        let outputs = pred.run(&inputs)?;
        Ok(outputs[0].to_vec::<f32>()?)
    }

    /// Fresh Glorot-initialized parameters matching this variant.
    pub fn init_params(&self, rng: &mut crate::util::rng::Rng) -> ParamSet {
        ParamSet::glorot_init(&self.meta.params, rng)
    }
}
