//! Model execution: backend dispatch between the built-in native CPU
//! engine and (feature-gated) PJRT.
//!
//! The default offline build executes models with
//! [`super::native`] — pure Rust, zero dependencies, same math as the
//! AOT artifacts. Enabling the `pjrt` cargo feature restores the
//! original path: load HLO text, compile once through the `xla` crate's
//! PJRT CPU client, run from the hot path. (The `xla` crate lives on the
//! registry and must be re-added to `Cargo.toml` alongside the feature;
//! the offline tree intentionally carries no reference to it otherwise.)
//!
//! ### Thread safety (pjrt)
//! The training world runs master + workers on OS threads sharing one
//! `PjRtClient` and per-variant compiled executables. The `xla` crate's
//! wrappers are raw-pointer newtypes without `Send`/`Sync`, but the
//! underlying PJRT CPU client is documented thread-safe for `Compile`
//! and `Execute`, and each call here builds its own `Literal` inputs and
//! consumes its own outputs — hence the `unsafe impl`s below. The native
//! backend is plain data and trivially `Send + Sync`.

#[cfg(feature = "pjrt")]
use std::path::Path;
use std::sync::Arc;

use crate::runtime::artifact::ModelMeta;
use crate::runtime::native::NativeModel;
use crate::tensor::ParamSet;

#[derive(Debug)]
pub enum RuntimeError {
    Xla(String),
    Load(String, String),
    BadInput { what: &'static str, expect: usize, got: usize },
    /// The requested model/backend combination is not available in this
    /// build (e.g. transformer without the `pjrt` feature).
    Unsupported(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Xla(msg) => write!(f, "xla: {msg}"),
            RuntimeError::Load(what, err) => {
                write!(f, "artifact {what} failed to load: {err}")
            }
            RuntimeError::BadInput { what, expect, got } => write!(
                f,
                "input size mismatch: expected {expect} got {got} for {what}"
            ),
            RuntimeError::Unsupported(msg) => {
                write!(f, "unsupported: {msg}")
            }
        }
    }
}

impl std::error::Error for RuntimeError {}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

/// Execution client. With the `pjrt` feature this wraps the shared PJRT
/// CPU client; the native backend needs no client state.
pub struct Client {
    #[cfg(feature = "pjrt")]
    inner: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
unsafe impl Send for Client {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for Client {}

impl Client {
    #[cfg(feature = "pjrt")]
    pub fn cpu() -> Result<Arc<Client>, RuntimeError> {
        Ok(Arc::new(Client { inner: xla::PjRtClient::cpu()? }))
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn cpu() -> Result<Arc<Client>, RuntimeError> {
        Ok(Arc::new(Client {}))
    }

    #[cfg(feature = "pjrt")]
    pub fn platform(&self) -> String {
        self.inner.platform_name()
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    /// Compile HLO text from `path`.
    #[cfg(feature = "pjrt")]
    pub fn compile_file(&self, path: &Path)
        -> Result<Executable, RuntimeError> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("non-utf8 artifact path"))
            .map_err(|e| RuntimeError::Load(path.display().to_string(),
                                            e.to_string()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.inner.compile(&comp)?;
        Ok(Executable { inner: exe })
    }
}

/// A compiled HLO module (pjrt builds only; safety: see module docs).
#[cfg(feature = "pjrt")]
pub struct Executable {
    inner: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
unsafe impl Send for Executable {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for Executable {}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute with literal inputs; returns the flattened output tuple.
    pub fn run(&self, inputs: &[xla::Literal])
        -> Result<Vec<xla::Literal>, RuntimeError> {
        let result = self.inner.execute::<xla::Literal>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }
}

#[cfg(feature = "pjrt")]
fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal,
    RuntimeError> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

#[cfg(feature = "pjrt")]
fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal,
    RuntimeError> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

enum Backend {
    Native(NativeModel),
    #[cfg(feature = "pjrt")]
    Pjrt {
        grad: Executable,
        eval: Executable,
        predict: Option<Executable>,
    },
}

/// The per-variant executable bundle, typed to the artifact interface.
pub struct ModelExecutables {
    pub meta: ModelMeta,
    backend: Backend,
}

/// Output of one gradient step.
#[derive(Clone, Debug)]
pub struct GradOutput {
    pub loss: f32,
    /// Flat gradient in the ParamSet/manifest parameter order.
    pub grads: Vec<f32>,
}

/// One layer's slice of the flat gradient became final mid-backward.
///
/// The native layer DAG emits these in reverse topological order
/// (output layer first) while upstream layers are still computing; the
/// bucketed all-reduce launches a collective per event so communication
/// overlaps the rest of backprop (DESIGN.md §Layer DAG & bucketed
/// overlap).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BucketReady {
    /// DAG node index (emission order: highest index first).
    pub layer: usize,
    /// Finalized contiguous range of the flat gradient vector
    /// (matches one [`ParamSet::layer_ranges`] entry).
    pub param_range: std::ops::Range<usize>,
}

/// Receiver for [`BucketReady`] events. `grads` is the full flat
/// gradient buffer; only `ready.param_range` is guaranteed final when
/// the event fires.
pub trait GradSink {
    fn bucket_ready(&mut self, ready: BucketReady, grads: &[f32]);
}

/// No-op sink for plain (non-overlapped) gradient steps.
impl GradSink for () {
    fn bucket_ready(&mut self, _ready: BucketReady, _grads: &[f32]) {}
}

impl ModelExecutables {
    /// Compile grad+eval (+ predict if wanted) for one variant via PJRT.
    #[cfg(feature = "pjrt")]
    pub fn load(client: &Client, meta: &ModelMeta, with_predict: bool)
        -> Result<ModelExecutables, RuntimeError> {
        Ok(ModelExecutables {
            meta: meta.clone(),
            backend: Backend::Pjrt {
                grad: client.compile_file(&meta.grad_file)?,
                eval: client.compile_file(&meta.eval_file)?,
                predict: if with_predict {
                    Some(client.compile_file(&meta.predict_file)?)
                } else {
                    None
                },
            },
        })
    }

    /// Build the native CPU engine for a variant.
    pub fn native(meta: &ModelMeta)
        -> Result<ModelExecutables, RuntimeError> {
        Ok(ModelExecutables {
            meta: meta.clone(),
            backend: Backend::Native(NativeModel::from_meta(meta)?),
        })
    }

    /// Which backend executes this variant (for logs/benches).
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            Backend::Native(_) => "native",
            #[cfg(feature = "pjrt")]
            Backend::Pjrt { .. } => "pjrt",
        }
    }

    fn check_xy(&self, x: &[f32], y: &[i32]) -> Result<(), RuntimeError> {
        if x.len() != self.meta.x_len() {
            return Err(RuntimeError::BadInput {
                what: "x", expect: self.meta.x_len(), got: x.len() });
        }
        if y.len() != self.meta.batch {
            return Err(RuntimeError::BadInput {
                what: "y", expect: self.meta.batch, got: y.len() });
        }
        Ok(())
    }

    fn check_params(&self, params: &ParamSet) -> Result<(), RuntimeError> {
        if params.num_params() != self.meta.param_count {
            return Err(RuntimeError::BadInput {
                what: "params",
                expect: self.meta.param_count,
                got: params.num_params(),
            });
        }
        Ok(())
    }

    #[cfg(feature = "pjrt")]
    fn param_literals(&self, params: &ParamSet)
        -> Result<Vec<xla::Literal>, RuntimeError> {
        self.check_params(params)?;
        let mut lits = Vec::with_capacity(self.meta.params.len() + 2);
        for (i, (_, shape)) in self.meta.params.iter().enumerate() {
            lits.push(literal_f32(params.slice(i), shape)?);
        }
        Ok(lits)
    }

    #[cfg(feature = "pjrt")]
    fn pjrt_inputs(&self, params: &ParamSet, x: &[f32], y: &[i32])
        -> Result<Vec<xla::Literal>, RuntimeError> {
        let mut inputs = self.param_literals(params)?;
        inputs.push(literal_f32(
            x, &[self.meta.batch, self.meta.seq_len, self.meta.features])?);
        inputs.push(literal_i32(y, &[self.meta.batch])?);
        Ok(inputs)
    }

    /// Validate and stage the positional inputs for a (params, x, y)
    /// call, returning how many input buffers a step passes to the
    /// backend. Public so the microbench can price marshalling
    /// separately from execution (EXPERIMENTS.md §Perf).
    pub fn marshal_inputs(&self, params: &ParamSet, x: &[f32], y: &[i32])
        -> Result<usize, RuntimeError> {
        self.check_xy(x, y)?;
        match &self.backend {
            Backend::Native(_) => {
                self.check_params(params)?;
                Ok(self.meta.params.len() + 2)
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt { .. } => {
                Ok(self.pjrt_inputs(params, x, y)?.len())
            }
        }
    }

    /// One gradient step: (params, x, y) -> (loss, flat grads).
    pub fn grad_step(&self, params: &ParamSet, x: &[f32], y: &[i32])
        -> Result<GradOutput, RuntimeError> {
        self.check_xy(x, y)?;
        match &self.backend {
            Backend::Native(model) => {
                self.check_params(params)?;
                model.grad_step(params, x, y)
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt { grad, .. } => {
                let inputs = self.pjrt_inputs(params, x, y)?;
                let outputs = grad.run(&inputs)?;
                debug_assert_eq!(outputs.len(),
                                 1 + self.meta.params.len());
                let loss = outputs[0].get_first_element::<f32>()?;
                // single allocation (plus the loss-piggyback spare
                // slot); copy_raw_to avoids the per-output Vec each
                // to_vec() would allocate
                let mut grads =
                    crate::runtime::native::grad_buffer(
                        self.meta.param_count);
                let mut off = 0usize;
                for (lit, (_, shape)) in
                    outputs[1..].iter().zip(&self.meta.params) {
                    let len: usize = shape.iter().product();
                    lit.copy_raw_to(&mut grads[off..off + len])?;
                    off += len;
                }
                debug_assert_eq!(off, self.meta.param_count);
                Ok(GradOutput { loss, grads })
            }
        }
    }

    /// [`ModelExecutables::grad_step`] with per-layer [`BucketReady`]
    /// emission for the bucketed, compute-overlapped all-reduce.
    ///
    /// The native backend fires each event the moment that layer's
    /// gradient lands, mid-backward. The PJRT backend computes the full
    /// gradient first (the compiled HLO is opaque) and then replays the
    /// same event sequence post-hoc — callers see identical semantics,
    /// just without intra-step overlap.
    pub fn grad_step_overlapped(&self, params: &ParamSet, x: &[f32],
                                y: &[i32], sink: &mut dyn GradSink)
        -> Result<GradOutput, RuntimeError> {
        self.check_xy(x, y)?;
        match &self.backend {
            Backend::Native(model) => {
                self.check_params(params)?;
                model.grad_step_overlapped(params, x, y, sink)
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt { .. } => {
                let out = self.grad_step(params, x, y)?;
                let ranges = params.layer_ranges();
                for (layer, (_, range)) in
                    ranges.into_iter().enumerate().rev() {
                    sink.bucket_ready(
                        BucketReady { layer, param_range: range },
                        &out.grads);
                }
                Ok(out)
            }
        }
    }

    /// Toggle scratch-buffer pooling in the native engine (no-op for
    /// PJRT, which manages its own buffers). On by default; the
    /// microbench flips it to price the arena.
    pub fn set_scratch_reuse(&self, on: bool) {
        match &self.backend {
            Backend::Native(model) => model.set_scratch_reuse(on),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt { .. } => {}
        }
    }

    /// Size the native engine's persistent compute pool (no-op for
    /// PJRT, which threads internally). `0` means auto-detect from
    /// `available_parallelism`; `1` restores the serial path. Safe to
    /// call between steps; trained weights are bitwise-identical at any
    /// thread count (DESIGN.md §Compute kernels).
    pub fn set_threads(&self, n: usize) {
        match &self.backend {
            Backend::Native(model) => model.set_threads(n),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt { .. } => {}
        }
    }

    /// The native engine's compute pool, shared so the optimizer and
    /// codec hot loops run on the same threads as the kernels. PJRT
    /// builds return a fresh 1-thread (inline) pool.
    pub fn thread_pool(&self) -> Arc<crate::util::threadpool::ThreadPool> {
        match &self.backend {
            Backend::Native(model) => model.thread_pool(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt { .. } => {
                Arc::new(crate::util::threadpool::ThreadPool::new(1))
            }
        }
    }

    /// Evaluation: (params, x, y) -> (mean loss, n correct).
    pub fn eval_step(&self, params: &ParamSet, x: &[f32], y: &[i32])
        -> Result<(f32, f32), RuntimeError> {
        self.check_xy(x, y)?;
        match &self.backend {
            Backend::Native(model) => {
                self.check_params(params)?;
                model.eval_step(params, x, y)
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt { eval, .. } => {
                let inputs = self.pjrt_inputs(params, x, y)?;
                let outputs = eval.run(&inputs)?;
                let loss = outputs[0].to_vec::<f32>()?[0];
                let ncorrect = outputs[1].to_vec::<f32>()?[0];
                Ok((loss, ncorrect))
            }
        }
    }

    /// Inference: (params, x) -> logits [batch * classes].
    pub fn predict(&self, params: &ParamSet, x: &[f32])
        -> Result<Vec<f32>, RuntimeError> {
        if x.len() != self.meta.x_len() {
            return Err(RuntimeError::BadInput {
                what: "x", expect: self.meta.x_len(), got: x.len() });
        }
        match &self.backend {
            Backend::Native(model) => {
                self.check_params(params)?;
                model.predict(params, x)
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt { predict, .. } => {
                let pred = predict.as_ref().expect(
                    "ModelExecutables loaded without predict");
                let mut inputs = self.param_literals(params)?;
                inputs.push(literal_f32(
                    x,
                    &[self.meta.batch, self.meta.seq_len,
                      self.meta.features])?);
                let outputs = pred.run(&inputs)?;
                Ok(outputs[0].to_vec::<f32>()?)
            }
        }
    }

    /// Batched inference over a partial batch: (params, `rows` rows of
    /// input) -> logits `[rows * classes]`.
    ///
    /// The serving micro-batcher rarely fills the executable's compiled
    /// batch exactly, and both native model families compute each batch
    /// row independently (row-major matmuls / per-row LSTM recurrence),
    /// so zero-padding the tail rows and truncating the output is
    /// bitwise-identical per row to a full-batch call. `rows` must be
    /// in `1..=meta.batch`.
    pub fn predict_rows(&self, params: &ParamSet, x: &[f32], rows: usize)
        -> Result<Vec<f32>, RuntimeError> {
        let row_len = self.meta.seq_len * self.meta.features;
        if rows == 0 || rows > self.meta.batch {
            return Err(RuntimeError::BadInput {
                what: "rows", expect: self.meta.batch, got: rows });
        }
        if x.len() != rows * row_len {
            return Err(RuntimeError::BadInput {
                what: "x", expect: rows * row_len, got: x.len() });
        }
        if rows == self.meta.batch {
            return self.predict(params, x);
        }
        let mut padded = vec![0.0f32; self.meta.x_len()];
        padded[..x.len()].copy_from_slice(x);
        let mut logits = self.predict(params, &padded)?;
        logits.truncate(rows * self.meta.classes);
        Ok(logits)
    }

    /// Fresh Glorot-initialized parameters matching this variant.
    pub fn init_params(&self, rng: &mut crate::util::rng::Rng) -> ParamSet {
        ParamSet::glorot_init(&self.meta.params, rng)
    }
}

// Arc sharing across worker threads requires Send + Sync; the native
// backend derives it structurally, the pjrt backend from the unsafe
// impls above.
const _: () = {
    fn assert_send_sync<T: Send + Sync>() {}
    #[allow(dead_code)]
    fn check() {
        assert_send_sync::<Arc<ModelExecutables>>();
    }
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::meta_for_key;

    /// Partial-batch inference must be bitwise-identical per row to a
    /// full-batch call — the property the serving micro-batcher's
    /// zero-pad-and-truncate path stands on, for both model families.
    #[test]
    fn predict_rows_matches_full_batch_prefix() {
        for key in ["mlp_b8", "lstm_b8"] {
            let meta = meta_for_key(key).unwrap();
            let exe = ModelExecutables::native(&meta).unwrap();
            let mut rng = crate::util::rng::Rng::new(3);
            let params = exe.init_params(&mut rng);
            let row = meta.seq_len * meta.features;
            let x: Vec<f32> = (0..meta.x_len())
                .map(|i| ((i % 97) as f32) * 0.021 - 1.0)
                .collect();
            let full = exe.predict(&params, &x).unwrap();
            for rows in [1usize, 3, meta.batch] {
                let part = exe
                    .predict_rows(&params, &x[..rows * row], rows)
                    .unwrap();
                assert_eq!(part.len(), rows * meta.classes);
                assert_eq!(part[..], full[..rows * meta.classes],
                           "{key} rows={rows} diverged");
            }
        }
    }

    #[test]
    fn predict_rows_validates_inputs() {
        let meta = meta_for_key("mlp_b4").unwrap();
        let exe = ModelExecutables::native(&meta).unwrap();
        let mut rng = crate::util::rng::Rng::new(1);
        let params = exe.init_params(&mut rng);
        let row = meta.seq_len * meta.features;
        // zero rows rejected
        assert!(exe.predict_rows(&params, &[], 0).is_err());
        // more rows than the compiled batch rejected
        let big = vec![0.0f32; 5 * row];
        assert!(exe.predict_rows(&params, &big, 5).is_err());
        // length/rows mismatch rejected
        let x = vec![0.0f32; 2 * row - 1];
        assert!(exe.predict_rows(&params, &x, 2).is_err());
    }
}
