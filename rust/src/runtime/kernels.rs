//! Lane-chunked, pool-parallel GEMM microkernels with a bitwise
//! contract.
//!
//! The three accumulating matmuls below replace the scalar triple loops
//! that used to live in `runtime/native.rs` — and they are required to
//! produce **bit-for-bit identical output at any thread count**,
//! because the all-reduce trainer's replicated-optimizer correctness
//! (and every bitwise e2e test in this repo) rides on deterministic
//! gradients. Two rules make that possible (DESIGN.md §Compute
//! kernels):
//!
//! 1. **Vectorize only along non-reduction axes.** Each output element
//!    `c[i][j]` accumulates its `k`-products in exactly the scalar
//!    reference order. The lane dimension is a tile of *independent*
//!    output columns (`j .. j+LANE`), each with its own register
//!    accumulator — LLVM can turn that into SIMD because the lanes
//!    never mix, and the per-element op order never changes. The
//!    reduction axis is never split across lanes or threads.
//! 2. **Parallelize over output row blocks.** Threads own disjoint,
//!    statically partitioned blocks of C's rows
//!    ([`crate::util::threadpool::block_range`]); no two threads touch
//!    the same output element, so there is nothing to combine and no
//!    combination order to get wrong.
//!
//! Register accumulation (load `c`, add the ordered products, store
//! once) is bitwise-equal to the in-memory reference because Rust
//! never contracts `a*b + c` into an FMA on its own, and f32 loads and
//! stores are exact. The scalar references live in [`scalar`] and the
//! property suite at the bottom pins every kernel to them across odd
//! shapes and thread counts.

use std::ops::Range;

use crate::util::threadpool::{block_range, SharedMut, ThreadPool};

/// Width of the independent-column register tile. Not a correctness
/// parameter (lanes are independent), only a vectorization hint.
pub(crate) const LANE: usize = 8;

/// A parallel part must carry at least this many flops before forking
/// helps; below it the kernels run inline on the caller.
pub(crate) const MIN_FLOPS_PER_PART: usize = 65_536;

/// Minimum elements per part for pooled elementwise loops
/// ([`par_blocks`]): gate activations, optimizer steps, fp16 codec.
pub(crate) const MIN_ELEMS_PER_PART: usize = 4_096;

/// How many row blocks to use for a kernel of `rows` rows and `flops`
/// total flops on `pool` — 1 when the matrix is too small to be worth
/// waking helpers for.
fn row_parts(pool: &ThreadPool, rows: usize, flops: usize) -> usize {
    pool.threads()
        .min(rows.max(1))
        .min((flops / MIN_FLOPS_PER_PART).max(1))
}

/// Reference (and fallback) scalar kernels: the exact pre-pool triple
/// loops. These define the accumulation order the pooled kernels must
/// reproduce bit for bit; the property tests compare against them.
pub(crate) mod scalar {
    /// C[rows, cols] += A[rows, inner] @ B[inner, cols]
    pub(crate) fn matmul_acc(a: &[f32], b: &[f32], c: &mut [f32],
                             rows: usize, inner: usize, cols: usize) {
        for i in 0..rows {
            let arow = &a[i * inner..(i + 1) * inner];
            let crow = &mut c[i * cols..(i + 1) * cols];
            for (p, &av) in arow.iter().enumerate() {
                let brow = &b[p * cols..(p + 1) * cols];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    }

    /// C[rows, cols] += A[inner, rows]^T @ B[inner, cols]
    pub(crate) fn matmul_tn_acc(a: &[f32], b: &[f32], c: &mut [f32],
                                rows: usize, inner: usize, cols: usize) {
        for p in 0..inner {
            let arow = &a[p * rows..(p + 1) * rows];
            let brow = &b[p * cols..(p + 1) * cols];
            for (i, &av) in arow.iter().enumerate() {
                let crow = &mut c[i * cols..(i + 1) * cols];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    }

    /// C[rows, cols] += A[rows, inner] @ B[cols, inner]^T
    pub(crate) fn matmul_nt_acc(a: &[f32], b: &[f32], c: &mut [f32],
                                rows: usize, inner: usize, cols: usize) {
        for i in 0..rows {
            let arow = &a[i * inner..(i + 1) * inner];
            for j in 0..cols {
                let brow = &b[j * inner..(j + 1) * inner];
                let mut acc = 0.0f32;
                for (&av, &bv) in arow.iter().zip(brow) {
                    acc += av * bv;
                }
                c[i * cols + j] += acc;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// single-block (row-range) microkernels
// ---------------------------------------------------------------------------

/// `matmul_acc` restricted to C rows `ir` (with `c` being exactly that
/// block). Lane tile over columns; `p` ascends per element exactly as
/// in the scalar reference.
fn acc_block(a: &[f32], b: &[f32], c: &mut [f32], ir: Range<usize>,
             inner: usize, cols: usize) {
    for (bi, i) in ir.enumerate() {
        let arow = &a[i * inner..(i + 1) * inner];
        let crow = &mut c[bi * cols..(bi + 1) * cols];
        let mut j = 0;
        while j < cols {
            let w = LANE.min(cols - j);
            let mut acc = [0.0f32; LANE];
            acc[..w].copy_from_slice(&crow[j..j + w]);
            for (p, &av) in arow.iter().enumerate() {
                let brow = &b[p * cols + j..p * cols + j + w];
                for (accv, &bv) in acc[..w].iter_mut().zip(brow) {
                    *accv += av * bv;
                }
            }
            crow[j..j + w].copy_from_slice(&acc[..w]);
            j += w;
        }
    }
}

/// `matmul_tn_acc` restricted to C rows `ir` — the loop nest is
/// re-ordered to make C's row the outer axis (so rows can be owned by
/// threads), but each element still sees `p` ascending, which is the
/// scalar reference's per-element order.
fn tn_block(a: &[f32], b: &[f32], c: &mut [f32], ir: Range<usize>,
            rows: usize, inner: usize, cols: usize) {
    for (bi, i) in ir.enumerate() {
        let crow = &mut c[bi * cols..(bi + 1) * cols];
        let mut j = 0;
        while j < cols {
            let w = LANE.min(cols - j);
            let mut acc = [0.0f32; LANE];
            acc[..w].copy_from_slice(&crow[j..j + w]);
            for p in 0..inner {
                let av = a[p * rows + i];
                let brow = &b[p * cols + j..p * cols + j + w];
                for (accv, &bv) in acc[..w].iter_mut().zip(brow) {
                    *accv += av * bv;
                }
            }
            crow[j..j + w].copy_from_slice(&acc[..w]);
            j += w;
        }
    }
}

/// `matmul_nt_acc` restricted to C rows `ir`. The `k` dot product IS
/// the reduction, so it stays a sequential scalar chain per output
/// element; the lane tile is `w` *independent* dot products advanced
/// in lockstep over `k`.
fn nt_block(a: &[f32], b: &[f32], c: &mut [f32], ir: Range<usize>,
            inner: usize, cols: usize) {
    for (bi, i) in ir.enumerate() {
        let arow = &a[i * inner..(i + 1) * inner];
        let crow = &mut c[bi * cols..(bi + 1) * cols];
        let mut j = 0;
        while j < cols {
            let w = LANE.min(cols - j);
            let mut acc = [0.0f32; LANE];
            for (k, &av) in arow.iter().enumerate() {
                for (jj, accv) in acc[..w].iter_mut().enumerate() {
                    *accv += av * b[(j + jj) * inner + k];
                }
            }
            for (jj, accv) in acc[..w].iter().enumerate() {
                crow[j + jj] += *accv;
            }
            j += w;
        }
    }
}

// ---------------------------------------------------------------------------
// pooled entry points
// ---------------------------------------------------------------------------

/// C[rows, cols] += A[rows, inner] @ B[inner, cols], parallel over row
/// blocks of C. Bitwise-identical to [`scalar::matmul_acc`] at any
/// thread count.
pub(crate) fn matmul_acc(pool: &ThreadPool, a: &[f32], b: &[f32],
                         c: &mut [f32], rows: usize, inner: usize,
                         cols: usize) {
    debug_assert_eq!(a.len(), rows * inner);
    debug_assert_eq!(b.len(), inner * cols);
    debug_assert_eq!(c.len(), rows * cols);
    let parts = row_parts(pool, rows, 2 * rows * inner * cols);
    if parts <= 1 {
        return acc_block(a, b, c, 0..rows, inner, cols);
    }
    let cv = SharedMut::new(c);
    pool.run(parts, |idx| {
        let r = block_range(rows, parts, idx);
        let cb = unsafe { cv.range(r.start * cols..r.end * cols) };
        acc_block(a, b, cb, r, inner, cols);
    });
}

/// C[rows, cols] += A[inner, rows]^T @ B[inner, cols], parallel over
/// row blocks of C. Bitwise-identical to [`scalar::matmul_tn_acc`] at
/// any thread count.
pub(crate) fn matmul_tn_acc(pool: &ThreadPool, a: &[f32], b: &[f32],
                            c: &mut [f32], rows: usize, inner: usize,
                            cols: usize) {
    debug_assert_eq!(a.len(), inner * rows);
    debug_assert_eq!(b.len(), inner * cols);
    debug_assert_eq!(c.len(), rows * cols);
    let parts = row_parts(pool, rows, 2 * rows * inner * cols);
    if parts <= 1 {
        return tn_block(a, b, c, 0..rows, rows, inner, cols);
    }
    let cv = SharedMut::new(c);
    pool.run(parts, |idx| {
        let r = block_range(rows, parts, idx);
        let cb = unsafe { cv.range(r.start * cols..r.end * cols) };
        tn_block(a, b, cb, r, rows, inner, cols);
    });
}

/// C[rows, cols] += A[rows, inner] @ B[cols, inner]^T, parallel over
/// row blocks of C. Bitwise-identical to [`scalar::matmul_nt_acc`] at
/// any thread count.
pub(crate) fn matmul_nt_acc(pool: &ThreadPool, a: &[f32], b: &[f32],
                            c: &mut [f32], rows: usize, inner: usize,
                            cols: usize) {
    debug_assert_eq!(a.len(), rows * inner);
    debug_assert_eq!(b.len(), cols * inner);
    debug_assert_eq!(c.len(), rows * cols);
    let parts = row_parts(pool, rows, 2 * rows * inner * cols);
    if parts <= 1 {
        return nt_block(a, b, c, 0..rows, inner, cols);
    }
    let cv = SharedMut::new(c);
    pool.run(parts, |idx| {
        let r = block_range(rows, parts, idx);
        let cb = unsafe { cv.range(r.start * cols..r.end * cols) };
        nt_block(a, b, cb, r, inner, cols);
    });
}

/// Pooled elementwise loop: run `f` over disjoint blocks of
/// `0..total`, at least [`MIN_ELEMS_PER_PART`] elements per part.
/// Callers compute each element exactly as their scalar loop did, so
/// results are bitwise-identical at any thread count.
pub(crate) fn par_blocks(pool: &ThreadPool, total: usize,
                         f: impl Fn(Range<usize>) + Sync) {
    pool.run_blocks(total, MIN_ELEMS_PER_PART, f);
}

/// Sustained GFLOP/s of [`matmul_acc`] on an `m x k x n` problem:
/// best-of-`reps` wall time (the calibration probe behind
/// `CostModel`'s compute term and the microbench GFLOP/s table).
pub(crate) fn gemm_gflops(pool: &ThreadPool, m: usize, k: usize,
                          n: usize, reps: usize) -> f64 {
    let a: Vec<f32> = (0..m * k).map(|i| (i % 97) as f32 * 0.013).collect();
    let b: Vec<f32> = (0..k * n).map(|i| (i % 89) as f32 * 0.011).collect();
    let mut c = vec![0.0f32; m * n];
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    // warm-up (page-in + pool wake)
    matmul_acc(pool, &a, &b, &mut c, m, k, n);
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = std::time::Instant::now();
        matmul_acc(pool, &a, &b, &mut c, m, k, n);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    std::hint::black_box(&c);
    flops / best / 1e9
}

/// Sustained GFLOP/s of a named kernel (`"nn"`, `"tn"`, `"nt"`) on an
/// `m x k x n` problem with a fresh `threads`-wide pool: best-of-`reps`
/// wall time. Public for `benches/runtime_microbench.rs` and the CI
/// compute gate; the training path sizes its long-lived pool through
/// `ModelExecutables::set_threads` instead.
pub fn kernel_gflops(kernel: &str, threads: usize, m: usize, k: usize,
                     n: usize, reps: usize) -> f64 {
    let pool = ThreadPool::new(threads.max(1));
    let (na, nb) = match kernel {
        "tn" => (k * m, k * n),
        "nt" => (m * k, n * k),
        _ => (m * k, k * n),
    };
    let a: Vec<f32> =
        (0..na).map(|i| (i % 97) as f32 * 0.013).collect();
    let b: Vec<f32> =
        (0..nb).map(|i| (i % 89) as f32 * 0.011).collect();
    let mut c = vec![0.0f32; m * n];
    let run = |c: &mut [f32]| match kernel {
        "tn" => matmul_tn_acc(&pool, &a, &b, c, m, k, n),
        "nt" => matmul_nt_acc(&pool, &a, &b, c, m, k, n),
        _ => matmul_acc(&pool, &a, &b, c, m, k, n),
    };
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    run(&mut c); // warm-up: page-in + pool wake
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = std::time::Instant::now();
        run(&mut c);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    std::hint::black_box(&c);
    flops / best / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fill(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    /// Shapes chosen to hit every boundary: degenerate 1x1, rows <
    /// threads, inner/cols not multiples of LANE, and the real model
    /// shapes (mlp fc0, lstm gate block).
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (1, 3, 5),
        (2, 7, 9),
        (3, 1, 17),
        (5, 13, 8),
        (7, 11, 19),
        (10, 16, 80),
        (16, 33, 7),
        (100, 480, 64),
    ];

    #[test]
    fn matmul_acc_bitwise_matches_scalar_at_any_thread_count() {
        let mut rng = Rng::new(2024);
        for &threads in &[1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            for &(rows, inner, cols) in SHAPES {
                let a = fill(&mut rng, rows * inner);
                let b = fill(&mut rng, inner * cols);
                let init = fill(&mut rng, rows * cols);
                let mut want = init.clone();
                scalar::matmul_acc(&a, &b, &mut want, rows, inner, cols);
                let mut got = init.clone();
                matmul_acc(&pool, &a, &b, &mut got, rows, inner, cols);
                assert!(
                    got.iter().zip(&want)
                        .all(|(x, y)| x.to_bits() == y.to_bits()),
                    "acc {rows}x{inner}x{cols} t={threads}"
                );
            }
        }
    }

    #[test]
    fn matmul_tn_acc_bitwise_matches_scalar_at_any_thread_count() {
        let mut rng = Rng::new(77);
        for &threads in &[1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            for &(rows, inner, cols) in SHAPES {
                let a = fill(&mut rng, inner * rows);
                let b = fill(&mut rng, inner * cols);
                let init = fill(&mut rng, rows * cols);
                let mut want = init.clone();
                scalar::matmul_tn_acc(&a, &b, &mut want, rows, inner,
                                      cols);
                let mut got = init.clone();
                matmul_tn_acc(&pool, &a, &b, &mut got, rows, inner,
                              cols);
                assert!(
                    got.iter().zip(&want)
                        .all(|(x, y)| x.to_bits() == y.to_bits()),
                    "tn {rows}x{inner}x{cols} t={threads}"
                );
            }
        }
    }

    #[test]
    fn matmul_nt_acc_bitwise_matches_scalar_at_any_thread_count() {
        let mut rng = Rng::new(4242);
        for &threads in &[1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            for &(rows, inner, cols) in SHAPES {
                let a = fill(&mut rng, rows * inner);
                let b = fill(&mut rng, cols * inner);
                let init = fill(&mut rng, rows * cols);
                let mut want = init.clone();
                scalar::matmul_nt_acc(&a, &b, &mut want, rows, inner,
                                      cols);
                let mut got = init.clone();
                matmul_nt_acc(&pool, &a, &b, &mut got, rows, inner,
                              cols);
                assert!(
                    got.iter().zip(&want)
                        .all(|(x, y)| x.to_bits() == y.to_bits()),
                    "nt {rows}x{inner}x{cols} t={threads}"
                );
            }
        }
    }

    #[test]
    fn kernels_bitwise_property_sweep() {
        // Random-shape property sweep on top of the fixed boundary
        // shapes: 64 random (rows, inner, cols) triples per kernel,
        // threads 1/2/4, all bit-for-bit vs the scalar reference.
        let mut rng = Rng::new(9001);
        let pools: Vec<ThreadPool> =
            [1usize, 2, 4].iter().map(|&t| ThreadPool::new(t)).collect();
        for case in 0..64 {
            let rows = 1 + rng.usize_below(24);
            let inner = 1 + rng.usize_below(40);
            let cols = 1 + rng.usize_below(24);
            let a = fill(&mut rng, rows * inner);
            let b_ic = fill(&mut rng, inner * cols);
            let a_ir = fill(&mut rng, inner * rows);
            let b_ci = fill(&mut rng, cols * inner);
            let init = fill(&mut rng, rows * cols);
            for pool in &pools {
                let mut want = init.clone();
                scalar::matmul_acc(&a, &b_ic, &mut want, rows, inner,
                                   cols);
                let mut got = init.clone();
                matmul_acc(pool, &a, &b_ic, &mut got, rows, inner, cols);
                assert!(got.iter().zip(&want)
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                        "case {case} acc");
                let mut want = init.clone();
                scalar::matmul_tn_acc(&a_ir, &b_ic, &mut want, rows,
                                      inner, cols);
                let mut got = init.clone();
                matmul_tn_acc(pool, &a_ir, &b_ic, &mut got, rows, inner,
                              cols);
                assert!(got.iter().zip(&want)
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                        "case {case} tn");
                let mut want = init.clone();
                scalar::matmul_nt_acc(&a, &b_ci, &mut want, rows, inner,
                                      cols);
                let mut got = init.clone();
                matmul_nt_acc(pool, &a, &b_ci, &mut got, rows, inner,
                              cols);
                assert!(got.iter().zip(&want)
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                        "case {case} nt");
            }
        }
    }

    #[test]
    fn gemm_gflops_probe_is_finite_and_positive() {
        let pool = ThreadPool::new(2);
        let g = gemm_gflops(&pool, 32, 32, 32, 2);
        assert!(g.is_finite() && g > 0.0, "gflops = {g}");
        for k in ["nn", "tn", "nt"] {
            let g = kernel_gflops(k, 2, 16, 16, 16, 1);
            assert!(g.is_finite() && g > 0.0, "{k}: {g}");
        }
    }
}
