//! Artifact manifest: what `python/compile/aot.py` emitted.
//!
//! `artifacts/meta.json` describes every AOT-compiled model variant: the
//! parameter names/shapes (in the positional order the HLO entry expects),
//! the input shapes, and the grad/eval/predict HLO file names. This module
//! parses it (with the from-scratch JSON substrate) into typed structs.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Debug)]
pub enum ArtifactError {
    Io { path: PathBuf, err: std::io::Error },
    Parse(String),
    UnknownVariant(String),
    MissingFile(PathBuf),
}

impl std::fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArtifactError::Io { path, err } => {
                write!(f, "io reading {}: {err}", path.display())
            }
            ArtifactError::Parse(msg) => write!(f, "manifest parse: {msg}"),
            ArtifactError::UnknownVariant(key) => {
                write!(f, "manifest missing model variant '{key}'")
            }
            ArtifactError::MissingFile(path) => {
                write!(f, "artifact file missing: {}", path.display())
            }
        }
    }
}

impl std::error::Error for ArtifactError {}

/// One (model, batch) variant from the manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelMeta {
    pub key: String,
    pub model: String,
    pub batch: usize,
    pub seq_len: usize,
    pub features: usize,
    pub classes: usize,
    pub hidden: usize,
    /// (name, shape) in the artifact's positional parameter order.
    pub params: Vec<(String, Vec<usize>)>,
    pub param_count: usize,
    pub grad_file: PathBuf,
    pub eval_file: PathBuf,
    pub predict_file: PathBuf,
}

impl ModelMeta {
    /// Floats per full training example batch: batch * seq_len * features.
    pub fn x_len(&self) -> usize {
        self.batch * self.seq_len * self.features
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: Vec<ModelMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, ArtifactError> {
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .map_err(|err| ArtifactError::Io { path: meta_path.clone(),
                                               err })?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, ArtifactError> {
        let j = Json::parse(text)
            .map_err(|e| ArtifactError::Parse(e.to_string()))?;
        let models_j = j
            .get("models")
            .and_then(|m| m.as_obj())
            .ok_or_else(|| ArtifactError::Parse("no 'models' object"
                .into()))?;
        let mut models = Vec::with_capacity(models_j.len());
        for (key, entry) in models_j {
            models.push(Self::parse_entry(dir, key, entry)?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), models })
    }

    fn parse_entry(dir: &Path, key: &str, entry: &Json)
        -> Result<ModelMeta, ArtifactError> {
        let perr = |m: &str| ArtifactError::Parse(format!("{key}: {m}"));
        let usize_field = |name: &str| -> Result<usize, ArtifactError> {
            entry
                .get(name)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| perr(&format!("missing usize '{name}'")))
        };
        let params_j = entry
            .get("params")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| perr("missing params"))?;
        let mut params = Vec::with_capacity(params_j.len());
        for p in params_j {
            let name = p
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| perr("param missing name"))?;
            let shape: Option<Vec<usize>> = p
                .get("shape")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|d| d.as_usize()).collect());
            let shape = shape.ok_or_else(|| perr("param missing shape"))?;
            params.push((name.to_string(), shape));
        }
        let arts = entry
            .get("artifacts")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| perr("missing artifacts"))?;
        let file = |kind: &str| -> Result<PathBuf, ArtifactError> {
            let name = arts
                .get(kind)
                .and_then(|v| v.as_str())
                .ok_or_else(|| perr(&format!("missing artifact '{kind}'")))?;
            Ok(dir.join(name))
        };
        Ok(ModelMeta {
            key: key.to_string(),
            model: entry
                .get("model")
                .and_then(|v| v.as_str())
                .ok_or_else(|| perr("missing model"))?
                .to_string(),
            batch: usize_field("batch")?,
            seq_len: usize_field("seq_len")?,
            features: usize_field("features")?,
            classes: usize_field("classes")?,
            hidden: usize_field("hidden")?,
            params,
            param_count: usize_field("param_count")?,
            grad_file: file("grad")?,
            eval_file: file("eval")?,
            predict_file: file("predict")?,
        })
    }

    pub fn get(&self, key: &str) -> Result<&ModelMeta, ArtifactError> {
        self.models
            .iter()
            .find(|m| m.key == key)
            .ok_or_else(|| ArtifactError::UnknownVariant(key.to_string()))
    }

    /// Variant for (model, batch), e.g. ("lstm", 100) -> lstm_b100.
    pub fn variant(&self, model: &str, batch: usize)
        -> Result<&ModelMeta, ArtifactError> {
        self.get(&format!("{model}_b{batch}"))
    }

    /// Verify every referenced HLO file exists.
    pub fn check_files(&self) -> Result<(), ArtifactError> {
        for m in &self.models {
            for f in [&m.grad_file, &m.eval_file, &m.predict_file] {
                if !f.exists() {
                    return Err(ArtifactError::MissingFile(f.clone()));
                }
            }
        }
        Ok(())
    }
}

/// Default artifact dir: $MPI_LEARN_ARTIFACTS or ./artifacts.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("MPI_LEARN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format_version": 1,
      "models": {
        "lstm_b100": {
          "model": "lstm", "batch": 100, "seq_len": 30, "features": 16,
          "classes": 3, "hidden": 20,
          "params": [
            {"name": "lstm_b", "shape": [80]},
            {"name": "lstm_wh", "shape": [20, 80]},
            {"name": "lstm_wx", "shape": [16, 80]},
            {"name": "out_b", "shape": [3]},
            {"name": "out_w", "shape": [20, 3]}
          ],
          "param_count": 3023,
          "inputs": {"x": [100, 30, 16], "y": [100]},
          "artifacts": {"grad": "lstm_b100_grad.hlo.txt",
                        "eval": "lstm_b100_eval.hlo.txt",
                        "predict": "lstm_b100_predict.hlo.txt"}
        }
      }
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(Path::new("/tmp/arts"), SAMPLE).unwrap();
        assert_eq!(m.models.len(), 1);
        let v = m.variant("lstm", 100).unwrap();
        assert_eq!(v.batch, 100);
        assert_eq!(v.params.len(), 5);
        assert_eq!(v.params[1], ("lstm_wh".to_string(), vec![20, 80]));
        assert_eq!(v.x_len(), 100 * 30 * 16);
        assert_eq!(v.grad_file,
                   Path::new("/tmp/arts/lstm_b100_grad.hlo.txt"));
    }

    #[test]
    fn unknown_variant_errors() {
        let m = Manifest::parse(Path::new("."), SAMPLE).unwrap();
        assert!(matches!(m.variant("lstm", 999),
                         Err(ArtifactError::UnknownVariant(_))));
    }

    #[test]
    fn missing_fields_error() {
        let bad = r#"{"models": {"x_b1": {"model": "x"}}}"#;
        assert!(Manifest::parse(Path::new("."), bad).is_err());
    }

    #[test]
    fn check_files_detects_missing() {
        let m = Manifest::parse(Path::new("/nonexistent_dir_xyz"),
                                SAMPLE).unwrap();
        assert!(matches!(m.check_files(),
                         Err(ArtifactError::MissingFile(_))));
    }

    #[test]
    fn parses_real_manifest_if_built() {
        // Integration-style: only runs when `make artifacts` has run.
        let dir = default_artifact_dir();
        if dir.join("meta.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.variant("lstm", 100).is_ok());
            m.check_files().unwrap();
        }
    }
}
